"""Multi-host sharded serving (DESIGN.md §8): the Engine on a TP/SP mesh
with replicated config tensors, on 8 forced host devices (subprocess
isolation — the main test process must keep seeing 1 device, see
tests/test_multidevice.py).

The acceptance bar: sharded decode is BIT-identical to the single-host
path (tokens compared on a random-init model, where any float
divergence flips an argmax), including mixed (n_layers[, E][, g])
config tensors, live retunes (``apply_allocation`` and a running
``PowerBudgetScheduler``), and zero retraces throughout.
"""
import jax
from conftest import run_forced_devices as run_sub


PRELUDE = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_serve_mesh
from repro.dist.sharding import serve_mapping, activate
from repro.nn import transformer as T
from repro.serve.engine import Engine, Request
assert len(jax.devices()) == 8
"""


def test_sharded_dense_engine_scheduler_bit_identity():
    """Dense LM on a (2, 4) data x model mesh, a PowerBudgetScheduler
    closing the loop on BOTH engines: the sharded engine must emit the
    exact token stream of the single-host engine (probes, retunes and
    all), meet the budget, and never retrace.  Also: sequence-parallel
    (kv="seq") prefill+decode matches the single-host logits."""
    run_sub(PRELUDE + """
from repro.core.power_model import energy_per_token_pj
from repro.serve.scheduler import PowerBudgetScheduler

cfg = T.ModelConfig(
    name="demo-lm", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, scan_layers=False,
    remat=False, q_chunk=32, loss_chunks=1, compute_dtype=jnp.float32)
params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
# two fixed prompt lengths -> exactly two prefill executables per engine
prompts = [rng.integers(0, 256, size=(6, 10)[i % 2]) for i in range(4)]

def serve(mapping):
    # no backoffs (hysteresis effectively off) so every retune's plan
    # deterministically converges to the budget from below
    sched = PowerBudgetScheduler(0.0, retune_every=6, probe_every=2,
                                 agreement_target=0.5,
                                 hysteresis=10**6, seed=0)
    eng = Engine(params, cfg, max_batch=4, max_len=48, scheduler=sched,
                 mapping=mapping, param_specs=specs)
    eng.rng = jax.random.PRNGKey(0)
    sched.set_budget(0.9 * energy_per_token_pj(
        np.zeros(cfg.n_layers, np.int32), eng.macs_per_token))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=8))
    eng.run()
    warm = (eng._decode._cache_size(), eng._prefill._cache_size())
    # live mixed per-layer retune between batches, as a controller would
    eng.apply_allocation({0: 31, 2: 5})
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=10 + i, prompt=p, max_new_tokens=8))
    done = eng.run()
    assert (eng._decode._cache_size(), eng._prefill._cache_size()) == warm
    toks = [t for r in sorted(done, key=lambda r: r.rid) for t in r.tokens]
    return eng, sched, toks

eng0, sched0, toks0 = serve(None)
mesh = make_serve_mesh(dp=2, tp=4)
eng1, sched1, toks1 = serve(serve_mapping(mesh, kv="hd"))

# bit-identity: same tokens, same scheduler trajectory, budget met
assert toks1 == toks0
assert sched1.n_probes == sched0.n_probes > 0
assert sched1.n_agree == sched0.n_agree
r0, r1 = sched0.report(), sched1.report()
assert r1["assignment"] == r0["assignment"]
assert r1["retunes"] == r0["retunes"] >= 2
assert r1["modeled_pj_per_token"] <= r1["budget_pj_per_token"] * (1 + 1e-9)

# placement sanity: params sharded by logical specs, cache by kv spec
wq = eng1.params["blocks"]["scan"]["b0"]["attn"]["wq"]
assert "model" in str(wq.values.sharding.spec), wq.values.sharding
assert "model" in str(wq.scale.sharding.spec), wq.scale.sharding
k = eng1.cache["scan"]["b0"]["k"]     # (L, B, S, KV, hd)
assert k.sharding.spec[3] == "model", k.sharding.spec   # KV heads TP
assert k.sharding.spec[1] == "data", k.sharding.spec    # batch DP
print("dense sharded engine OK")

# --- sequence parallelism (kv="seq"): sharded softmax reassociates the
# float reduction, so the bar is allclose, not bit-identity ------------
cfg_sp = dataclasses.replace(cfg, kv_onehot_write=True)
mp = serve_mapping(mesh, kv="seq")
cache0, cspec = T.init_cache(cfg_sp, 1, 32)
sh = mp.shardings(cspec, cache0)
kspec = jax.tree_util.tree_flatten_with_path(sh)[0]
kv_leaves = [s for p, s in kspec if "'k'" in str(p) or "'v'" in str(p)]
assert any(s.spec[2] == "model" for s in kv_leaves), \
    "kv_seq must resolve to the model axis"   # (L, B, S, KV, hd) dim 2

tokens = jnp.asarray(prompts[0], jnp.int32)[None, :]
nxt = jnp.asarray([[7]], jnp.int32)
def prefill_decode(p, tokens, nxt):
    logits, cache = T.prefill(p, cfg_sp, tokens, max_len=32)
    l2, _ = T.decode_step(p, cfg_sp, cache, nxt)
    return logits, l2
ref1, ref2 = jax.jit(prefill_decode)(params, tokens, nxt)
with mp.mesh, activate(mp):
    sp1, sp2 = jax.jit(prefill_decode)(params, tokens, nxt)
np.testing.assert_allclose(np.asarray(sp1), np.asarray(ref1),
                           rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(sp2), np.asarray(ref2),
                           rtol=1e-5, atol=1e-5)
print("seq-parallel decode OK")
""")


def test_sharded_moe_pallas_mixed_expert_cfg_bit_identity():
    """MoE model through the grouped Pallas expert kernel on a (4, 2)
    mesh with a MIXED (n_layers, E, g) config tensor — the full config
    space of the engine — plus a live per-expert ``apply_allocation``
    retune: tokens bit-identical to single-host, zero retraces."""
    run_sub(PRELUDE + """
cfg = T.ModelConfig(
    name="demo-moe", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    head_dim=32, d_ff=128, vocab_size=256, n_experts=4, top_k=2,
    scan_layers=False, remat=False, q_chunk=32, loss_chunks=1,
    compute_dtype=jnp.float32, mac_backend="pallas", mac_interpret=True)
params, specs = T.init_lm(jax.random.PRNGKey(1), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, 256, size=6) for _ in range(3)]
mixed = np.asarray([[[0, 5], [8, 8], [16, 0], [31, 12]],
                    [[3, 3], [0, 31], [7, 7], [1, 9]]], np.int32)

def serve(mapping):
    eng = Engine(params, cfg, max_batch=2, max_len=32, cfg_experts=4,
                 cfg_groups=2, mapping=mapping, param_specs=specs)
    eng.rng = jax.random.PRNGKey(0)
    eng.set_approx_cfg(mixed)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    eng.run()
    warm = (eng._decode._cache_size(), eng._prefill._cache_size())
    eng.apply_allocation({(0, 1): 31, (1, 3): 2})   # single-expert keys
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=10 + i, prompt=p, max_new_tokens=5))
    done = eng.run()
    assert (eng._decode._cache_size(), eng._prefill._cache_size()) == warm
    return eng, [t for r in sorted(done, key=lambda r: r.rid)
                 for t in r.tokens]

eng0, toks0 = serve(None)
eng1, toks1 = serve(serve_mapping(make_serve_mesh(dp=4, tp=2), kv="hd"))
assert toks1 == toks0
bank = eng1.params["blocks"]["scan"]["b0"]["mlp"]["w_gate"]
assert bank.values.sharding.spec[-1] == "model", bank.values.sharding
assert bank.scale.sharding.spec[-1] == "model", bank.scale.sharding
print("moe sharded engine OK")
""")


def test_quantize_lm_specs_places_qtensor_trees():
    """In-process structural check (single-device mesh): the quantized
    spec tree must resolve a NamedSharding for every QTensor leaf of
    ``quantize_lm_params`` output — values AND scales — with the TP
    axis landing on the GEMM output dims."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.dist.sharding import serve_mapping
    from repro.launch.mesh import make_mesh
    from repro.nn import transformer as T

    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        n_experts=2, top_k=1, scan_layers=False,
                        remat=False, compute_dtype=jnp.float32)
    params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
    qparams = T.quantize_lm_params(params, cfg)
    qspecs = T.quantize_lm_specs(specs, cfg)
    mapping = serve_mapping(make_mesh((1, 1), ("data", "model")), kv="hd")
    sh = mapping.shardings(qspecs, qparams)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    assert all(isinstance(s, NamedSharding) for _, s in flat)
    by_path = {str(p): s for p, s in flat}
    wq = [s for p, s in flat if "wq" in str(p)]
    assert wq and all(s.spec and s.spec[-1] == "model" for s in wq), \
        [s.spec for s in wq]
    bank = [s for p, s in flat if "w_gate" in str(p)]
    assert bank and all(s.spec and s.spec[-1] == "model" for s in bank), \
        [s.spec for s in bank]
    # device_put must accept the resolved tree (size-1 axes: a no-op)
    jax.device_put(qparams, sh)
