"""Runtime-switchable error configs (PR 1 tentpole).

Contract: the traced-config paths are BIT-IDENTICAL to the static-config
reference for every one of the 32 configs, at every level of the stack
(XLA operand path, LUT oracle, Pallas kernel, paper-MLP datapath), and
switching configs between calls triggers ZERO recompilations — one
compiled artifact serves all 32 configs, including through the serving
engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approx_matmul import (approx_dense, approx_matmul_lut,
                                      approx_matmul_lut_blocked,
                                      approx_matmul_operand,
                                      approx_matmul_operand_blocked,
                                      operand_param_table)
from repro.core.approx_multiplier import (N_CONFIGS, OPERAND_PARAM_TABLE,
                                          operand_params)
from repro.core.quantization import quantize, truncate_operand_lsb
from repro.kernels.approx_mac.ops import (_approx_dense_fused_jit,
                                          _approx_mac_jit,
                                          approx_dense_pallas, approx_mac)

RNG = np.random.default_rng(7)
A = jnp.asarray(RNG.integers(-127, 128, (32, 64)), jnp.int8)
B = jnp.asarray(RNG.integers(-127, 128, (64, 48)), jnp.int8)


def _t(c):
    return jnp.asarray(c, jnp.int32)


# --- (a) traced == static, bit-identical, all 32 configs -------------------

def test_param_table_matches_static_params():
    assert OPERAND_PARAM_TABLE.shape == (N_CONFIGS, 4)
    for c in range(N_CONFIGS):
        assert tuple(OPERAND_PARAM_TABLE[c]) == operand_params(c)


@pytest.mark.parametrize("cfg", range(N_CONFIGS))
def test_operand_matmul_traced_bit_identical(cfg):
    ref = approx_matmul_operand(A, B, cfg)
    out = approx_matmul_operand(A, B, _t(cfg))
    assert jnp.array_equal(out, ref), cfg


def test_operand_matmul_bit_identical_with_int8_min():
    a = jnp.asarray([[-128, 5, -128, 127]], jnp.int8)
    b = jnp.asarray(RNG.integers(-128, 128, (4, 8)), jnp.int8)
    for cfg in range(N_CONFIGS):
        ref = approx_matmul_operand(a, b, cfg)
        out = approx_matmul_operand(a, b, _t(cfg))
        assert jnp.array_equal(out, ref), cfg


def test_lut_matmul_traced_bit_identical():
    a = A[:8, :16]
    b = B[:16, :8]
    for cfg in range(N_CONFIGS):
        assert jnp.array_equal(approx_matmul_lut(a, b, _t(cfg)),
                               approx_matmul_lut(a, b, cfg)), cfg


def test_truncate_operand_traced_bit_identical():
    # full int8 range INCLUDING -128 (unrepresentable in the paper's
    # signed-magnitude format and never produced by the quantizer, but a
    # valid raw input — regression: the traced depth==0 path used to
    # clamp |−128| to 127 while the static path kept it)
    v = jnp.arange(-128, 128, dtype=jnp.int8)
    for cfg in range(N_CONFIGS):
        d_a, d_b, gate, rtn = operand_params(cfg)
        for depth in (d_a, d_b):
            ref = truncate_operand_lsb(v, depth, gate, bool(rtn))
            out = truncate_operand_lsb(v, _t(depth), _t(gate), _t(rtn))
            assert jnp.array_equal(out, ref), (cfg, depth)


# --- (b) Pallas kernel (interpret mode) matches ----------------------------

@pytest.mark.parametrize("cfg", [0, 1, 5, 8, 13, 16, 24, 31])
def test_pallas_kernel_traced_config_matches_ref(cfg):
    ref = approx_matmul_operand(A, B, cfg)
    out = approx_mac(A, B, _t(cfg), interpret=True)
    assert out.dtype == jnp.int32
    assert jnp.array_equal(out, ref), cfg


# --- (b2) per-N-block (per-neuron) config vectors — PR 2 tentpole ----------

def test_operand_param_table_is_hoisted_device_constant():
    t1 = operand_param_table()
    t2 = operand_param_table()
    assert t1 is t2                       # one upload per process
    np.testing.assert_array_equal(np.asarray(t1), OPERAND_PARAM_TABLE)


def test_pallas_kernel_mixed_block_configs_match_operand_oracle():
    """One GEMM, different error configs per 128-column block — the
    kernel's per-tile scalar-prefetch vector vs the blocked reference."""
    a = jnp.asarray(RNG.integers(-127, 128, (32, 64)), jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 128, (64, 384)), jnp.int8)
    vec = jnp.asarray([3, 31, 8], jnp.int32)          # 3 blocks of 128
    out = approx_mac(a, b, vec, interpret=True)
    ref = approx_matmul_operand_blocked(a, b, vec, 128)
    assert jnp.array_equal(out, ref)
    # and each block individually equals the uniform-config kernel
    for i, c in enumerate([3, 31, 8]):
        blk = approx_mac(a, b[:, i * 128:(i + 1) * 128], c, interpret=True)
        assert jnp.array_equal(out[:, i * 128:(i + 1) * 128], blk), c


def test_blocked_lut_oracle_composes():
    """The bit-exact ASIC-model oracle for a mixed per-neuron-block GEMM
    composes from per-config LUT matmuls (and differs from uniform)."""
    a = A[:8, :16]
    b = B[:16, :8]
    vec = [1, 31]
    mixed = approx_matmul_lut_blocked(a, b, vec, 4)
    assert jnp.array_equal(mixed[:, :4], approx_matmul_lut(a, b[:, :4], 1))
    assert jnp.array_equal(mixed[:, 4:], approx_matmul_lut(a, b[:, 4:], 31))
    assert not jnp.array_equal(mixed, approx_matmul_lut(a, b, 1))


def test_group_vector_spreads_over_blocks():
    """A config vector shorter than n_blocks maps neuron groups onto
    contiguous logical column spans (group j owns [j*N/g, (j+1)*N/g))."""
    a = jnp.asarray(RNG.integers(-127, 128, (16, 64)), jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 128, (64, 512)), jnp.int8)   # 4 blocks
    out = approx_mac(a, b, jnp.asarray([2, 31], jnp.int32), interpret=True)
    ref = approx_matmul_operand_blocked(a, b, [2, 2, 31, 31], 128)
    assert jnp.array_equal(out, ref)


def test_group_vector_conservative_collapse():
    """Blocks that straddle a neuron-group boundary — or GEMMs too
    narrow to resolve the groups — run the lowest-measured-MRED config
    among their groups (never higher error than any covered neuron
    asked for).  cfg 11 has a higher index but LOWER measured error
    than cfg 9, so the collapse must rank by error, not index."""
    from repro.kernels.approx_mac.ops import _mred_table_dev
    mred = np.asarray(_mred_table_dev())
    assert mred[11] < mred[9]
    a = jnp.asarray(RNG.integers(-127, 128, (16, 64)), jnp.int8)
    # narrow GEMM: one 128-col block covering both groups -> cfg 11
    b1 = jnp.asarray(RNG.integers(-127, 128, (64, 128)), jnp.int8)
    out = approx_mac(a, b1, jnp.asarray([9, 11], jnp.int32), interpret=True)
    assert jnp.array_equal(out, approx_mac(a, b1, 11, interpret=True))
    # n=192: block 0 (cols 0-127) straddles the group boundary at 96 ->
    # lowest-MRED of the two; block 1 (cols 128-191) is inside group 1
    b2 = jnp.asarray(RNG.integers(-127, 128, (64, 192)), jnp.int8)
    out = approx_mac(a, b2, jnp.asarray([11, 9], jnp.int32), interpret=True)
    ref = approx_matmul_operand_blocked(a, b2, [11, 9], 128)
    assert jnp.array_equal(out, ref)
    # g == n_blocks but N % bn != 0: block spans != group spans, so the
    # per-block fast path must NOT apply — with groups [31, 0] over
    # n=200, group 1 (cols 100-199, exact) overlaps block 0, which must
    # collapse to exact; block 1 lies inside group 1 -> whole GEMM exact
    b3 = jnp.asarray(RNG.integers(-127, 128, (64, 200)), jnp.int8)
    out = approx_mac(a, b3, jnp.asarray([31, 0], jnp.int32), interpret=True)
    assert jnp.array_equal(out, approx_mac(a, b3, 0, interpret=True))


# --- (b3) fused float-in/float-out dense on the kernel path ----------------

X_F = jnp.asarray(RNG.normal(size=(20, 64)), jnp.float32)
W_F = jnp.asarray(RNG.normal(size=(64, 48)) * 0.05, jnp.float32)


@pytest.mark.parametrize("cfg", range(N_CONFIGS))
def test_fused_dense_pallas_bit_identical_to_xla_path(cfg):
    """Acceptance: the ONE-pallas_call fused path (in-kernel activation
    quantization + rescale epilogue) is bit-identical to the XLA operand
    path for every config."""
    w_qt = quantize(W_F, axis=1)
    ref = approx_dense(X_F, w_qt, _t(cfg))
    out = approx_dense_pallas(X_F, w_qt, config=_t(cfg), interpret=True,
                              compute_dtype=jnp.float32)
    assert jnp.array_equal(out, ref), cfg


def test_fused_matches_unfused_and_per_tensor_scale():
    w_qt = quantize(W_F)                    # per-tensor weight scale
    for cfg in (0, 8, 31):
        ref = jnp.asarray(approx_dense(X_F, w_qt, cfg), jnp.float32)
        fused = approx_dense_pallas(X_F, w_qt, config=cfg, interpret=True,
                                    compute_dtype=jnp.float32)
        unfused = approx_dense_pallas(X_F, w_qt, config=cfg, fused=False,
                                      interpret=True,
                                      compute_dtype=jnp.float32)
        assert jnp.array_equal(fused, ref), cfg
        assert jnp.array_equal(unfused, ref), cfg


def test_fused_dense_mixed_block_configs_match_blocked_composition():
    """dense-level per-neuron knob: a (2,) config vector over a 256-wide
    GEMM == concatenation of two uniform-config fused GEMMs == the
    blocked operand oracle on the quantized operands."""
    w = jnp.asarray(RNG.normal(size=(64, 256)) * 0.05, jnp.float32)
    w_qt = quantize(w, axis=1)
    vec = jnp.asarray([5, 24], jnp.int32)
    out = approx_dense_pallas(X_F, w_qt, config=vec, interpret=True,
                              compute_dtype=jnp.float32)
    x_qt = quantize(X_F)
    acc = approx_matmul_operand_blocked(x_qt.values, w_qt.values, vec, 128)
    # combined scale rounded once — the repo-wide rescale convention
    # (core.approx_matmul.approx_dense)
    ref = acc.astype(jnp.float32) * (x_qt.scale * w_qt.scale[None, :])
    assert jnp.array_equal(out, ref)


def test_dense_layer_pallas_backend_bit_identical():
    from repro.nn.layers import dense
    for cfg in (0, 1, 8, 16, 31):
        ref = dense(X_F, W_F, approx_cfg=_t(cfg), compute_dtype=jnp.float32)
        out = dense(X_F, W_F, approx_cfg=_t(cfg), backend="pallas",
                    interpret=True, compute_dtype=jnp.float32)
        assert jnp.array_equal(out, ref), cfg


# --- (c) zero recompilation across config sweeps ---------------------------

def test_operand_matmul_no_retrace_over_32_configs():
    f = jax.jit(approx_matmul_operand)
    f(A, B, _t(0))
    n0 = f._cache_size()
    for cfg in range(N_CONFIGS):
        f(A, B, _t(cfg))
    assert f._cache_size() == n0 == 1


def test_pallas_kernel_no_retrace_over_32_configs():
    approx_mac(A, B, 0, interpret=True)
    n0 = _approx_mac_jit._cache_size()
    for cfg in range(N_CONFIGS):
        approx_mac(A, B, cfg, interpret=True)
    assert _approx_mac_jit._cache_size() == n0


def test_pallas_per_block_vectors_no_retrace():
    """Sweeping per-N-block config VECTORS (fixed length) shares one
    executable — both the int kernel and the fused dense path."""
    b = jnp.asarray(RNG.integers(-127, 128, (64, 256)), jnp.int8)
    approx_mac(A, b, jnp.zeros((2,), jnp.int32), interpret=True)
    n0 = _approx_mac_jit._cache_size()
    w_qt = quantize(jnp.asarray(RNG.normal(size=(64, 256)) * 0.05,
                                jnp.float32), axis=1)
    approx_dense_pallas(X_F, w_qt, config=jnp.zeros((2,), jnp.int32),
                        interpret=True)
    f0 = _approx_dense_fused_jit._cache_size()
    for cfg in range(N_CONFIGS):
        vec = jnp.asarray([cfg, (cfg + 7) % N_CONFIGS], jnp.int32)
        approx_mac(A, b, vec, interpret=True)
        approx_dense_pallas(X_F, w_qt, config=vec, interpret=True)
    assert _approx_mac_jit._cache_size() == n0
    assert _approx_dense_fused_jit._cache_size() == f0


# --- paper-MLP datapath: integer logits bit-identical ----------------------

def _toy_qmlp():
    from repro.nn import mlp_paper as M
    params = M.init_params(jax.random.PRNGKey(0))
    calib = RNG.random((64, 62)).astype(np.float32)
    return M.QuantizedMLP.from_float(params, calib), calib[:16]


def test_quantized_mlp_traced_config_bit_identical():
    qm, x = _toy_qmlp()
    xq = qm.quantize_input(x)
    for method in ("lut", "operand"):
        for cfg in (0, 1, 8, 16, 31):
            ref = qm.apply(xq, cfg, method)
            out = qm.apply(xq, _t(cfg), method)
            assert jnp.array_equal(out, ref), (method, cfg)


def test_quantized_mlp_per_layer_configs():
    qm, x = _toy_qmlp()
    xq = qm.quantize_input(x)
    mixed = qm.apply(xq, (1, 31), "operand")
    assert not jnp.array_equal(mixed, qm.apply(xq, 0, "operand"))
    # bit-exact layer-wise composition: hidden GEMM at cfg 1, output
    # GEMM at cfg 31 (catches a swapped c1/c2 in _layer_configs)
    from repro.core.quantization import QMAX
    acc1 = approx_matmul_operand(jnp.asarray(xq), jnp.asarray(qm.w1), 1) \
        + jnp.asarray(qm.b1)[None, :]
    h = jnp.clip(jnp.maximum(acc1, 0) >> qm.shift1, 0, QMAX
                 ).astype(jnp.int8)
    ref = approx_matmul_operand(h, jnp.asarray(qm.w2), 31) \
        + jnp.asarray(qm.b2)[None, :]
    assert jnp.array_equal(mixed, ref)


# --- model + engine level ---------------------------------------------------

def _small_model():
    from repro.nn import transformer as T
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return T, cfg, params


def test_forward_traced_scalar_and_vector_agree():
    T, cfg, params = _small_model()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    for c in (0, 8, 31):
        h_scalar = T.forward(params, cfg, toks, approx_cfg=_t(c))
        h_vec = T.forward(params, cfg, toks,
                          approx_cfg=jnp.full((2,), c, jnp.int32))
        np.testing.assert_array_equal(np.asarray(h_scalar),
                                      np.asarray(h_vec))


def test_forward_no_retrace_over_configs():
    T, cfg, params = _small_model()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    f = jax.jit(lambda p, t, a: T.forward(p, cfg, t, approx_cfg=a))
    f(params, toks, jnp.zeros((2,), jnp.int32))
    n0 = f._cache_size()
    for c in range(N_CONFIGS):
        f(params, toks, jnp.full((2,), c, jnp.int32))
    assert f._cache_size() == n0 == 1


def test_engine_32_config_sweep_zero_retraces():
    """Acceptance: a scripted sweep over configs 0-31 through Engine
    completes with zero retraces after warmup."""
    from repro.serve.engine import Engine, Request
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=2, max_len=32)
    prompt = np.arange(8) % 64

    def one_round(c):
        eng.set_approx_cfg(c)
        eng.submit(Request(rid=c, prompt=prompt, max_new_tokens=3))
        done, eng.completed = eng.run(max_ticks=50), []
        assert len(done) == 1 and len(done[0].tokens) == 3

    one_round(0)   # warmup: compiles one prefill + one decode executable
    sizes = (eng._decode._cache_size(), eng._prefill._cache_size())
    for c in range(N_CONFIGS):
        one_round(c)
    assert (eng._decode._cache_size(), eng._prefill._cache_size()) == sizes

    # per-request + per-layer allocation reuse the same executables too
    eng.submit(Request(rid=100, prompt=prompt, max_new_tokens=3,
                       approx_cfg=31))
    eng.apply_allocation({"layer_0": 4, "layer_1": 27})
    eng.submit(Request(rid=101, prompt=prompt, max_new_tokens=3))
    done, eng.completed = eng.run(max_ticks=50), []
    assert len(done) == 2
    assert (eng._decode._cache_size(), eng._prefill._cache_size()) == sizes


def test_forward_accepts_0d_numpy_config():
    T, cfg, params = _small_model()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    h_np = T.forward(params, cfg, toks, approx_cfg=np.asarray(8))
    h_int = T.forward(params, cfg, toks, approx_cfg=8)
    np.testing.assert_array_equal(np.asarray(h_np), np.asarray(h_int))


def test_engine_live_retune_reaches_inflight_unpinned_slots():
    from repro.serve.engine import Engine, Request
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=2, max_len=32)
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64, max_new_tokens=20))
    eng.submit(Request(rid=1, prompt=np.arange(6) % 64, max_new_tokens=20,
                       approx_cfg=8))         # pinned by its request
    eng._admit()
    eng.set_approx_cfg(31)                    # mid-generation retune
    # unpinned slot follows the retune; the pinned one keeps its own 8
    np.testing.assert_array_equal(eng._pool_cfg(), [8, 8])
    eng.set_approx_cfg(2)
    np.testing.assert_array_equal(eng._pool_cfg(), [2, 2])


def test_engine_apply_allocation_rejects_bad_keys():
    from repro.serve.engine import Engine
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=1, max_len=32)
    eng.apply_allocation({"layer_1": 8, 0: 4})       # both key forms work
    np.testing.assert_array_equal(eng.approx_cfg, [4, 8])
    for bad in ({"attn": 8}, {"layer_-1": 8}, {"layer_2": 8}, {5: 8}):
        with pytest.raises(ValueError):
            eng.apply_allocation(bad)


def test_engine_pool_config_is_lowest_error_join():
    from repro.serve.engine import Engine, Request, _mred_table
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=2, max_len=32, approx_cfg=16)
    # cfg 11 has a HIGHER index but LOWER measured error than cfg 9 —
    # the join must rank by error, not by config index
    assert _mred_table()[11] < _mred_table()[9]
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64, max_new_tokens=8,
                       approx_cfg=jnp.asarray([9, 8])))
    eng.submit(Request(rid=1, prompt=np.arange(9) % 64, max_new_tokens=8,
                       approx_cfg=jnp.asarray([11, 31])))
    eng._admit()
    np.testing.assert_array_equal(eng._pool_cfg(), [11, 8])


# --- pallas serving backend (PR 2 tentpole) --------------------------------

def _small_model_pallas():
    import dataclasses
    T, cfg, params = _small_model()
    cfg_p = dataclasses.replace(cfg, mac_backend="pallas",
                                mac_interpret=True)
    return T, cfg, cfg_p, params


def test_quantize_lm_params_is_bit_identical_to_per_call_quantize():
    """Pre-quantizing GEMM weights once (engine init) must not change a
    single bit vs quantizing inside every call — same arrays, same
    per-output-channel scales, just hoisted out of the traced step."""
    T, cfg, params = _small_model()
    qp = T.quantize_lm_params(params, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    for c in (0, 8, 31):
        h_ref = T.forward(params, cfg, toks, approx_cfg=_t(c))
        h_q = T.forward(qp, cfg, toks, approx_cfg=_t(c))
        np.testing.assert_array_equal(np.asarray(h_ref), np.asarray(h_q))


def test_forward_pallas_backend_bit_identical_to_xla():
    T, cfg, cfg_p, params = _small_model_pallas()
    qp = T.quantize_lm_params(params, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    for c in (0, 8, 31):
        h_x = T.forward(qp, cfg, toks, approx_cfg=_t(c))
        h_p = T.forward(qp, cfg_p, toks, approx_cfg=_t(c))
        np.testing.assert_array_equal(np.asarray(h_x), np.asarray(h_p))


def test_forward_per_layer_per_block_config_matrix():
    """(n_layers, n_groups) config matrices flow through forward on the
    pallas backend; uniform rows reproduce the per-layer vector."""
    T, cfg, cfg_p, params = _small_model_pallas()
    qp = T.quantize_lm_params(params, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    uni = T.forward(qp, cfg_p, toks,
                    approx_cfg=jnp.asarray([8, 31], jnp.int32))
    mat = T.forward(qp, cfg_p, toks,
                    approx_cfg=jnp.asarray([[8, 8], [31, 31]], jnp.int32))
    np.testing.assert_array_equal(np.asarray(uni), np.asarray(mat))
    mixed = T.forward(qp, cfg_p, toks,
                      approx_cfg=jnp.asarray([[0, 31], [8, 16]], jnp.int32))
    assert mixed.shape == uni.shape


def test_engine_pallas_backend_32_config_sweep_zero_retraces():
    """Acceptance: a 0-31 sweep through the Engine on the pallas backend
    (fused kernel, pre-quantized QTensor weights, per-layer-per-block
    config matrices) completes with zero retraces after warmup."""
    from repro.serve.engine import Engine, Request
    T, cfg, cfg_p, params = _small_model_pallas()
    eng = Engine(params, cfg_p, max_batch=2, max_len=32, cfg_groups=2)
    assert eng.approx_cfg.shape == (2, 2)
    prompt = np.arange(8) % 64

    def one_round(c):
        eng.set_approx_cfg(c)
        eng.submit(Request(rid=int(np.max(c)), prompt=prompt,
                           max_new_tokens=2))
        done, eng.completed = eng.run(max_ticks=50), []
        assert len(done) == 1 and len(done[0].tokens) == 2

    one_round(0)   # warmup: compiles one prefill + one decode executable
    sizes = (eng._decode._cache_size(), eng._prefill._cache_size())
    for c in range(N_CONFIGS):
        one_round(c)
    # per-layer-per-block retunes ride the same executables
    one_round(np.asarray([[0, 31], [8, 16]], np.int32))
    eng.apply_allocation({"layer_0": 4, 0: 27})
    eng.submit(Request(rid=77, prompt=prompt, max_new_tokens=2,
                       approx_cfg=31))
    done, eng.completed = eng.run(max_ticks=50), []
    assert len(done) == 1
    assert (eng._decode._cache_size(), eng._prefill._cache_size()) == sizes


def test_recurrent_archs_pallas_backend_and_per_block_configs():
    """The backend switch reaches the recurrent/mlstm/slstm cells'
    projections too (dense_kw threading): pallas == xla on a hybrid
    global+recurrent model, and per-layer-per-block matrices trace
    (regression: the cells used to drop the backend, crashing vector
    configs and silently running XLA)."""
    import dataclasses
    T = __import__("repro.nn.transformer", fromlist=["x"])
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64, lru_width=32,
                pattern=("global", "recurrent"), scan_layers=False,
                remat=False, q_chunk=8, loss_chunks=1,
                compute_dtype=jnp.float32)
    cfg_p = T.ModelConfig(**base, mac_backend="pallas", mac_interpret=True)
    cfg_x = T.ModelConfig(**base)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg_p)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    for c in (0, 8):
        hx = T.forward(params, cfg_x, toks, approx_cfg=_t(c))
        hp = T.forward(params, cfg_p, toks, approx_cfg=_t(c))
        np.testing.assert_array_equal(np.asarray(hx), np.asarray(hp))
    h = T.forward(params, cfg_p, toks,
                  approx_cfg=jnp.asarray([[0, 31], [8, 16]], jnp.int32))
    assert h.shape == (2, 8, 32)
    for pat in (("mlstm",), ("slstm",)):
        c2 = dataclasses.replace(cfg_p, pattern=pat, lru_width=0)
        p2, _ = T.init_lm(jax.random.PRNGKey(0), c2)
        h2 = T.forward(p2, c2, toks,
                       approx_cfg=jnp.asarray([[0, 31], [8, 16]], jnp.int32))
        assert h2.shape == (2, 8, 32), pat


def test_engine_pool_join_per_layer_per_block():
    """The lowest-measured-error pool join extends elementwise to
    (n_layers, cfg_groups) matrices (cfg 11 has a higher index but lower
    MRED than cfg 9 — the join must rank by error, not index)."""
    from repro.serve.engine import Engine, Request, _mred_table
    T, cfg, cfg_p, params = _small_model_pallas()
    eng = Engine(params, cfg_p, max_batch=2, max_len=32, cfg_groups=2)
    assert _mred_table()[11] < _mred_table()[9]
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64, max_new_tokens=8,
                       approx_cfg=np.asarray([[9, 8], [31, 0]])))
    eng.submit(Request(rid=1, prompt=np.arange(9) % 64, max_new_tokens=8,
                       approx_cfg=np.asarray([[11, 31], [8, 0]])))
    eng._admit()
    np.testing.assert_array_equal(eng._pool_cfg(), [[11, 8], [8, 0]])


def test_quantized_mlp_pallas_method_matches_operand():
    """The paper's 62-30-10 network through the serving kernel: the
    "pallas" method is bit-identical to the "operand" XLA adaptation."""
    qm, x = _toy_qmlp()
    xq = qm.quantize_input(x)
    for cfg in (0, 8, 31):
        ref = qm.apply(xq, cfg, "operand")
        out = qm.apply(xq, _t(cfg), "pallas", interpret=True)
        assert jnp.array_equal(out, ref), cfg


# --- controller backoff regression (PR 1 satellite) -------------------------

def test_controller_backoff_steps_down_not_reset():
    """Validation overshoot must cost one notch of saving on the worst
    layer, not drop it to exact: total_saving stays higher at the same
    budget than the reset-to-zero behavior."""
    from repro.core.controller import DynamicPowerController
    from repro.core.power_model import MAC_SAVING_FRAC

    d = {c: float(MAC_SAVING_FRAC[c]) / 100.0 for c in (8, 16, 31)}
    d[0] = 0.0
    extra = 0.0035   # superadditive interaction the probes can't see

    def loss_fn(assignment):
        loss = sum(d[c] for c in assignment.values())
        if sum(1 for c in assignment.values() if c > 0) >= 2:
            loss += extra
        return loss

    budget = 0.009
    ctrl = DynamicPowerController(["A", "B"], loss_fn,
                                  probe_configs=(8, 16, 31))
    assignment = ctrl.allocate(loss_budget=budget)
    # end-to-end degradation fits the budget...
    assert loss_fn(assignment) - ctrl.base_loss <= budget + 1e-12
    # ...and no layer was reset to exact (the old behavior zeroed one)
    assert assignment["A"] > 0 and assignment["B"] > 0, assignment
    reset_variant = dict(assignment)
    reset_variant["A"] = 0
    assert ctrl.total_saving(assignment) > ctrl.total_saving(reset_variant)
