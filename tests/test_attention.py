"""chunked_attention / decode_attention vs the reference oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import (chunked_attention, decode_attention,
                                ref_attention)

KEY = jax.random.PRNGKey(7)


def qkv(b, sq, skv, h, kv, hd, dtype=jnp.float32, case=0):
    ks = jax.random.split(jax.random.fold_in(KEY, case), 3)
    return (jax.random.normal(ks[0], (b, sq, h, hd), dtype),
            jax.random.normal(ks[1], (b, skv, kv, hd), dtype),
            jax.random.normal(ks[2], (b, skv, kv, hd), dtype))


@pytest.mark.parametrize("case,b,s,h,kv,hd,causal,window,cap,qc", [
    (1, 2, 64, 4, 4, 32, True, 0, 0.0, 16),
    (2, 2, 64, 4, 2, 32, True, 0, 0.0, 16),      # GQA
    (3, 1, 128, 4, 1, 32, True, 32, 0.0, 32),    # MQA + window
    (4, 2, 64, 2, 2, 32, True, 0, 50.0, 16),     # softcap
    (5, 2, 60, 2, 2, 32, True, 0, 0.0, 16),      # non-divisible S (padding)
    (6, 1, 64, 2, 2, 32, False, 0, 0.0, 64),     # non-causal single chunk
    (7, 1, 96, 2, 2, 32, True, 16, 30.0, 32),    # window + cap
])
def test_chunked_matches_ref(case, b, s, h, kv, hd, causal, window, cap, qc):
    q, k, v = qkv(b, s, s, h, kv, hd, case=case)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            logit_cap=cap, q_chunk=qc)
    ref = ref_attention(q, k, v, causal=causal, window=window, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_chunked_unroll_matches_map():
    q, k, v = qkv(2, 64, 64, 4, 4, 32, case=10)
    a = chunked_attention(q, k, v, q_chunk=16, unroll=False)
    b = chunked_attention(q, k, v, q_chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_cross_attention_kv_longer():
    q, k, v = qkv(2, 16, 48, 4, 4, 32, case=11)
    out = chunked_attention(q, k, v, causal=False, q_chunk=8)
    ref = ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_matches_ref_last_position():
    """decode at position P == full attention's row P."""
    b, s, h, kv, hd = 2, 32, 4, 2, 16
    q, k, v = qkv(b, s, s, h, kv, hd, case=12)
    full = ref_attention(q, k, v, causal=True)
    s_max = 48
    k_cache = jnp.zeros((b, s_max, kv, hd)).at[:, :s].set(k)
    v_cache = jnp.zeros((b, s_max, kv, hd)).at[:, :s].set(v)
    out = decode_attention(q[:, -1:], k_cache, v_cache, cache_len=s)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_decode_window_semantics():
    b, s, h, kv, hd, w = 1, 32, 2, 2, 16, 8
    q, k, v = qkv(b, s, s, h, kv, hd, case=13)
    full = ref_attention(q, k, v, causal=True, window=w)
    out = decode_attention(q[:, -1:], k, v, cache_len=s, window=w)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_mlstm_decay_matches_naive_recurrence():
    """Parallel decay-attention form == sequential mLSTM recurrence."""
    b, s, h, hd = 1, 24, 2, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    log_i = jax.random.normal(ks[3], (b, s, h)) * 0.5
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h)) + 2.0)
    log_fcum = jnp.cumsum(log_f, axis=1)
    out = chunked_attention(q, k, v, causal=True, q_chunk=8,
                            decay={"log_fcum": log_fcum, "log_i": log_i})
    # naive sequential recurrence (xLSTM eq. 19-27)
    ref = np.zeros((b, s, h, hd), np.float32)
    for bi in range(b):
        for hi in range(h):
            C = np.zeros((hd, hd))
            n = np.zeros(hd)
            m = -np.inf
            for t in range(s):
                lf = float(log_f[bi, t, hi])
                li = float(log_i[bi, t, hi])
                m_new = max(lf + m, li)
                fs, is_ = np.exp(lf + m - m_new), np.exp(li - m_new)
                kt = np.asarray(k[bi, t, hi], np.float64)
                vt = np.asarray(v[bi, t, hi], np.float64)
                qt = np.asarray(q[bi, t, hi], np.float64) / np.sqrt(hd)
                C = fs * C + is_ * np.outer(kt, vt)
                n = fs * n + is_ * kt
                m = m_new
                den = max(abs(float(n @ qt)), np.exp(-m))
                ref[bi, t, hi] = (C.T @ qt) / den
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)
