"""Optimizer, schedules, train-step builder (incl. microbatch equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import transformer as T
from repro.train.optimizer import (adamw, apply_updates, clip_by_global_norm,
                                   global_norm, sgd)
from repro.train.schedule import constant, linear_decay, warmup_cosine
from repro.train.step import build_train_step, init_state

KEY = jax.random.PRNGKey(0)


def test_adamw_converges_on_quadratic():
    opt = adamw(lr=0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_sgd_momentum_converges():
    opt = sgd(lr=0.05, momentum=0.9)
    params = {"w": jnp.asarray([4.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert abs(float(params["w"][0])) < 1e-2


def test_weight_decay_shrinks_params():
    opt = adamw(lr=0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([2.0])}
    state = opt.init(params)
    zero_grads = {"w": jnp.zeros(1)}
    for _ in range(20):
        updates, state = opt.update(zero_grads, state, params)
        params = apply_updates(params, updates)
    assert abs(float(params["w"][0])) < 2.0 * 0.5


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(constant(0.5)(7)) == 0.5
    l = linear_decay(1.0, 10, 110)
    assert float(l(110)) == pytest.approx(0.0, abs=1e-6)


def test_microbatch_equivalence():
    """nmb=1 and nmb=4 produce the same updated params (grad averaging)."""
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(KEY, cfg)
    opt = adamw(lr=1e-2)
    batch = {"tokens": jax.random.randint(KEY, (8, 16), 0, 64),
             "labels": jax.random.randint(KEY, (8, 16), 0, 64)}
    outs = []
    for nmb in (1, 4):
        step = build_train_step(cfg, opt, num_microbatches=nmb)
        state = init_state(params, opt)
        new_state, metrics = jax.jit(step)(state, batch)
        outs.append((new_state["params"], float(metrics["loss"])))
    p1, l1 = outs[0]
    p4, l4 = outs[1]
    assert l1 == pytest.approx(l4, rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_loss_decreases_over_steps():
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=32,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(KEY, cfg)
    opt = adamw(lr=3e-3)
    step = jax.jit(build_train_step(cfg, opt))
    state = init_state(params, opt)
    batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, 32),
             "labels": jax.random.randint(KEY, (4, 16), 0, 32)}
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)  # overfit one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]
