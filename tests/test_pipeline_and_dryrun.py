"""Prefetcher semantics + a true dry-run smoke (deliverable e) in a
512-virtual-device subprocess."""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.data.pipeline import Prefetcher

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_prefetcher_orders_and_overlaps():
    calls = []

    def source(step):
        calls.append(step)
        time.sleep(0.01)
        return {"x": np.full((2,), step)}

    pf = Prefetcher(source, depth=3)
    got = [pf.get() for _ in range(5)]
    pf.close()
    assert [s for s, _ in got] == list(range(5))
    assert all(int(b["x"][0]) == s for s, b in got)
    assert len(calls) >= 5            # produced at least what we consumed


def test_prefetcher_propagates_errors():
    def source(step):
        if step == 2:
            raise ValueError("boom")
        return {"x": np.zeros(1)}

    pf = Prefetcher(source, depth=1)
    pf.get(), pf.get()
    with pytest.raises(ValueError):
        pf.get()
        pf.get()
    pf.close()


def test_prefetcher_bounded_depth():
    produced = []

    def source(step):
        produced.append(step)
        return step

    pf = Prefetcher(source, depth=2)
    time.sleep(0.3)
    # bounded: at most depth+1 batches produced before any consumption
    assert len(produced) <= 4
    pf.close()


@pytest.mark.slow
def test_dryrun_smoke_subprocess():
    """Deliverable (e) in-suite: lower+compile one real cell on the
    production 16x16 mesh with 512 forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
import repro.launch.dryrun as dr          # sets XLA_FLAGS before jax init
import jax
assert len(jax.devices()) == 512
from repro.launch.mesh import make_production_mesh
mesh = make_production_mesh()
res = dr.lower_cell("xlstm-350m", "long_500k", mesh)
assert res["n_devices"] == 256
assert res["memory"]["peak_estimate_bytes"] < 4 * 2**30
assert res["corrected"]["flops_per_device"] > 0
print("dryrun smoke OK", res["memory"]["peak_estimate_bytes"] / 2**30)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "dryrun smoke OK" in r.stdout
