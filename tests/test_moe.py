"""MoE dispatch: capacity semantics + equivalence with the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.moe import moe_dense_oracle, moe_ffn

KEY = jax.random.PRNGKey(11)


def make_params(d, e, f, glu=True):
    ks = jax.random.split(KEY, 4)
    p = {"router": jax.random.normal(ks[0], (d, e)) * 0.5,
         "w_up": jax.random.normal(ks[1], (e, d, f)) / np.sqrt(d),
         "w_down": jax.random.normal(ks[2], (e, f, d)) / np.sqrt(f)}
    if glu:
        p["w_gate"] = jax.random.normal(ks[3], (e, d, f)) / np.sqrt(d)
    return p


@pytest.mark.parametrize("t,d,e,f,k,groups", [
    (32, 16, 4, 32, 2, 1),
    (64, 16, 8, 16, 2, 4),
    (64, 8, 4, 16, 1, 2),
])
def test_matches_dense_oracle_at_full_capacity(t, d, e, f, k, groups):
    """capacity_factor big enough -> no drops -> exact match with the
    every-token-through-every-expert oracle."""
    params = make_params(d, e, f)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (t, d))
    y_moe, _ = moe_ffn(x, params, n_experts=e, top_k=k,
                       capacity_factor=float(e), n_groups=groups)
    y_ref = moe_dense_oracle(x, params, n_experts=e, top_k=k)
    np.testing.assert_allclose(np.asarray(y_moe), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_no_renormalize_matches_oracle():
    params = make_params(16, 4, 16)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (32, 16))
    y_moe, _ = moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                       n_groups=1, renormalize=False)
    y_ref = moe_dense_oracle(x, params, n_experts=4, top_k=2,
                             renormalize=False)
    np.testing.assert_allclose(np.asarray(y_moe), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_reduce_output_norm():
    """With tiny capacity most tokens are dropped -> output is damped
    but finite (never NaN)."""
    params = make_params(16, 4, 16)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (64, 16))
    y_small, _ = moe_ffn(x, params, n_experts=4, top_k=2,
                         capacity_factor=0.1, n_groups=1)
    y_big, _ = moe_ffn(x, params, n_experts=4, top_k=2,
                       capacity_factor=4.0, n_groups=1)
    assert np.isfinite(np.asarray(y_small)).all()
    assert float(jnp.sum(y_small ** 2)) < float(jnp.sum(y_big ** 2))


def test_group_invariance_at_full_capacity():
    """Dispatch groups change the compute layout, not the math."""
    params = make_params(16, 4, 16)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (64, 16))
    outs = [moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                    n_groups=g)[0] for g in (1, 2, 4)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-4)


def test_approx_cfg_path_close_to_exact():
    """The approx-MAC knob on expert einsums: cfg 1 (mildest) stays close;
    error grows with config index."""
    params = make_params(16, 4, 32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (32, 16))
    y0, _ = moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                    n_groups=1, approx_cfg=0)
    errs = []
    for cfg in (1, 31):
        y, _ = moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                       n_groups=1, approx_cfg=cfg)
        errs.append(float(jnp.mean(jnp.abs(y - y0))) /
                    (float(jnp.mean(jnp.abs(y0))) + 1e-9))
    assert errs[0] < 0.15          # mild config: small relative error
    assert np.isfinite(errs[1])


def test_gradients_through_dispatch():
    params = make_params(16, 4, 16)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (32, 16))

    def loss(p):
        y, _ = moe_ffn(x, p, n_experts=4, top_k=2, capacity_factor=2.0,
                       n_groups=1)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(params)
    gn = np.sqrt(sum(float(jnp.sum(l ** 2)) for l in jax.tree.leaves(g)))
    assert np.isfinite(gn) and gn > 0


def test_seq_chunks_equivalence_at_full_capacity():
    """Sequential sub-chunk dispatch == single-shot at full capacity."""
    params = make_params(16, 4, 16)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (64, 16))
    y1, _ = moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                    n_groups=2, seq_chunks=1)
    y4, _ = moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                    n_groups=2, seq_chunks=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=2e-4, atol=2e-4)


def test_seq_chunks_unroll_matches_map():
    params = make_params(16, 4, 16)
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (64, 16))
    ym, _ = moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                    n_groups=2, seq_chunks=4, unroll_chunks=False)
    yu, _ = moe_ffn(x, params, n_experts=4, top_k=2, capacity_factor=4.0,
                    n_groups=2, seq_chunks=4, unroll_chunks=True)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yu),
                               rtol=1e-5, atol=1e-6)
