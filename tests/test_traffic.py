"""Traffic determinism regression (PR 10 satellite, DESIGN.md §13).

PR 7 promised the replayability contract — ``arrivals(tick)`` a pure
function of ``(classes, plan, seed)`` — but only spot-checked a few
ticks.  This module pins the whole contract: the FULL arrival trace is
identical run-to-run and under any access order, ``rate_at`` edges sit
exactly on the half-open spike boundaries (overlaps compounding), and
an end-to-end engine run over the same trace yields an identical
``slo_report`` and identical token streams.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import Engine
from repro.serve.traffic import (TrafficClass, TrafficGenerator,
                                 class_budget_shares, slo_report)

CLASSES = (TrafficClass("chat", ttft_slo_s=0.5, e2e_slo_s=2.0,
                        prompt_len=5, max_new_tokens=3),
           TrafficClass("batch", weight=0.5, prompt_len=8,
                        max_new_tokens=4))
PLAN = dict(rate_per_tick=0.8, spikes=((4, 9, 3.0), (6, 12, 2.0)))


def _small_model():
    from repro.nn import transformer as T
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return T, cfg, params


class FakeClock:
    """Deterministic injected time source: each read advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def _fingerprint(reqs):
    return [(r.rid, r.cls, r.max_new_tokens, r.prompt.tolist())
            for r in reqs]


def _trace(gen, ticks=20):
    return [_fingerprint(gen.arrivals(t)) for t in range(ticks)]


# --- the arrival trace is a pure function of (classes, plan, seed) ----------

def test_identical_inputs_give_identical_full_trace():
    g1 = TrafficGenerator(CLASSES, seed=7, **PLAN)
    g2 = TrafficGenerator(CLASSES, seed=7, **PLAN)
    assert _trace(g1) == _trace(g2)
    # and the trace is non-trivial: both classes appear, spikes land
    flat = [r for tick in _trace(g1) for r in tick]
    assert {cls for _, cls, _, _ in flat} == {"chat", "batch"}
    # a different seed (same classes/plan) changes the trace
    assert _trace(TrafficGenerator(CLASSES, seed=8, **PLAN)) != _trace(g1)
    # a different plan (same seed) changes the trace
    alt = TrafficGenerator(CLASSES, seed=7, rate_per_tick=0.8,
                           spikes=((4, 9, 5.0),))
    assert _trace(alt) != _trace(g1)


def test_trace_is_identical_under_any_access_order():
    """Tick replay is random-access: querying the trace forward,
    backward, or one tick in isolation gives byte-identical
    arrivals."""
    fwd = _trace(TrafficGenerator(CLASSES, seed=7, **PLAN))
    g = TrafficGenerator(CLASSES, seed=7, **PLAN)
    order = list(range(20))[::-1] + [11, 3, 11]       # revisits too
    for t in order:
        assert _fingerprint(g.arrivals(t)) == fwd[t], t


def test_rids_are_globally_unique_and_self_describing():
    g = TrafficGenerator(CLASSES, seed=7, **PLAN)
    rids = [r.rid for tick in range(20) for r in g.arrivals(tick)]
    assert len(rids) == len(set(rids))
    for t in range(20):
        for i, r in enumerate(g.arrivals(t)):
            assert r.rid == (t << 16) | i


# --- rate_at edges ----------------------------------------------------------

def test_rate_at_spike_boundaries_are_half_open_and_compound():
    g = TrafficGenerator(CLASSES, seed=0, **PLAN)
    base = PLAN["rate_per_tick"]
    # [4, 9) x3 and [6, 12) x2, overlapping on [6, 9)
    assert g.rate_at(3) == base                       # before either
    assert g.rate_at(4) == base * 3.0                 # start inclusive
    assert g.rate_at(5) == base * 3.0
    assert g.rate_at(6) == base * 3.0 * 2.0           # overlap compounds
    assert g.rate_at(8) == base * 3.0 * 2.0           # last overlap tick
    assert g.rate_at(9) == base * 2.0                 # first end EXCLUSIVE
    assert g.rate_at(11) == base * 2.0
    assert g.rate_at(12) == base                      # second end exclusive
    # a zero-length window [5, 5) never applies
    g0 = TrafficGenerator(CLASSES, seed=0, rate_per_tick=1.0,
                          spikes=((5, 5, 9.0),))
    assert g0.rate_at(5) == 1.0


# --- end-to-end: same trace, same slo_report --------------------------------

def _serve(seed=7, ticks=14):
    T, cfg, params = _small_model()
    gen = TrafficGenerator(CLASSES, seed=seed, vocab_size=cfg.vocab_size,
                           **PLAN)
    eng = Engine(params, cfg, max_batch=2, max_len=32, queue_capacity=8,
                 clock=FakeClock())
    offered = []
    for t in range(ticks):
        for r in gen.arrivals(t):
            offered.append(r)
            eng.submit(r)
        eng.step()
    eng.run(max_ticks=100)                 # drain
    return offered, eng


def test_identical_runs_give_identical_slo_report_and_streams():
    offered1, eng1 = _serve()
    offered2, eng2 = _serve()
    rep1, rep2 = slo_report(offered1), slo_report(offered2)
    assert rep1 == rep2                    # full scorecard, both levels
    assert rep1["total"]["offered"] == len(offered1) > 0
    assert sorted((r.rid, tuple(r.tokens)) for r in eng1.completed) \
        == sorted((r.rid, tuple(r.tokens)) for r in eng2.completed)
    # per-class energy attribution is reproducible too (DESIGN.md §13)
    assert eng1.serve_tokens_by_class == eng2.serve_tokens_by_class
    assert eng1.serve_energy_by_class == eng2.serve_energy_by_class


# --- budget-share plumbing --------------------------------------------------

def test_class_budget_shares_helper():
    quiet = (TrafficClass("a"), TrafficClass("b", weight=2.0))
    assert class_budget_shares(quiet) == {}            # nobody opted in
    mixed = (TrafficClass("a", budget_share=0.7),
             TrafficClass("b", weight=2.0))            # falls back to weight
    assert class_budget_shares(mixed) == {"a": 0.7, "b": 2.0}
    full = (TrafficClass("a", budget_share=0.25),
            TrafficClass("b", budget_share=0.75))
    assert class_budget_shares(full) == {"a": 0.25, "b": 0.75}
