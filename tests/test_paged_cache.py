"""Property tests for the paged-KV page allocator (DESIGN.md §11).

The allocator is the ownership ledger of the paged serving pool; these
properties (via hypothesis or the deterministic
tests/_hypothesis_compat.py shim) are the invariants the engine's
correctness rests on:

* alloc / free / fork sequences never double-free, and every reserved
  block keeps refcount 1 forever;
* refcounts equal live block-table references exactly, at every step of
  a random operation trace (the prefix index holds no refcount);
* a prefix fork followed by the first divergent write copies exactly
  one block (copy-on-write), and an unshared block is written in place;
* allocator state round-trips through ``checkpoint.Checkpointer``
  snapshot/restore bit-exactly, prefix index included.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.serve.paged_cache import (N_RESERVED, PageAllocator,
                                     PagedCacheConfig, TRASH_BLOCK,
                                     ZERO_BLOCK)

N_EXAMPLES = 60


def _cfg(num_blocks=18, block_size=4, share=True):
    return PagedCacheConfig(num_blocks=num_blocks, block_size=block_size,
                            prefill_chunk=block_size * 2,
                            share_prefixes=share)


def _random_trace(alloc: PageAllocator, rng: np.random.Generator,
                  n_ops: int):
    """Drive a random alloc/free/fork/register/cow trace, mirroring the
    engine's ownership bookkeeping in `tables` (list of owned-block
    lists).  Consistency is asserted after EVERY op."""
    tables: list[list[int]] = []
    next_token = [0]

    def new_prompt(n):
        out = list(range(next_token[0], next_token[0] + n))
        next_token[0] += n
        return out

    prompts: list[list[int]] = []
    for _ in range(n_ops):
        op = rng.integers(0, 5)
        if op == 0 and alloc.can_alloc(2):            # admit 2 blocks
            blocks = alloc.alloc_n(2)
            tables.append(blocks)
            prompt = new_prompt(2 * alloc.cfg.block_size)
            prompts.append(prompt)
            bs = alloc.cfg.block_size
            for i, blk in enumerate(blocks):
                alloc.register_prefix(tuple(prompt[:(i + 1) * bs]), blk)
        elif op == 1 and tables:                      # release a table
            i = int(rng.integers(len(tables)))
            alloc.release(tables.pop(i))
            prompts.pop(i)
        elif op == 2 and tables:                      # fork (share) one
            i = int(rng.integers(len(tables)))
            tables.append(alloc.fork(tables[i]))
            prompts.append(list(prompts[i]))
        elif op == 3 and tables and alloc.can_alloc(1):   # grow one
            i = int(rng.integers(len(tables)))
            tables[i].append(alloc.alloc())
        elif op == 4 and tables and alloc.can_alloc(1):   # COW write
            i = int(rng.integers(len(tables)))
            j = int(rng.integers(len(tables[i])))
            blk, _copied = alloc.ensure_writable(tables[i][j])
            tables[i][j] = blk
        alloc.check_consistency(tables)
        assert alloc.refcounts[ZERO_BLOCK] == 1
        assert alloc.refcounts[TRASH_BLOCK] == 1
    return tables


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       num_blocks=st.sampled_from([6, 10, 18, 34]),
       share=st.booleans())
def test_random_traces_never_double_free(seed, num_blocks, share):
    alloc = PageAllocator(_cfg(num_blocks=num_blocks, share=share))
    rng = np.random.default_rng(seed)
    tables = _random_trace(alloc, rng, n_ops=40)
    for t in tables:
        alloc.release(t)
    alloc.check_consistency([])
    assert alloc.free_blocks() == alloc.cfg.usable_blocks


def test_decref_below_zero_is_double_free():
    alloc = PageAllocator(_cfg())
    blk = alloc.alloc()
    alloc.decref(blk)
    with pytest.raises(AssertionError, match="double free"):
        alloc.decref(blk)


def test_reserved_blocks_never_allocated():
    alloc = PageAllocator(_cfg(num_blocks=4))
    got = {alloc.alloc(), alloc.alloc()}
    assert got == {N_RESERVED, N_RESERVED + 1}
    with pytest.raises(MemoryError):
        alloc.alloc()


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_fork_then_divergent_write_copies_exactly_one_block(seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(_cfg())
    owner = alloc.alloc_n(3)
    shared = alloc.fork(owner)
    before = alloc.free_blocks()
    j = int(rng.integers(3))
    blk, copied = alloc.ensure_writable(shared[j])
    shared[j] = blk
    assert copied and blk != owner[j]
    assert alloc.free_blocks() == before - 1     # exactly one new block
    alloc.check_consistency([owner, shared])
    # the copied block is now exclusively owned: the second write on it
    # must NOT copy again
    blk2, copied2 = alloc.ensure_writable(shared[j])
    assert blk2 == blk and not copied2
    alloc.release(owner)
    alloc.release(shared)
    alloc.check_consistency([])


def test_unshared_block_writes_in_place():
    alloc = PageAllocator(_cfg())
    blk = alloc.alloc()
    got, copied = alloc.ensure_writable(blk)
    assert got == blk and not copied


def test_match_prefix_stops_one_token_short():
    """The last prompt token is always prefilled locally (its logits
    seed the first sample), so an exact-multiple prompt shares one
    block less than its full length."""
    cfg = _cfg(block_size=4)
    alloc = PageAllocator(cfg)
    prompt = list(range(8))
    blocks = alloc.alloc_n(2)
    for i, blk in enumerate(blocks):
        alloc.register_prefix(tuple(prompt[:(i + 1) * 4]), blk)
    assert alloc.match_prefix(prompt) == blocks[:1]
    assert alloc.match_prefix(prompt + [99]) == blocks
    assert alloc.match_prefix([7, 6, 5, 4, 3]) == []


def test_dying_block_leaves_the_prefix_index():
    cfg = _cfg(block_size=4)
    alloc = PageAllocator(cfg)
    prompt = list(range(8))
    blk = alloc.alloc()
    alloc.register_prefix(tuple(prompt[:4]), blk)
    assert alloc.match_prefix(prompt) == [blk]
    alloc.decref(blk)
    assert alloc.match_prefix(prompt) == []
    # the id can be recycled for an unrelated request without ghosts
    assert alloc.alloc() == blk
    assert alloc.match_prefix(prompt) == []


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_state_roundtrips_through_checkpointer(seed, tmp_path):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(_cfg())
    tables = _random_trace(alloc, rng, n_ops=25)
    state = alloc.state_dict()

    ckpt = Checkpointer(str(tmp_path / f"ck{seed}"))
    ckpt.save(0, {"refcounts": state["refcounts"]},
              metadata={"prefix_index": state["prefix_index"]})
    tree, meta = ckpt.restore({"refcounts": np.zeros_like(state["refcounts"])})

    fresh = PageAllocator(_cfg())
    fresh.load_state_dict({"refcounts": tree["refcounts"],
                           "prefix_index": meta["prefix_index"]})
    assert np.array_equal(fresh.refcounts, alloc.refcounts)
    assert fresh._prefix_index == alloc._prefix_index
    assert {k: sorted(v) for k, v in fresh._block_keys.items()} \
        == {k: sorted(v) for k, v in alloc._block_keys.items()}
    fresh.check_consistency(tables)
