"""Approx-draft self-speculative decoding (PR 9 tentpole, DESIGN.md §12).

Contracts:

* **Stream identity** — a speculative engine emits token streams
  IDENTICAL to its non-speculative twin's exact greedy streams: every
  emitted token is the VERIFIER's own argmax (the drafts only decide
  how many verifier tokens commit per tick), on dense and paged paths,
  across seeds, draft depths and draft configs.  The model is briefly
  trained first — a random-init model has near-uniform logits, so every
  argmax is a near-tie that flips under the int8 datapath's per-tensor
  dynamic activation scale (batch/width composition perturbs the last
  grid bit); training restores the margins the token-stream bars rely
  on (same reasoning as benchmarks/paged_serving.py).
* **Zero retraces** — the whole (k, draft-cfg) sweep, including live
  ``set_spec`` retargets, runs through ONE decode executable plus ONE
  verify executable (dense) / the ONE existing prefill-chunk executable
  (paged): k is a host loop count, the draft config is traced data.
* **Speculation pays** — tokens-per-verify-step > 1 and serve-energy
  per emitted token below the non-speculative exact baseline at the
  measured acceptance rate.
* **Rewind invariants** — paged spec ticks allocate ahead and trim back
  to the acceptance point: the allocator stays consistent and drains to
  a fully-free pool; aborted ticks (injected faults) roll back and the
  stream still completes identically.
* **Satellite regressions** — dup_probe chaos runs the probe decode
  exactly once (only the telemetry is duplicated); finish→readmit into
  the same paged slot is bit-identical to a fresh engine; two
  mid-prefill slots that exhaust the pool no longer deadlock; requests
  that can never fit are rejected at admission instead of livelocking;
  ``record_spec`` feeds the DRAFT config's estimates without ever
  backing off the pool ladder, and draft-k follows the same one-notch
  hysteresis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.engine import Engine, Request
from repro.serve.faults import FaultEvent, FaultInjector
from repro.serve.paged_cache import PagedCacheConfig
from repro.serve.scheduler import PowerBudgetScheduler
from repro.serve.speculative import (SpecConfig, longest_agreeing_prefix)


def _demo_cfg():
    from repro.nn import transformer as T
    return T.ModelConfig(name="demo", n_layers=2, d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=64,
                         vocab_size=64, scan_layers=False, remat=False,
                         q_chunk=8, loss_chunks=1,
                         compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def model():
    """Briefly-trained demo LM (see module docstring for why trained)."""
    from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
    from repro.nn import transformer as T
    from repro.train import optimizer as opt_mod
    from repro.train.step import build_train_step, init_state
    cfg = _demo_cfg()
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(SyntheticLMConfig(vocab_size=64, seq_len=48,
                                         global_batch=16, n_templates=4,
                                         seed=0))
    train = jax.jit(build_train_step(cfg, opt_mod.adamw(lr=4e-3)))
    state = init_state(params, opt_mod.adamw(lr=4e-3))
    for i in range(300):
        b = data.batch(i)
        state, _ = train(state,
                         {k: jnp.asarray(v) for k, v in b.items()})
    return jax.tree.map(np.asarray, state["params"]), cfg


def _reqs(seed, n=4, plen=16, new=12, base=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=base + i, prompt=rng.integers(1, 64, size=plen),
                    max_new_tokens=new, **kw) for i in range(n)]


def _drain(eng, reqs, max_ticks=2000):
    for r in reqs:
        assert eng.submit(r)
    done = eng.run(max_ticks=max_ticks)
    assert all(r.status == "done" for r in done), \
        [(r.rid, r.status) for r in done]
    return {r.rid: list(r.tokens) for r in done}


def _paged(num_blocks, block_size=16, chunk=16):
    return PagedCacheConfig(num_blocks=num_blocks, block_size=block_size,
                            prefill_chunk=chunk)


# --- stream identity + zero retraces ---------------------------------------

def test_dense_spec_identical_to_exact_greedy_across_sweep(model):
    params, cfg = model
    ref_eng = Engine(params, cfg, max_batch=4, max_len=64)
    spec_eng = Engine(params, cfg, max_batch=4, max_len=64,
                      spec=SpecConfig(draft_cfg=8, k=3, max_k=5))
    for seed, k, dcfg in ((0, 3, 8), (1, 5, 8), (2, 2, 20), (3, 4, 31)):
        spec_eng.set_spec(SpecConfig(draft_cfg=dcfg, k=k, max_k=5))
        base = 100 * seed
        assert _drain(ref_eng, _reqs(seed, base=base)) \
            == _drain(spec_eng, _reqs(seed, base=base)), (seed, k, dcfg)
    assert spec_eng.n_spec_ticks > 0 and spec_eng.n_spec_emitted > 0
    # ONE decode + ONE verify executable across the whole sweep
    assert spec_eng._decode._cache_size() == 1
    assert spec_eng._verify._cache_size() == 1
    assert spec_eng._prefill._cache_size() == 1


def test_paged_spec_identical_rewinds_and_drains(model):
    params, cfg = model
    ref_eng = Engine(params, cfg, max_batch=4, max_len=64,
                     paged=_paged(40))
    spec_eng = Engine(params, cfg, max_batch=4, max_len=64,
                      paged=_paged(40),
                      spec=SpecConfig(draft_cfg=8, k=3, max_k=5))
    for seed, k, dcfg in ((0, 3, 8), (1, 5, 20)):
        spec_eng.set_spec(SpecConfig(draft_cfg=dcfg, k=k, max_k=5))
        base = 100 * seed
        assert _drain(ref_eng, _reqs(seed, base=base)) \
            == _drain(spec_eng, _reqs(seed, base=base)), (seed, k, dcfg)
    assert spec_eng.n_spec_ticks > 0
    # the verify rides the ONE existing prefill-chunk executable; the
    # rewind trims back to a consistent, fully-drained pool
    assert spec_eng._prefill_chunk._cache_size() == 1
    assert spec_eng._decode._cache_size() == 1
    spec_eng.allocator.check_consistency(spec_eng._slot_blocks)
    assert spec_eng.allocator.free_blocks() == 40 - 2


def test_spec_skips_non_greedy_and_window_overflow(model):
    params, cfg = model
    # a sampling slot in the pool disables speculation for the tick
    eng = Engine(params, cfg, max_batch=2, max_len=64,
                 spec=SpecConfig(draft_cfg=8, k=3, max_k=3))
    reqs = _reqs(0, n=2, new=6)
    reqs[1].temperature = 0.7
    _drain(eng, reqs)
    assert eng.n_spec_ticks == 0
    # near the cache end the window cannot fit: the engine falls back
    # to plain ticks and still finishes (boundary-stop at max_len - 1)
    eng2 = Engine(params, cfg, max_batch=1, max_len=32,
                  spec=SpecConfig(draft_cfg=8, k=3, max_k=3))
    out = _drain(eng2, _reqs(1, n=1, plen=24, new=16))
    assert len(out[0]) < 16          # clipped by the cache boundary
    ref = Engine(params, cfg, max_batch=1, max_len=32)
    assert out == _drain(ref, _reqs(1, n=1, plen=24, new=16))


# --- speculation pays -------------------------------------------------------

def test_spec_throughput_and_energy_beat_exact_baseline(model):
    params, cfg = model
    base = Engine(params, cfg, max_batch=4, max_len=64)
    ref = _drain(base, _reqs(0, new=16))
    spec = Engine(params, cfg, max_batch=4, max_len=64,
                  spec=SpecConfig(draft_cfg=8, k=3, max_k=3))
    got = _drain(spec, _reqs(0, new=16))
    assert ref == got
    # >1 emitted token per exact verify pass (the speedup claim) ...
    assert spec.n_verify_steps > 0
    assert spec.n_spec_emitted / spec.n_verify_steps > 1.0
    # ... at LOWER serve energy per emitted token than the exact
    # baseline: drafts bill at the cheap draft config, the verify is
    # one exact weight-pass per slot covering up to k+1 tokens
    pj_base = (base.serve_mac_energy_pj_per_param
               / base.n_tokens_emitted)
    pj_spec = (spec.serve_mac_energy_pj_per_param
               / spec.n_tokens_emitted)
    assert pj_spec < pj_base


# --- fault handling: aborts roll back, stream unchanged ---------------------

def test_spec_abort_rolls_back_and_stream_is_unchanged(model):
    params, cfg = model

    class FakeClock:
        t = 0.0

        def __call__(self):
            FakeClock.t += 1e-3
            return FakeClock.t

    for paged in (None, _paged(40)):
        clean = Engine(params, cfg, max_batch=2, max_len=64, paged=paged,
                       spec=SpecConfig(draft_cfg=8, k=3, max_k=3))
        ref = _drain(clean, _reqs(0, n=2, new=24))
        inj = FaultInjector([FaultEvent(tick=2, kind="step_fail"),
                             FaultEvent(tick=3, kind="step_fail")])
        eng = Engine(params, cfg, max_batch=2, max_len=64, paged=paged,
                     spec=SpecConfig(draft_cfg=8, k=3, max_k=3),
                     fault_injector=inj, clock=FakeClock())
        got = _drain(eng, _reqs(0, n=2, new=24))
        assert got == ref, "abort rollback must not change the stream"
        assert eng.n_spec_aborts >= 1
        if paged is not None:
            eng.allocator.check_consistency(eng._slot_blocks)
            assert eng.allocator.free_blocks() == 40 - 2


def test_longest_agreeing_prefix():
    assert longest_agreeing_prefix([1, 2, 3], [1, 2, 3]) == 3
    assert longest_agreeing_prefix([1, 2, 3], [1, 9, 3]) == 1
    assert longest_agreeing_prefix([7], [3]) == 0
    assert longest_agreeing_prefix([], []) == 0


# --- satellite: dup_probe duplicates telemetry, not compute -----------------

def test_dup_probe_runs_probe_decode_exactly_once():
    from repro.nn import transformer as T
    cfg = _demo_cfg()
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    sched = PowerBudgetScheduler(10.0, probe_every=1, retune_every=10**9)
    inj = FaultInjector([FaultEvent(tick=2, kind="dup_probe")])
    eng = Engine(params, cfg, max_batch=1, approx_cfg=1, scheduler=sched,
                 fault_injector=inj)
    eng.submit(Request(rid=0, prompt=np.arange(5) % 64,
                       max_new_tokens=6))
    calls = []
    inner = eng._decode

    def counting(*a, **kw):
        calls.append(1)
        return inner(*a, **kw)

    eng._decode = counting
    while eng.step():
        pass
    probed_ticks = sched.n_probes - 1   # one tick recorded twice
    # every probed tick = 1 serve decode + EXACTLY 1 probe decode; the
    # pre-fix engine looped the whole on_step hook per delivered
    # feedback, re-running the shadow decode on the dup_probe tick
    assert len(calls) == 2 * probed_ticks
    assert sched.n_probes == probed_ticks + 1


# --- satellite: paged slot recycling / starvation / admission ---------------

def test_finish_then_readmit_same_slot_bit_identical(model):
    params, cfg = model

    def fresh(req_seed, **kw):
        eng = Engine(params, cfg, max_batch=1, max_len=64,
                     paged=_paged(12, block_size=8, chunk=8))
        return _drain(eng, _reqs(req_seed, n=1, **kw))

    eng = Engine(params, cfg, max_batch=1, max_len=64,
                 paged=_paged(12, block_size=8, chunk=8))
    # request A finishes (including via the max_len boundary), then B
    # is admitted into the SAME slot: B must match a fresh engine's B
    a = _drain(eng, _reqs(0, n=1, plen=16, new=8))
    assert a == fresh(0, plen=16, new=8)
    b = _drain(eng, _reqs(1, n=1, base=0, plen=40, new=64))  # boundary
    assert b == fresh(1, plen=40, new=64)
    c = _drain(eng, _reqs(2, n=1, base=0, plen=16, new=8))
    assert c == fresh(2, plen=16, new=8)
    eng.allocator.check_consistency(eng._slot_blocks)
    assert eng.allocator.free_blocks() == 12 - 2


def test_two_starved_prefills_no_longer_deadlock(model):
    """Pre-fix: two mid-prefill slots that exhausted the pool waited on
    each other forever — only the DECODE path could preempt, and no
    decode tick ever ran.  The starved-pool escape preempts the
    youngest mid-prefill request by recompute instead."""
    params, cfg = model
    eng = Engine(params, cfg, max_batch=2, max_len=64,
                 paged=_paged(8, block_size=4, chunk=4))
    out = _drain(eng, _reqs(0, n=2, plen=20, new=4), max_ticks=400)
    assert all(len(t) == 4 for t in out.values())
    assert eng.n_preempted >= 1
    eng.allocator.check_consistency(eng._slot_blocks)
    assert eng.allocator.free_blocks() == 8 - 2


def test_unfittable_request_rejected_not_livelocked(model):
    """Pre-fix: a request whose peak length can never fit the pool was
    admitted anyway and preempt-thrashed forever.  Admission must
    reject it up front."""
    params, cfg = model
    eng = Engine(params, cfg, max_batch=1, max_len=64,
                 paged=_paged(6, block_size=4, chunk=4))
    # peak = prompt + max_new - 1 = 35 entries = 9 blocks > 4 usable
    bad = Request(rid=99, prompt=np.arange(20) % 64, max_new_tokens=16)
    assert eng.submit(bad)                 # queued; rejected at admission
    eng.step()
    assert bad.status == "rejected" and eng.n_rejected == 1
    # a fitting request still sails through
    good = _reqs(0, n=1, plen=8, new=4)[0]
    assert eng.submit(good)
    eng.run(max_ticks=200)
    assert good.status == "done" and len(good.tokens) == 4


# --- satellite: acceptance statistics flow through the scheduler ------------

def test_record_spec_attributes_draft_config_without_pool_backoff():
    sched = PowerBudgetScheduler(10.0, hysteresis=2, hold_ticks=6,
                                 retune_every=2)
    sched.bind((2,), initial=np.asarray([8, 8], np.int32))
    sched.configure_spec(4)
    draft_vec = np.asarray([20, 20], np.int32)
    n0 = sched.n_probes
    sched.record_spec(2, 4, draft_vec)      # 2 accepted + 1 rejection
    assert sched.n_probes == n0 + 3
    # feedback lands on the executed DRAFT config's cells ...
    assert ((0,), 20) in sched.est and ((1,), 20) in sched.est
    # ... and NEVER on the pool ladder: hysteresis-many zero-acceptance
    # ticks must not back off the pool assignment (plain record_probe
    # disagreements at this count would)
    for _ in range(4):
        sched.record_spec(0, 4, draft_vec)
    assert sched.assignment == {(0,): 8, (1,): 8}
    assert not any(h["event"] == "backoff" for h in sched.history)


def test_draft_k_one_notch_hysteresis_and_recovery():
    class StubEngine:                      # just what on_tick reads
        mac_energy_pj_per_param = 0.0
        n_tokens_charged = 0
        clock = staticmethod(lambda: 0.0)

        def set_approx_cfg(self, v):
            pass

    sched = PowerBudgetScheduler(10.0, hysteresis=2, hold_ticks=6,
                                 retune_every=2)
    sched.bind((2,))
    sched.configure_spec(3)
    assert sched.draft_k == 3
    draft_vec = np.asarray([8, 8], np.int32)
    # one-notch backoff per hysteresis-long zero-acceptance burst
    sched.record_spec(0, 3, draft_vec)
    assert sched.draft_k == 3              # streak 1 < hysteresis
    sched.record_spec(0, 3, draft_vec)
    assert sched.draft_k == 2              # exactly ONE notch
    assert any(h["event"] == "spec_backoff" for h in sched.history)
    # an accepting tick resets the streak
    sched.record_spec(1, 3, draft_vec)
    sched.record_spec(0, 3, draft_vec)
    assert sched.draft_k == 2
    # floor at 1
    for _ in range(10):
        sched.record_spec(0, 3, draft_vec)
    assert sched.draft_k == 1
    # recovery: held until _k_hold_until, then one notch per retune
    eng = StubEngine()
    held = sched.draft_k
    while sched.tick < sched._k_hold_until:
        sched.on_tick(eng)
        assert sched.draft_k <= held + 1
    for _ in range(3 * sched.retune_every):
        sched.on_tick(eng)
    assert sched.draft_k == 3
    assert sched.report()["draft_k"] == 3


def test_engine_feeds_record_spec_and_scheduler_caps_k(model):
    params, cfg = model
    sched = PowerBudgetScheduler(10.0, probe_every=10**9,
                                 retune_every=10**9)
    eng = Engine(params, cfg, max_batch=2, max_len=64, scheduler=sched,
                 spec=SpecConfig(draft_cfg=8, k=3, max_k=5))
    assert sched.draft_k == 3
    _drain(eng, _reqs(0, n=2))
    assert eng.n_spec_ticks > 0
    assert sched.n_probes > 0              # acceptance flowed through
    assert any((k, 8) in sched.est for k in sched.keys)
    # the engine's live depth follows the scheduler's axis, capped
    sched.draft_k = 1
    assert eng._spec_k() == 1
    sched.draft_k = 99
    assert eng._spec_k() == 5              # max_k cap
