"""Recurrent blocks: parallel forms vs sequential step semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.recurrent import (mlstm_block_init, mlstm_final_state,
                                mlstm_parallel, mlstm_step, recurrent_block,
                                recurrent_block_init, rg_lru_init, rg_lru_scan,
                                rg_lru_step, slstm_block_init, slstm_scan,
                                slstm_step)

KEY = jax.random.PRNGKey(3)


def test_rg_lru_scan_matches_steps():
    w, b, s = 16, 2, 12
    params = rg_lru_init(KEY, w)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, w))
    y_scan, h_last = rg_lru_scan(params, x)
    h = jnp.zeros((b, w))
    ys = []
    for t in range(s):
        y_t, h = rg_lru_step(params, x[:, t], h)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-5, atol=1e-5)


def test_rg_lru_h0_continuation():
    """scan(x, h0=state) == scan over the concatenated sequence."""
    w, b = 8, 1
    params = rg_lru_init(KEY, w)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (b, 10, w))
    y_full, _ = rg_lru_scan(params, x)
    _, h_mid = rg_lru_scan(params, x[:, :5])
    y_cont, _ = rg_lru_scan(params, x[:, 5:], h0=h_mid)
    np.testing.assert_allclose(np.asarray(y_full[:, 5:]),
                               np.asarray(y_cont), rtol=1e-5, atol=1e-5)


def test_recurrent_block_prefill_then_decode():
    d, w, b, s = 16, 16, 2, 8
    params = recurrent_block_init(KEY, d, w)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s + 1, d))
    y_full, _ = recurrent_block(params, x)
    _, state = recurrent_block(params, x[:, :s])
    y_step, _ = recurrent_block(params, x[:, s:s + 1], state=state,
                                decode=True)
    np.testing.assert_allclose(np.asarray(y_full[:, s]),
                               np.asarray(y_step[:, 0]),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_parallel_then_step_continuation():
    d, nh, b, s = 16, 2, 1, 10
    params = mlstm_block_init(KEY, d, nh)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s + 1, d)) * 0.5
    y_full = mlstm_parallel(params, x, nh, q_chunk=4)
    state = mlstm_final_state(params, x[:, :s], nh)
    y_step, _ = mlstm_step(params, x[:, s:s + 1], state, nh)
    np.testing.assert_allclose(np.asarray(y_full[:, s]),
                               np.asarray(y_step[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_step_chain_matches_parallel():
    d, nh, b, s = 8, 1, 1, 6
    params = mlstm_block_init(KEY, d, nh)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (b, s, d)) * 0.5
    y_par = mlstm_parallel(params, x, nh, q_chunk=s)
    d_inner = int(d * 2.0)
    hd = d_inner // nh
    state = {"C": jnp.zeros((b, nh, hd, hd)), "n": jnp.zeros((b, nh, hd)),
             "m": jnp.full((b, nh), -30.0)}
    for t in range(s):
        y_t, state = mlstm_step(params, x[:, t:t + 1], state, nh)
        np.testing.assert_allclose(np.asarray(y_par[:, t]),
                                   np.asarray(y_t[:, 0]),
                                   rtol=2e-3, atol=2e-3)


def test_slstm_scan_continuation():
    d, nh, b, s = 16, 2, 2, 9
    params = slstm_block_init(KEY, d, nh)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (b, s + 1, d))
    y_full, _ = slstm_scan(params, x, nh)
    _, state = slstm_scan(params, x[:, :s], nh)
    y_step, _ = slstm_step(params, x[:, s:s + 1], state, nh)
    np.testing.assert_allclose(np.asarray(y_full[:, s]),
                               np.asarray(y_step[:, 0]),
                               rtol=1e-4, atol=1e-4)


def test_gradients_flow():
    d, nh = 8, 2
    for init, apply in [
        (lambda k: mlstm_block_init(k, d, nh),
         lambda p, x: mlstm_parallel(p, x, nh, q_chunk=4)),
        (lambda k: slstm_block_init(k, d, nh),
         lambda p, x: slstm_scan(p, x, nh)[0]),
    ]:
        params = init(KEY)
        x = jax.random.normal(jax.random.fold_in(KEY, 7), (1, 8, d))
        g = jax.grad(lambda p: jnp.sum(apply(p, x) ** 2))(params)
        gn = np.sqrt(sum(float(jnp.sum(l ** 2)) for l in jax.tree.leaves(g)))
        assert np.isfinite(gn) and gn > 0
