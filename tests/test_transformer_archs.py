"""Per-architecture smoke tests (reduced same-family configs) + the
decode-vs-forward consistency contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, cells_for, get_config
from repro.nn import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def make_batch(cfg, s=S):
    ks = jax.random.split(KEY, 3)
    tokens = jax.random.randint(ks[0], (B, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.vision_prefix_len:
        batch["vision_embeds"] = jax.random.normal(
            ks[1], (B, cfg.vision_prefix_len, cfg.d_model))
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(ks[2], (B, 16, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_and_grads(arch):
    cfg = get_config(arch).smoke()
    params, specs = T.init_lm(KEY, cfg)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: T.lm_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = np.sqrt(sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                        for g in jax.tree.leaves(grads)))
    assert np.isfinite(gnorm) and gnorm > 0
    # spec tree mirrors the param tree
    pt = jax.tree.structure(params)
    st = jax.tree.structure(specs, is_leaf=lambda t: isinstance(t, tuple))
    assert pt == st


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_dtype(arch):
    cfg = get_config(arch).smoke()
    params, _ = T.init_lm(KEY, cfg)
    batch = make_batch(cfg)
    hid = T.forward(params, cfg, batch["tokens"],
                    vision_embeds=batch.get("vision_embeds"),
                    enc_embeds=batch.get("enc_embeds"))
    s_total = S + (cfg.vision_prefix_len or 0)
    assert hid.shape == (B, s_total, cfg.d_model)
    logits = T.logits_for(params, cfg, hid)
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward(arch):
    cfg = get_config(arch).smoke()
    params, _ = T.init_lm(KEY, cfg)
    batch = make_batch(cfg)
    logits_pf, cache = T.prefill(params, cfg, batch["tokens"],
                                 vision_embeds=batch.get("vision_embeds"),
                                 enc_embeds=batch.get("enc_embeds"),
                                 max_len=S + (cfg.vision_prefix_len or 0) + 8)
    hid = T.forward(params, cfg, batch["tokens"],
                    vision_embeds=batch.get("vision_embeds"),
                    enc_embeds=batch.get("enc_embeds"))
    logits_fw = T.logits_for(params, cfg, hid[:, -1])
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(logits_fw),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_continuation_matches_forward(arch):
    """prefill(s) + greedy decode of n tokens == teacher-forced forward
    over the same extended sequence — the serving-correctness contract."""
    # exact caches for the contract (int8 KV quantization is lossy by
    # design and covered by test_kv_quant_cache_close_to_exact); MoE runs
    # dropless so teacher-forced forward == decode exactly (the dropped-
    # capacity training dispatch differs by design on dropped tokens,
    # covered by test_moe.py::test_capacity_drops_reduce_output_norm)
    cfg = get_config(arch).smoke(kv_quant=False, capacity_factor=99.0)
    params, _ = T.init_lm(KEY, cfg)
    batch = make_batch(cfg)
    tokens = batch["tokens"]
    n_extra = 4
    logits, cache = T.prefill(params, cfg, tokens,
                              vision_embeds=batch.get("vision_embeds"),
                              enc_embeds=batch.get("enc_embeds"),
                              max_len=S + (cfg.vision_prefix_len or 0)
                              + n_extra + 1)
    decoded = [int(jnp.argmax(logits[0]))]
    seq = tokens
    for i in range(n_extra):
        nt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        seq = jnp.concatenate([seq, nt], axis=1)
        logits, cache = T.decode_step(params, cfg, cache, nt)
        decoded.append(int(jnp.argmax(logits[0])))
    # teacher-forced reference over the extended sequence
    hid = T.forward(params, cfg, seq,
                    vision_embeds=batch.get("vision_embeds"),
                    enc_embeds=batch.get("enc_embeds"))
    ref = T.logits_for(params, cfg, hid[:, -1])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["gemma2-27b", "qwen2.5-3b", "olmoe-1b-7b"])
def test_approx_cfg_degrades_gracefully(arch):
    """The paper's knob: mild configs perturb logits slightly; output
    stays finite at the most aggressive config."""
    cfg = get_config(arch).smoke()
    params, _ = T.init_lm(KEY, cfg)
    batch = make_batch(cfg)
    hid0 = T.forward(params, cfg, batch["tokens"])
    hid1 = T.forward(params, cfg, batch["tokens"], approx_cfg=1)
    hid31 = T.forward(params, cfg, batch["tokens"], approx_cfg=31)
    rel1 = float(jnp.linalg.norm(hid1 - hid0) / (jnp.linalg.norm(hid0) + 1e-9))
    assert rel1 < 0.35, rel1
    assert np.isfinite(np.asarray(hid31, np.float32)).all()


def test_scan_vs_unrolled_layers_identical():
    cfg = get_config("qwen2.5-3b").smoke(n_layers=4)
    import dataclasses
    params, _ = T.init_lm(KEY, dataclasses.replace(cfg, scan_layers=True))
    batch = make_batch(cfg)
    h_scan = T.forward(params, dataclasses.replace(cfg, scan_layers=True),
                       batch["tokens"])
    h_unroll = T.forward(params, dataclasses.replace(cfg, scan_layers=False),
                         batch["tokens"])
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_unroll),
                               rtol=1e-5, atol=1e-5)


def test_kv_quant_cache_close_to_exact():
    import dataclasses
    cfg = get_config("qwen2.5-3b").smoke()
    params, _ = T.init_lm(KEY, cfg)
    batch = make_batch(cfg)
    lg, cache = T.prefill(params, cfg, batch["tokens"], max_len=S + 4)
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    lg_q, cache_q = T.prefill(params, cfg_q, batch["tokens"], max_len=S + 4)
    nt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    d0, _ = T.decode_step(params, cfg, cache, nt)
    d1, _ = T.decode_step(params, cfg_q, cache_q, nt)
    rel = float(jnp.linalg.norm(d1 - d0) / (jnp.linalg.norm(d0) + 1e-9))
    assert rel < 0.1, rel
