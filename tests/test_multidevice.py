"""Multi-device semantics on forced host devices (subprocess isolation —
the main test process must keep seeing 1 device).

Each test spawns `python -c` with XLA_FLAGS=--xla_force_host_platform_
device_count=8 and asserts inside the subprocess; failures propagate via
the exit code + stderr.
"""
from conftest import run_forced_devices as run_sub


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
assert len(jax.devices()) == 8
mesh = make_mesh((4, 2), ("data", "model"))
"""


def test_dp_tp_train_step_matches_single_device():
    run_sub(PRELUDE + """
import dataclasses
from repro.nn import transformer as T
from repro.train.optimizer import adamw
from repro.train.step import build_train_step, init_state
from repro.dist.sharding import Mapping, activate, train_state_specs

cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                    head_dim=16, d_ff=64, vocab_size=64, scan_layers=False,
                    remat=False, q_chunk=8, loss_chunks=1,
                    compute_dtype=jnp.float32)
key = jax.random.PRNGKey(0)
params, specs = T.init_lm(key, cfg)
opt = adamw(lr=1e-2)
step = build_train_step(cfg, opt, num_microbatches=2)
state = init_state(params, opt)
batch = {"tokens": jax.random.randint(key, (8, 16), 0, 64),
         "labels": jax.random.randint(key, (8, 16), 0, 64)}
# single-device reference
ref_state, ref_metrics = jax.jit(step)(state, batch)

mapping = Mapping(mesh, fsdp=True)
state_specs = train_state_specs(specs)
state_sh = mapping.shardings(state_specs, jax.eval_shape(lambda: state))
batch_sh = mapping.batch_sharding(batch)
with mesh, activate(mapping):
    dist_state, dist_metrics = jax.jit(
        step, in_shardings=(state_sh, batch_sh))(state, batch)
assert abs(float(ref_metrics["loss"]) - float(dist_metrics["loss"])) < 1e-4
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(dist_state["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-5)
print("DP+TP == single-device OK")
""")


def test_grad_compression_close_to_exact_mean():
    run_sub(PRELUDE + """
from repro.train.grad_compress import compressed_psum_mean, init_residual
key = jax.random.PRNGKey(1)
grads = {"a": jax.random.normal(key, (4, 64)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (128,))}
resid = init_residual(grads)
mean, new_resid = compressed_psum_mean(grads, resid, mesh, axis="data")
# replicated input => exact mean == input; int8 error bounded by scale
for k in grads:
    scale = float(jnp.max(jnp.abs(grads[k]))) / 127.0
    err = float(jnp.max(jnp.abs(mean[k] - grads[k])))
    assert err <= scale * 0.51 + 1e-7, (k, err, scale)
    # error feedback holds the residual: x = q*scale + residual
    recon = float(jnp.max(jnp.abs(
        (grads[k] - new_resid[k]) - mean[k])))
    assert recon <= scale * 0.51 + 1e-6, (k, recon)
print("grad compression OK")
""")


def test_sp_decode_attention_matches_ref():
    run_sub(PRELUDE + """
from repro.dist.seq_parallel import sp_decode_attention
from repro.nn.attention import decode_attention
key = jax.random.PRNGKey(2)
b, s, h, kv, hd = 1, 64, 4, 2, 16
ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (b, 1, h, hd))
k = jax.random.normal(ks[1], (b, s, kv, hd))
v = jax.random.normal(ks[2], (b, s, kv, hd))
for clen in (64, 40):
    ref = decode_attention(q, k, v, cache_len=clen)
    out = sp_decode_attention(q, k, v, clen, mesh, seq_axis="data")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
print("SP decode attention OK")
""")


def test_pipeline_forward_matches_sequential():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
assert len(jax.devices()) == 8
mesh = make_mesh((8,), ("stage",))
from repro.dist.pipeline_par import pipeline_forward
key = jax.random.PRNGKey(3)
n_stages, m, mb, d = 8, 4, 2, 16
w = jax.random.normal(key, (n_stages, d, d)) * 0.3

def stage_fn(w_s, x):
    return jnp.tanh(x @ w_s)

xs = jax.random.normal(jax.random.fold_in(key, 1), (m, mb, d))
out = pipeline_forward(stage_fn, w, xs, mesh)
ref = xs
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("pipeline forward OK")
""")


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    run_sub(PRELUDE + f"""
from repro.checkpoint.checkpointer import Checkpointer
ck = Checkpointer(r"{tmp_path}")
t = {{"w": jnp.arange(64.0).reshape(8, 8)}}
# save from a (4,2)-sharded placement
sh = NamedSharding(mesh, P("data", "model"))
t_sharded = {{"w": jax.device_put(t["w"], sh)}}
ck.save(1, t_sharded)
# restore onto a different mesh layout
mesh2 = make_mesh((2, 4), ("data", "model"))
sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
restored, _ = ck.restore(t, shardings=sh2)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
assert restored["w"].sharding == sh2["w"]
print("elastic restore OK")
""")
