"""Cross-feature interaction matrix (PR 10 tentpole test surface).

Paged KV (PR 8), approx-draft speculation (PR 9), chaos injection +
brownout (PR 7) and the power scheduler (PR 4) were each tested against
the plain engine and pairwise — never all LIVE in one engine.  This is
the composed harness: every arm of the paged × speculative ×
chaos-injected × scheduler-attached matrix runs the same workload and
must keep the three invariants that make the features composable:

  * stream bit-identity to the uninjected exact run — with the
    scheduler's budget at/above exact, its plan is all-exact, so chaos
    rollbacks, spec verify passes, paged rewinds and scheduler hooks
    must all be invisible in the emitted tokens;
  * zero retraces — one compiled executable per entry point across the
    whole run, all features live;
  * the ``energy_log`` row-sum == totals invariant, including the
    per-class partition (DESIGN.md §13), with every feature charging
    through the same ``_count_energy``.

The all-features-hot arm (sub-exact budget + brownout + class budgets +
mixed-class traffic) drops the bit-identity claim — the budget is
SUPPOSED to move configs — and pins the accounting/retrace invariants
at full load instead.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.brownout import BrownoutController
from repro.serve.engine import Engine, Request
from repro.serve.faults import FaultEvent, FaultInjector
from repro.serve.paged_cache import PagedCacheConfig
from repro.serve.scheduler import PowerBudgetScheduler
from repro.serve.speculative import SpecConfig
from repro.serve.traffic import TrafficClass, TrafficGenerator


@pytest.fixture(scope="module")
def model():
    """Briefly-trained demo LM: a random-init model has near-uniform
    logits, so verify-vs-decode last-bit numerics flip argmax ties and
    the bit-identity bar would test luck, not the contract (same
    reasoning as tests/test_speculative.py)."""
    from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
    from repro.nn import transformer as T
    from repro.train import optimizer as opt_mod
    from repro.train.step import build_train_step, init_state
    cfg = T.ModelConfig(name="demo", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=2, head_dim=16, d_ff=64,
                        vocab_size=64, scan_layers=False, remat=False,
                        q_chunk=8, loss_chunks=1,
                        compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(SyntheticLMConfig(vocab_size=64, seq_len=48,
                                         global_batch=16, n_templates=4,
                                         seed=0))
    train = jax.jit(build_train_step(cfg, opt_mod.adamw(lr=4e-3)))
    state = init_state(params, opt_mod.adamw(lr=4e-3))
    for i in range(300):
        b = data.batch(i)
        state, _ = train(state,
                         {k: jnp.asarray(v) for k, v in b.items()})
    return jax.tree.map(np.asarray, state["params"]), cfg


class FakeClock:
    """Deterministic injected time source: each read advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def _paged():
    return PagedCacheConfig(num_blocks=40, block_size=16,
                            prefill_chunk=16)


def _engine(params, cfg, paged, **kw):
    """One constructor for every arm: paged engines chunk their
    prefills; dense engines pad to one compiled prompt shape (the
    repo's dense zero-retrace mechanism, PR 5)."""
    if paged:
        kw["paged"] = _paged()
    else:
        kw["prefill_pad"] = 32          # all test prompts fit one pad
    return Engine(params, cfg, max_batch=4, max_len=64, **kw)


def _chaos():
    """Faults that must be invisible in the stream: retried decode
    failures, a NaN rollback, and duplicated probe telemetry."""
    return FaultInjector([FaultEvent(tick=2, kind="step_fail"),
                          FaultEvent(tick=3, kind="step_fail"),
                          FaultEvent(tick=5, kind="nan_logits"),
                          FaultEvent(tick=7, kind="dup_probe")])


def _reqs(seed=0, plens=(10, 20, 8, 12), new=24, cls="default"):
    # one prompt > prefill_chunk so the paged arms exercise the
    # mid-prompt chunk executable, not just the one-chunk fast path;
    # enough decode ticks (a trained spec engine commits k+1 per tick)
    # that every chaos event lands before the pool drains
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, 64, size=plen),
                    max_new_tokens=new, cls=cls)
            for i, plen in enumerate(plens)]


def _drain(eng, reqs, max_ticks=3000):
    for r in reqs:
        assert eng.submit(r)
    done = eng.run(max_ticks=max_ticks)
    assert all(r.status == "done" for r in done), \
        [(r.rid, r.status) for r in done]
    return {r.rid: list(r.tokens) for r in done}


def _assert_zero_retraces(eng):
    assert eng._decode._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    if eng.paged is not None:
        assert eng._prefill_chunk._cache_size() == 1
    elif eng.spec is not None:
        assert eng._verify._cache_size() == 1


def _assert_energy_log_invariants(eng):
    """Rows sum to totals; non-probe rows sum to the serve counters;
    per-class rows partition the per-class counters exactly."""
    rows = list(eng.energy_log)
    assert sum(t * pj for _, t, pj, _ in rows) == pytest.approx(
        eng.mac_energy_pj_per_param, rel=1e-12)
    assert sum(t for _, t, *_ in rows) == eng.n_tokens_charged
    assert sum(t * pj for k, t, pj, _ in rows if k != "probe") \
        == pytest.approx(eng.serve_mac_energy_pj_per_param, rel=1e-12)
    for k, _, _, c in rows:
        assert (c is None) == (k == "probe"), (k, c)
    by_cls: dict = {}
    for k, t, pj, c in rows:
        if k != "probe":
            e, n = by_cls.get(c, (0.0, 0))
            by_cls[c] = (e + t * pj, n + t)
    assert set(by_cls) == set(eng.serve_energy_by_class)
    for c, (e, n) in by_cls.items():
        assert e == pytest.approx(eng.serve_energy_by_class[c],
                                  rel=1e-12)
        assert n == eng.serve_tokens_by_class[c]
    assert sum(eng.serve_tokens_by_class.values()) \
        == eng.n_serve_tokens_charged


@pytest.fixture(scope="module")
def exact_streams(model):
    """The uninjected exact run every arm must reproduce, one per
    memory layout (dense vs paged prefill chunking reduce in different
    shapes, so cross-layout identity needs prefill_pad == chunk — PR
    8's test owns that claim; here each arm replays ITS layout)."""
    params, cfg = model
    return {flag: _drain(_engine(params, cfg, flag), _reqs())
            for flag in (False, True)}


@pytest.mark.parametrize("paged", [False, True],
                         ids=["dense", "paged"])
@pytest.mark.parametrize("spec", [False, True],
                         ids=["plain", "spec"])
def test_all_features_live_stream_is_bit_identical(model, exact_streams,
                                                   paged, spec):
    """paged × speculative × chaos × scheduler, all in ONE engine: the
    stream equals the uninjected exact run bit-for-bit, zero retraces,
    and the energy log stays a partition of the totals."""
    params, cfg = model
    # budget >= exact pJ/token => the plan stays all-exact: the
    # scheduler's hooks run on every tick but the pool config never
    # moves, so bit-identity must hold THROUGH the whole feature stack
    sched = PowerBudgetScheduler(1e9, retune_every=4, probe_every=2)
    inj = _chaos()
    eng = _engine(params, cfg, paged,
                  spec=SpecConfig(draft_cfg=8, k=3, max_k=3) if spec
                  else None,
                  scheduler=sched, fault_injector=inj,
                  clock=FakeClock(), retry_base_s=0.01,
                  retry_cap_s=0.05)
    got = _drain(eng, _reqs())
    assert got == exact_streams[paged], (paged, spec)
    # the chaos actually landed and was absorbed: step_fail always has
    # a delivery point; nan_logits corrupts DECODE logits, so an arm
    # whose every tick is a (chunk-verified) paged spec tick may leave
    # it pending — when it did deliver, it must have been quarantined
    assert eng.n_retries >= 1
    if inj.counts["nan_logits"]:
        assert eng.n_nan_events >= 1
    else:
        assert paged and spec, "only paged-spec may miss nan delivery"
    assert sched.tick > 0
    if spec:
        assert eng.n_spec_ticks + eng.n_spec_aborts > 0
    _assert_zero_retraces(eng)
    _assert_energy_log_invariants(eng)


@pytest.mark.parametrize("paged", [False, True],
                         ids=["dense", "paged"])
def test_all_features_hot_accounting_and_zero_retraces(model, paged):
    """The maximal composition: sub-exact budget (configs DO move),
    brownout scaling that budget, per-class splits closed from live
    attribution, speculation, chaos, and mixed-class traffic — the
    accounting and retrace invariants must survive all of it."""
    from repro.core.power_model import energy_per_token_pj
    params, cfg = model
    classes = (TrafficClass("chat", prompt_len=8, max_new_tokens=5,
                            weight=2.0, budget_share=0.6),
               TrafficClass("bulk", prompt_len=12, max_new_tokens=8,
                            budget_share=0.4))
    gen = TrafficGenerator(classes, rate_per_tick=0.7, seed=3,
                           vocab_size=cfg.vocab_size,
                           spikes=((4, 8, 3.0),))
    sched = PowerBudgetScheduler(1.0, retune_every=4, probe_every=2,
                                 hold_ticks=8)
    sched.set_class_budgets({c.name: c.budget_share for c in classes})
    bo = BrownoutController(ladder=(0, 16, 31), high_watermark=0.8,
                            low_watermark=0.2, hold_ticks=4)
    eng = _engine(params, cfg, paged, queue_capacity=8,
                  spec=SpecConfig(draft_cfg=8, k=2, max_k=2),
                  scheduler=sched, brownout=bo, fault_injector=_chaos(),
                  clock=FakeClock(), retry_base_s=0.01,
                  retry_cap_s=0.05)
    sched.set_budget(0.85 * energy_per_token_pj(0, eng.macs_per_token))
    offered = []
    for t in range(16):
        for r in gen.arrivals(t):
            offered.append(r)
            eng.submit(r)
        eng.step()
    eng.run(max_ticks=500)
    assert offered and any(r.status == "done" for r in offered)
    _assert_zero_retraces(eng)
    _assert_energy_log_invariants(eng)
    # both classes were attributed, and the class loop actually closed
    assert {"chat", "bulk"} <= set(eng.serve_tokens_by_class)
    assert sched.class_report, "per-class retune never ran"
    for c, row in sched.class_report.items():
        assert row["share"] > 0.0 and "next_share" in row, c
    assert sum(sched.class_shares.values()) == pytest.approx(1.0)
