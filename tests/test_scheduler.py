"""Online power-budget scheduler (PR 4 tentpole).

Contract: a ``PowerBudgetScheduler`` hooked into the Engine tick loop
(a) converges the executed energy/token to the joules/token budget on a
synthetic workload, (b) backs a disagreement burst off by exactly ONE
probe config on the offending key, (c) adds ZERO compiled artifacts
across a full run — probes and retunes reuse the engine's two
executables — and (d) reduces to the offline
``DynamicPowerController.allocate`` greedy when fed identical static
feedback (the shared ``core.controller.greedy_allocate`` core).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import (DynamicPowerController,
                                   step_down_config)
from repro.core.power_model import MAC_SAVING_FRAC, energy_per_token_pj
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import PowerBudgetScheduler


def _small_model():
    from repro.nn import transformer as T
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return T, cfg, params


class FakeClock:
    """Deterministic injected time source: each read advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def _feed(eng, rng, rid, n=4, max_new=8):
    while len(eng.queue) < n:
        eng.submit(Request(rid=rid[0],
                           prompt=rng.integers(0, 64, size=6),
                           max_new_tokens=max_new))
        rid[0] += 1


# --- (a) budget respected within tolerance ---------------------------------

def test_budget_respected_on_synthetic_workload():
    T, cfg, params = _small_model()
    # isolate the budget loop from probe noise (the random-init toy
    # model has no logit margins): probes effectively off
    sched = PowerBudgetScheduler(0.0, retune_every=4, probe_every=10**9,
                                 seed=0)
    eng = Engine(params, cfg, max_batch=2, max_len=32, scheduler=sched,
                 clock=FakeClock())
    exact = energy_per_token_pj(np.zeros(cfg.n_layers, np.int32),
                                eng.macs_per_token)
    budget = 0.85 * exact
    sched.set_budget(budget)
    rng, rid = np.random.default_rng(0), [0]
    for _ in range(60):
        _feed(eng, rng, rid)
        eng.step()
    # the engine runs the scheduler's allocation...
    np.testing.assert_array_equal(eng.approx_cfg,
                                  sched._tensor(sched.assignment))
    # ...whose modeled energy meets the budget from below, within 5%
    modeled = sched._energy_pj(sched.assignment)
    assert modeled <= budget + 1e-9
    assert abs(modeled - budget) / budget < 0.05, (modeled, budget)
    # ...and the MEASURED energy of the tail window tracks it too
    retunes = [h for h in sched.history if h["event"] == "retune"]
    measured = retunes[-1]["measured_pj_per_token"]
    assert measured is not None
    assert abs(measured - budget) / budget < 0.05, (measured, budget)


def test_set_budget_retargets_live():
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(0.0, retune_every=2, probe_every=10**9)
    eng = Engine(params, cfg, max_batch=2, max_len=32, scheduler=sched,
                 clock=FakeClock())
    exact = energy_per_token_pj(np.zeros(cfg.n_layers, np.int32),
                                eng.macs_per_token)
    rng, rid = np.random.default_rng(0), [0]
    for frac in (0.9, 0.7):
        sched.set_budget(frac * exact)
        for _ in range(20):
            _feed(eng, rng, rid)
            eng.step()
        modeled = sched._energy_pj(sched.assignment)
        assert modeled <= frac * exact + 1e-9
        assert abs(modeled - frac * exact) / (frac * exact) < 0.05


# --- (b) backoff: one probe config, one key --------------------------------

def test_backoff_steps_down_exactly_one_probe_config():
    sched = PowerBudgetScheduler(0.0, hysteresis=3,
                                 probe_configs=(8, 16, 24, 31))
    sched.bind((2,))
    sched.assignment = {(0,): 24, (1,): 8}
    # key (0,) is the measurably-worst offender
    sched.est[((0,), 24)] = 0.5
    sched.est[((1,), 8)] = 0.001
    for _ in range(2):
        sched.record_probe(False)
    # burst shorter than the hysteresis: nothing moves
    assert sched.assignment == {(0,): 24, (1,): 8}
    sched.record_probe(False)
    # one notch down the PROBE ladder on the offending key only —
    # 24 -> 16, not a reset to exact, and (1,) untouched
    assert sched.assignment[(0,)] == step_down_config(24, (8, 16, 24, 31))
    assert sched.assignment[(0,)] == 16
    assert sched.assignment[(1,)] == 8
    assert sched.n_backoffs == 1
    # the key is held: its ladder is capped at the stepped-down config
    assert all(MAC_SAVING_FRAC[c] <= MAC_SAVING_FRAC[16]
               for c in sched._ladder((0,)))
    # an agreeing probe resets the streak
    sched.record_probe(True)
    sched.record_probe(False)
    sched.record_probe(False)
    assert sched.n_backoffs == 1


def test_backoff_penalty_decays_so_config_is_not_banned_forever():
    """The backoff charges the stepped-down-from config the full
    disagreement budget; since probes only re-measure configs that
    execute, retune-time recovery must relax that estimate toward the
    MRED prior or the config would be unreachable for the rest of the
    process lifetime."""
    sched = PowerBudgetScheduler(0.0, retune_every=1, probe_every=10**9,
                                 hysteresis=1, hold_ticks=2, recover=0.5,
                                 probe_configs=(8, 16, 31))
    sched.bind((1,))
    sched.assignment = {(0,): 31}
    sched.est[((0,), 31)] = 0.5
    sched.record_probe(False)            # hysteresis=1: immediate backoff
    assert sched.assignment[(0,)] == 16
    penalty = sched.est[((0,), 31)]
    assert penalty >= 1.0 - sched.agreement_target

    class StubEngine:                    # just what on_tick reads
        mac_energy_pj_per_param = 0.0
        n_tokens_charged = 0
        clock = staticmethod(lambda: 0.0)

        def set_approx_cfg(self, v):
            pass

    eng = StubEngine()
    for _ in range(20):
        sched.on_tick(eng)
    # hold expired and the penalty relaxed back to ~the prior
    assert (0,) not in sched.hold
    assert sched.est[((0,), 31)] < 0.1 * penalty + 2 * sched._prior(31)


def test_backoff_reaches_live_engine():
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(0.0, retune_every=10**9,
                                 probe_every=10**9, hysteresis=2,
                                 probe_configs=(8, 16, 31))
    eng = Engine(params, cfg, max_batch=2, max_len=32, scheduler=sched,
                 clock=FakeClock())
    sched.assignment = {(0,): 31, (1,): 8}
    eng.set_approx_cfg(sched._tensor(sched.assignment))
    sched.est[((0,), 31)] = 0.9
    sched.record_probe(False)
    sched.record_probe(False)
    # the engine's live config steps (0,) down one probe notch: 31 -> 16
    np.testing.assert_array_equal(eng.approx_cfg, [16, 8])


# --- (c) zero retraces across a full scheduler run -------------------------

def test_full_scheduler_run_zero_retraces():
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(0.0, retune_every=4, probe_every=2,
                                 seed=0)
    eng = Engine(params, cfg, max_batch=2, max_len=32, scheduler=sched,
                 clock=FakeClock())
    exact = energy_per_token_pj(np.zeros(cfg.n_layers, np.int32),
                                eng.macs_per_token)
    sched.set_budget(0.8 * exact)
    rng, rid = np.random.default_rng(0), [0]
    # warmup: one tick compiles one prefill + one decode executable;
    # the first probe fires on it too (same shapes, traced config)
    _feed(eng, rng, rid)
    eng.step()
    sizes = (eng._decode._cache_size(), eng._prefill._cache_size())
    for _ in range(40):
        _feed(eng, rng, rid)
        eng.step()
    # probes ran, retunes ran (and on this random-init model, almost
    # certainly backoffs too) — all on the SAME two executables
    assert sched.n_probes > 10 and sched.tick > 40
    assert (eng._decode._cache_size(),
            eng._prefill._cache_size()) == sizes


# --- (d) online == offline on identical static feedback --------------------

def test_online_matches_offline_allocate_on_static_feedback():
    probe_configs = (8, 16, 31)
    layers = ["layer_0", "layer_1"]
    # dyadic deltas/budget: exactly representable, so the two paths'
    # float accumulations cannot diverge at the budget boundary (the
    # online disagreement budget passes through 1 - agreement_target)
    delta = {(0, 8): 4 / 1024, (0, 16): 6 / 1024, (0, 31): 20 / 1024,
             (1, 8): 1 / 1024, (1, 16): 3 / 1024, (1, 31): 12 / 1024}
    budget = 8 / 1024

    # offline: additive loss_fn over the same table -> calibrate
    # measures exactly `delta`; validation is a no-op (additivity)
    def loss_fn(assignment):
        return sum(delta.get((int(l.rsplit("_", 1)[-1]), c), 0.0)
                   for l, c in assignment.items())

    ctrl = DynamicPowerController(layers, loss_fn,
                                  probe_configs=probe_configs)
    offline = ctrl.allocate(loss_budget=budget)

    # online: same table injected as static feedback, energy budget
    # unreachable (0 pJ) so the greedy runs on the disagreement budget
    # alone — the shared greedy core must land on the same assignment
    sched = PowerBudgetScheduler(0.0, probe_configs=probe_configs,
                                 agreement_target=1.0 - budget,
                                 sensitivity={((l,), c): d
                                              for (l, c), d in
                                              delta.items()})
    sched.bind((2,))
    online = sched.plan()
    for i, name in enumerate(layers):
        assert online[(i,)] == offline[name], (online, offline)
    # and the allocation is non-trivial (budget binds somewhere)
    assert any(v > 0 for v in online.values())
    assert sum(delta.get((k[0], c), 0.0)
               for k, c in online.items()) <= budget + 1e-12


def test_plan_refines_toward_budget_from_below():
    """With a reachable energy budget the greedy may overshoot below;
    the refinement pass must claw back saving while staying <= budget,
    and never end above it."""
    sched = PowerBudgetScheduler(0.0, probe_configs=tuple(range(1, 32)))
    sched.bind((4,))
    exact = energy_per_token_pj(np.zeros(4, np.int32))
    for frac in (0.95, 0.85, 0.75, 0.65):
        sched.set_budget(frac * exact)
        asg = sched.plan()
        e = sched._energy_pj(asg)
        assert e <= frac * exact + 1e-12
        assert abs(e - frac * exact) / (frac * exact) < 0.05, (frac, e)


def test_incremental_energy_state_matches_full_recompute():
    """plan()'s O(1)/O(E) trial evaluator must agree with the full
    energy_per_token_pj rebuild — including the expert-collapsed dense
    share on (L, E, G) spaces."""
    from repro.serve.scheduler import _EnergyState
    rng = np.random.default_rng(0)
    for shape, f in (((3,), 0.0), ((2, 4), 0.0), ((2, 3, 2), 0.6)):
        vec = rng.integers(0, 32, size=shape).astype(np.int64)
        st = _EnergyState(vec, 1e6, f)
        assert st.energy() == pytest.approx(
            energy_per_token_pj(vec, 1e6, f), rel=1e-12)
        for _ in range(20):
            key = tuple(int(rng.integers(0, s)) for s in shape)
            c = int(rng.integers(0, 32))
            ref = vec.copy()
            ref[key] = c
            assert st.trial(key, c) == pytest.approx(
                energy_per_token_pj(ref, 1e6, f), rel=1e-12), (shape, key)
            st.commit(key, c)
            vec = ref
            assert st.energy() == pytest.approx(
                energy_per_token_pj(vec, 1e6, f), rel=1e-12)


def test_plan_on_expert_group_space_respects_budget():
    sched = PowerBudgetScheduler(0.0, probe_configs=tuple(range(1, 32)))
    sched.bind((2, 3, 2), macs_per_token=1e6, moe_mac_frac=0.6)
    exact = energy_per_token_pj(np.zeros((2, 3, 2), np.int64), 1e6, 0.6)
    for frac in (0.9, 0.75):
        sched.set_budget(frac * exact)
        asg = sched.plan()
        e = sched._energy_pj(asg)
        assert e <= frac * exact + 1e-9
        assert abs(e - frac * exact) / (frac * exact) < 0.05, (frac, e)


# --- engine sampling regression (found building the probe signal) -----------

def test_decode_honors_request_temperature():
    """Request.temperature=0 promises greedy decoding, but the decode
    loop used to sample every slot at temperature 1.0 after the first
    token — the scheduler's argmax-agreement probes measure what the
    engine emits only if the engine actually emits greedy tokens."""
    T, cfg, params = _small_model()

    def toks(seed, temperature):
        eng = Engine(params, cfg, max_batch=2, max_len=32, seed=seed)
        eng.submit(Request(rid=0, prompt=np.arange(6) % 64,
                           max_new_tokens=6, temperature=temperature))
        return eng.run(max_ticks=30)[0].tokens

    # greedy decode is RNG-independent (the old behavior diverged from
    # the second token on)
    assert toks(0, 0.0) == toks(123, 0.0)
    # mixed temperatures in one pool still serve fine
    eng = Engine(params, cfg, max_batch=2, max_len=32)
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64, max_new_tokens=4,
                       temperature=0.0))
    eng.submit(Request(rid=1, prompt=np.arange(8) % 64, max_new_tokens=4,
                       temperature=0.8))
    done = eng.run(max_ticks=30)
    assert len(done) == 2 and all(len(r.tokens) == 4 for r in done)


# --- clock injection (satellite): deterministic request timing --------------

def test_injected_clock_stamps_requests_deterministically():
    T, cfg, params = _small_model()

    def run_once():
        clk = FakeClock()
        eng = Engine(params, cfg, max_batch=2, max_len=32, clock=clk)
        rng = np.random.default_rng(0)
        for rid in range(3):
            eng.submit(Request(rid=rid, prompt=rng.integers(0, 64, size=6),
                               max_new_tokens=3))
        done = eng.run(max_ticks=30)
        return [(r.rid, r.submitted_at, r.first_token_at, r.finished_at)
                for r in done]

    a, b = run_once(), run_once()
    assert a == b                       # fully deterministic timing
    for _, sub, first, fin in a:
        assert sub is not None and sub < first < fin
        assert fin < 1.0                # fake-clock domain, not wall time


def test_request_submitted_at_stamped_by_engine_clock():
    T, cfg, params = _small_model()
    clk = FakeClock()
    eng = Engine(params, cfg, max_batch=1, max_len=32, clock=clk)
    req = Request(rid=0, prompt=np.arange(4) % 64)
    assert req.submitted_at is None     # no wall-clock at construction
    eng.submit(req)
    assert req.submitted_at == pytest.approx(1e-3)
    # an explicit pre-set stamp is preserved
    req2 = Request(rid=1, prompt=np.arange(4) % 64, submitted_at=42.0)
    eng.submit(req2)
    assert req2.submitted_at == 42.0


def test_scheduler_history_uses_engine_clock():
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(0.0, retune_every=2, probe_every=10**9)
    eng = Engine(params, cfg, max_batch=1, max_len=32, scheduler=sched,
                 clock=FakeClock())
    eng.submit(Request(rid=0, prompt=np.arange(4) % 64, max_new_tokens=6))
    eng.run(max_ticks=10)
    times = [h["time"] for h in sched.history if h["event"] == "retune"]
    assert times and all(t < 1.0 for t in times)
    assert times == sorted(times)
