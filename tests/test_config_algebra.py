"""Property tests for the config algebra (PR 4 satellite).

The conservative config join appears three times in the stack — the
engine's decode-pool join (``engine.pool_join``), the expert-axis
collapse (``ops.collapse_expert_cfg``), and the kernel's
straddling-block collapse — all meaning the same thing: elementwise
meet under the total order (measured MRED, config index).  These laws
make "never exceed any participant's requested error" composable: the
pool can join requests in any order, incrementally or at once, and the
expert collapse commutes with it.

Laws (>= 200 generated cases each, via hypothesis or the deterministic
tests/_hypothesis_compat.py shim): commutativity, associativity,
idempotence, never-ranks-above-the-lowest-MRED-input (and membership:
the join picks one of its inputs), deterministic (mred, index)
tie-break, and pool_join == collapse_expert_cfg on the expert axis —
over random (n_layers, E, g) tensors drawn from all 32 configs.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.approx_multiplier import N_CONFIGS
from repro.core.error_metrics import mred_table
from repro.kernels.approx_mac.ops import collapse_expert_cfg
from repro.serve.engine import pool_join

MRED = np.asarray(mred_table())
# reference total order: position when sorting by (measured MRED, index)
_ORDER = np.lexsort((np.arange(N_CONFIGS), MRED))
RANK = np.empty(N_CONFIGS, np.int64)
RANK[_ORDER] = np.arange(N_CONFIGS)

N_EXAMPLES = 200


def _tensors(seed: int, k: int = 3):
    """k random (L, E, g) config tensors (shared shape) from all 32
    configs, with occasional duplicated values to exercise ties."""
    rng = np.random.default_rng(seed)
    shape = (int(rng.integers(1, 4)), int(rng.integers(1, 5)),
             int(rng.integers(1, 4)))
    out = [rng.integers(0, N_CONFIGS, size=shape).astype(np.int32)
           for _ in range(k)]
    if rng.random() < 0.3:          # force elementwise ties sometimes
        out[1] = out[0].copy()
    return out


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_join_commutative(seed):
    a, b, _ = _tensors(seed)
    np.testing.assert_array_equal(pool_join([a, b]), pool_join([b, a]))
    # the expert collapse is the same meet: expert-permutation invariant
    rng = np.random.default_rng(seed + 1)
    x = a[0]                                    # (E, g)
    perm = rng.permutation(x.shape[0])
    np.testing.assert_array_equal(np.asarray(collapse_expert_cfg(x)),
                                  np.asarray(collapse_expert_cfg(x[perm])))


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_join_associative(seed):
    a, b, c = _tensors(seed)
    all_at_once = pool_join([a, b, c])
    left = pool_join([pool_join([a, b]), c])
    right = pool_join([a, pool_join([b, c])])
    np.testing.assert_array_equal(all_at_once, left)
    np.testing.assert_array_equal(all_at_once, right)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_join_idempotent(seed):
    a, _, _ = _tensors(seed)
    np.testing.assert_array_equal(pool_join([a, a]), a)
    np.testing.assert_array_equal(pool_join([a]), a)
    # one-expert collapse is the identity; E identical experts too
    row = a[:1, 0, :]                           # (1, g)
    np.testing.assert_array_equal(np.asarray(collapse_expert_cfg(row)),
                                  row[0])
    rep = np.repeat(row, 3, axis=0)             # (3, g), all equal
    np.testing.assert_array_equal(np.asarray(collapse_expert_cfg(rep)),
                                  row[0])


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_join_never_ranks_above_lowest_mred_input(seed):
    a, b, c = _tensors(seed)
    j = pool_join([a, b, c])
    # elementwise: the join's measured MRED is the minimum...
    assert (MRED[j] <= np.minimum(MRED[a], np.minimum(MRED[b], MRED[c]))
            ).all()
    # ...and the join MEMBERSHIP holds: every cell comes from an input
    assert ((j == a) | (j == b) | (j == c)).all()
    # same bound for the expert collapse along its axis
    x = a[0]                                    # (E, g)
    col = np.asarray(collapse_expert_cfg(x))
    assert (MRED[col] <= MRED[x].min(axis=0)).all()
    assert (col[None, :] == x).any(axis=0).all()


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_join_deterministic_tie_break(seed):
    a, b, _ = _tensors(seed)
    j = pool_join([a, b])
    # fully deterministic closed form: the (mred, index)-lexicographic
    # argmin — MRED ties resolve toward the LOWER config index
    np.testing.assert_array_equal(j, np.where(RANK[b] < RANK[a], b, a))
    # repeated evaluation is stable
    np.testing.assert_array_equal(j, pool_join([a, b]))
    # explicit tie: configs 1 and 3 measure the SAME MRED — the join
    # must pick the lower index
    assert MRED[1] == MRED[3]
    t1 = np.full_like(a, 3)
    t2 = np.full_like(a, 1)
    assert (pool_join([t1, t2]) == 1).all()


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_collapse_expert_cfg_is_pool_join_over_expert_axis(seed):
    a, _, _ = _tensors(seed)
    for layer in a:                             # (E, g) per layer
        np.testing.assert_array_equal(np.asarray(collapse_expert_cfg(layer)),
                                      pool_join(layer))
