# repro-lint: scope=src/repro/serve/fixture.py
"""GOOD: maxlen deques on the tick path; bare lists only off it."""
from collections import deque


class Engine:
    def __init__(self):
        self.history = deque(maxlen=4096)
        self.pending = []

    def on_tick(self, engine):
        self.history.append(engine)

    def submit(self, req):
        self.pending.append(req)       # drained by the step loop
