# repro-lint: scope=src/repro/nn/fixture.py
"""BAD: host reads of traced values (rule: trace-safety)."""
import jax


@jax.jit
def f(x):
    return float(x) + 1.0          # concretizes the tracer


def g(w, config):
    return w * int(config)         # Python-level read of the error config
