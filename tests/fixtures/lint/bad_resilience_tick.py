# repro-lint: scope=src/repro/serve/faults.py
"""BAD: unbounded fault-audit state on the chaos tick path (rule:
bounded-state) — PR 7 extended TICK_METHODS with the injector's
``begin_tick`` and the traffic generator's ``arrivals``."""


class FaultInjector:
    def __init__(self):
        self.fired = []                # bare list

    def begin_tick(self, engine):
        self.fired.append(engine)      # grows forever under chaos


class TrafficGenerator:
    def __init__(self):
        self.trace = []

    def arrivals(self, tick):
        self.trace.append(tick)        # every tick of the whole run
        return []
