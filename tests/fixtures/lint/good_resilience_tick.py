# repro-lint: scope=src/repro/serve/faults.py
"""GOOD: bounded audit windows on the chaos tick path; the arrival
stream is recomputed from (seed, tick), never accumulated."""
from collections import deque


class FaultInjector:
    def __init__(self):
        self.fired = deque(maxlen=4096)

    def begin_tick(self, engine):
        self.fired.append(engine)


class TrafficGenerator:
    def __init__(self):
        self.seed = 0

    def arrivals(self, tick):
        return [(self.seed, tick)]     # pure function, no trace kept
