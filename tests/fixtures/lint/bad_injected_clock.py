# repro-lint: scope=src/repro/serve/fixture.py
"""BAD (historical: the PR 4 wall-clock default): ambient time reads
make request ordering and scheduler timing untestable (rule:
injected-clock)."""
import time
from dataclasses import dataclass, field


@dataclass
class Request:
    submitted_at: float = field(default_factory=time.time)


def loop():
    t0 = time.time()
    return time.monotonic() - t0
