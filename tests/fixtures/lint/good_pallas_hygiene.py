# repro-lint: scope=src/repro/kernels/fixture.py
"""GOOD: index_maps over grid args + shape-derived locals; prefetch
refs lead the kernel signature."""
from jax.experimental import pallas as pl


def build(x):
    group = x.shape[0] // 8            # local, derived from shapes
    return pl.BlockSpec((8, 128), lambda i, j: (i, j // group))


def _kernel(cfg_ref, xscale_ref, a_ref, o_ref, acc_ref):
    o_ref[...] = a_ref[...]
