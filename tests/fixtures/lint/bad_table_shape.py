# repro-lint: scope=src/repro/nn/fixture.py
"""BAD (paged-KV zero-retrace): block tables / page indices / sequence
lengths are per-tick DATA operands of the one compiled decode step —
letting them pick shapes or steer Python control flow compiles one
executable per occupancy (rule: cfg-shape)."""
import jax.numpy as jnp


def f(x, seq_len):
    mask = jnp.zeros((seq_len, 4))       # length-dependent shape
    return x + mask.sum()


def g(kv, block_table):
    if block_table[0] > 0:               # Python branch on the table
        return kv * 2.0
    return kv


def h(x, seq_lens):
    pos = jnp.arange(seq_lens)           # length-dependent iota
    return x + pos.sum()


def k(x, page_idx):
    return x.reshape(page_idx, -1)       # table value as a shape
