# repro-lint: scope=src/repro/nn/fixture.py
"""GOOD: tables and lengths stay data — gathers, masks and writes are
indexed by them, but every shape comes from static array metadata."""
import jax.numpy as jnp


def f(x, seq_len):
    mask = jnp.zeros((x.shape[0], 4))          # shape from the DATA
    pos = jnp.arange(x.shape[1])
    return x + (pos[None, :] < seq_len).astype(x.dtype) @ mask


def g(kv_pool, block_table):
    if block_table is None:                    # Python-default dispatch
        return kv_pool
    return kv_pool[block_table]                # gather: table as INDEX


def h(x, seq_lens):
    posv = seq_lens[:, None]                   # data operand, not shape
    return x * jnp.where(posv > 0, 1.0, 0.0)
