# repro-lint: scope=src/repro/kernels/fixture.py
"""BAD: an index_map closing over a kernel-call parameter, and a
scalar-prefetch ref after a regular ref (rule: pallas-hygiene)."""
from jax.experimental import pallas as pl


def build(n_heads):
    return pl.BlockSpec((8, 128), lambda i, j: (i, j // n_heads))


def _kernel(a_ref, cfg_ref, o_ref, acc_ref):
    o_ref[...] = a_ref[...]
