# repro-lint: scope=src/repro/nn/fixture.py
"""BAD (speculative zero-retrace): the draft config is traced DATA and
the draft depth is a host loop count bounded by the static max_k —
letting either pick a shape or steer Python control flow in a traced
body compiles one executable per (k, draft-cfg) cell and kills the
live sweep (rule: cfg-shape)."""
import jax.numpy as jnp


def f(x, draft_k):
    window = jnp.zeros((draft_k, 4))     # depth-dependent verify window
    return x + window.sum()


def g(logits, draft_cfg):
    if draft_cfg > 16:                   # Python branch on the traced knob
        return logits * 2.0
    return logits


def h(x, spec_k):
    pos = jnp.arange(spec_k)             # depth-dependent iota
    return x + pos.sum()


def k(tokens, draft_config):
    return tokens.reshape(draft_config, -1)  # knob value as a shape
