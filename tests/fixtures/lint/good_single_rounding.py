# repro-lint: scope=src/repro/core/fixture.py
"""GOOD: the combined scale is rounded ONCE, then one multiply."""


def rescale(acc, x_scale, w_scale):
    return acc * (x_scale * w_scale)
