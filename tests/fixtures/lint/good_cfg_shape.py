# repro-lint: scope=src/repro/nn/fixture.py
"""GOOD: config-independent shapes; None-default and isinstance
dispatch; metadata-only shape reads."""
import jax
import jax.numpy as jnp


def f(x, cfg):
    if cfg is None:                        # Python-default dispatch
        cfg = 0
    mask = jnp.zeros((x.shape[0], 4))      # shape from the DATA, not cfg
    return x + mask.sum()


def g(x, approx_cfg):
    if isinstance(approx_cfg, jax.Array) or approx_cfg > 0:
        return x * 2.0                     # static/traced dual API
    return x


def h(cfg):
    return jnp.broadcast_to(jnp.asarray(cfg), jnp.shape(cfg))
