# repro-lint: scope=src/repro/serve/fixture.py
"""GOOD: the telemetry window is bounded by construction — one maxlen
deque holds the samples, scalar accumulators carry everything else."""
from collections import deque


class BoundedWindow:
    def __init__(self, maxlen=64):
        self._buf = deque(maxlen=maxlen)
        self.n_spikes = 0

    def push(self, x):
        self._buf.append(float(x))

    def score(self, x):
        if not self._buf:
            return 0.0
        return float(x) - sum(self._buf) / len(self._buf)
