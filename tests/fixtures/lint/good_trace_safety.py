# repro-lint: scope=src/repro/nn/fixture.py
"""GOOD: shape-derived conversions and isinstance-guarded static reads."""
import jax


@jax.jit
def f(x):
    d = int(x.shape[0])            # static metadata, not a traced value
    return x * d


def g(w, config):
    if isinstance(config, jax.Array):
        return w                   # traced branch never reads the value
    return w * int(config)         # static branch: config is a Python int
