# repro-lint: scope=src/repro/serve/fixture.py
"""BAD (bounded telemetry state): every control signal flows through a
telemetry window's ``push``/``score`` per tick, so an unbounded sample
buffer there leaks memory at serving rate (rule: bounded-state)."""
from collections import deque


class LeakyWindow:
    def __init__(self):
        self.samples = []
        self.history = deque()       # deque without maxlen

    def push(self, x):
        self.samples.append(x)       # bare-list append on the tick path
