# repro-lint: scope=src/repro/nn/fixture.py
"""BAD (telemetry/class-budget zero-retrace): spike scores and class
budget splits are host control signals that feed the traced config
DATA operand — letting one pick a shape or steer Python control flow
in a traced body mints a new executable per telemetry reading (rule:
cfg-shape)."""
import jax.numpy as jnp


def f(x, spike_score):
    if spike_score > 4.0:                # Python branch on the signal
        return x * 0.5
    return x


def g(x, class_budgets):
    return jnp.zeros((class_budgets, 4))     # budget count as a shape


def h(tokens, class_shares):
    return tokens.reshape(class_shares, -1)  # split value as a shape


def k(x, budget_share):
    idx = jnp.arange(budget_share)           # share-dependent iota
    return x + idx.sum()
