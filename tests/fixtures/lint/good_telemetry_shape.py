# repro-lint: scope=src/repro/nn/fixture.py
"""GOOD: telemetry stays a data operand — spike decisions go through
jnp.where, class capacity is a STATIC constant, shares ride along as
traced values, and None-dispatch happens on the Python default."""
import jax.numpy as jnp

N_CLASSES = 4                                  # static class capacity


def f(x, spike_score):
    damp = jnp.where(jnp.asarray(spike_score) > 4.0, 0.5, 1.0)
    return x * damp                            # signal as a data MASK


def g(x, class_budgets):
    if class_budgets is None:                  # Python-default dispatch
        return x
    buf = jnp.zeros((N_CLASSES, 4))            # static shape
    return x + buf.sum()


def h(x, class_shares):
    w = jnp.asarray(class_shares)              # data operand, not shape
    return x * w
