# repro-lint: scope=src/repro/nn/fixture.py
"""BAD (historical: traced-cfg-in-shape): the config flowing into a
shape position or Python control flow retraces per config value and
shatters the one-executable guarantee (rule: cfg-shape)."""
import jax.numpy as jnp


def f(x, cfg):
    mask = jnp.zeros((cfg, 4))     # config-dependent shape
    return x + mask.sum()


def g(x, approx_cfg):
    if approx_cfg > 0:             # Python branch on the traced knob
        return x * 2.0
    return x


def h(x, config):
    for _ in range(config):        # unrolls per config value
        x = x + 1.0
    return x
