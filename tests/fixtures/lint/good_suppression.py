# repro-lint: scope=src/repro/serve/fixture.py
"""GOOD: a reasoned waiver silences exactly the named rule."""
import time


def loop():
    # repro-lint: disable=injected-clock — fixture demonstrating a reasoned waiver
    return time.time()
