# repro-lint: scope=src/repro/serve/fixture.py
"""GOOD: the wall clock appears only as the injected default of a
parameter/field named ``clock``; everything reads the injection."""
import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass
class Engine:
    clock: Callable[[], float] = field(default=time.time)


def loop(clock: Callable[[], float] = time.time):
    t0 = clock()
    return clock() - t0
