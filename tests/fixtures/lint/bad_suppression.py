# repro-lint: scope=src/repro/serve/fixture.py
"""BAD: a suppression without a reason suppresses nothing and is
itself a finding (rule: suppression)."""
import time


def loop():
    return time.time()  # repro-lint: disable=injected-clock
