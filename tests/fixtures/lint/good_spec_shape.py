# repro-lint: scope=src/repro/nn/fixture.py
"""GOOD: the speculative knobs stay data / host loop counts — the
verify window is shaped by the STATIC max_k, the draft config is a
gather index into traced tables, and depth branches happen on the
Python default, never the traced value."""
import jax.numpy as jnp

MAX_K = 7


def f(x, draft_k):
    window = jnp.zeros((MAX_K + 1, 4))         # static window shape
    live = jnp.arange(MAX_K + 1) < draft_k     # depth as a data MASK
    return x + (window.sum(-1) * live).sum()


def g(logits, draft_cfg, table):
    if draft_cfg is None:                      # Python-default dispatch
        return logits
    return logits * table[draft_cfg]           # knob as a gather INDEX


def h(x, spec_k):
    posv = jnp.asarray(spec_k)[None]           # data operand, not shape
    return x * jnp.where(posv > 0, 1.0, 0.0)
