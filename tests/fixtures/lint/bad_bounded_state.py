# repro-lint: scope=src/repro/serve/fixture.py
"""BAD: unbounded serving state on the tick path (rule: bounded-state)."""
from collections import deque


class Engine:
    def __init__(self):
        self.history = deque()         # no maxlen
        self.log = []

    def on_tick(self, engine):
        self.log.append(engine)        # grows forever under serving
