# repro-lint: scope=src/repro/core/fixture.py
"""BAD (historical: the PR 3 rescale reassociation): the two-multiply
dequant chain is regrouped by XLA's simplifier under jit, so
differently-compiled paths diverge by 1 ulp (rule: single-rounding)."""


def rescale(acc, x_scale, w_scale):
    return (acc * x_scale) * w_scale
