"""Energy-report accounting invariants (PR 4 satellite).

The engine's energy integral is the quantity the power-budget scheduler
steers, so its bookkeeping gets first-class coverage (it was previously
only exercised incidentally through example asserts): per-step charges
sum exactly to the report totals, the MoE dense share is charged at the
expert-COLLAPSED config it actually executes, and the reported saving
fraction is the MAC_SAVING_FRAC composition of the executed configs.

One engine per model is shared across the checks (each Engine instance
compiles its own prefill/decode pair); per-config assertions work on
report DELTAS between rounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.power_model import (ENERGY_PER_MAC_PJ, MAC_SAVING_FRAC,
                                    energy_per_mac_pj,
                                    energy_per_token_pj)
from repro.kernels.approx_mac.ops import collapse_expert_cfg
from repro.serve.engine import Engine, Request


def _small_model():
    from repro.nn import transformer as T
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return T, cfg, params


@pytest.fixture(scope="module")
def dense_engine():
    T, cfg, params = _small_model()
    return Engine(params, cfg, max_batch=2, max_len=64)


@pytest.fixture(scope="module")
def moe_engine():
    from repro.nn import transformer as T
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        n_experts=2, top_k=1, scan_layers=False,
                        remat=False, q_chunk=8, loss_chunks=1,
                        compute_dtype=jnp.float32, mac_backend="pallas",
                        mac_interpret=True)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return Engine(params, cfg, max_batch=2, max_len=32, cfg_experts=2)


def _round(eng, rid, approx_cfg):
    """One request at `approx_cfg`; returns the round's (modeled pJ,
    exact pJ) per-param charge deltas and the new log rows."""
    eng.set_approx_cfg(approx_cfg)
    e0, x0, n0 = (eng.mac_energy_pj_per_param,
                  eng.exact_energy_pj_per_param, len(eng.energy_log))
    eng.submit(Request(rid=rid, prompt=np.arange(6) % 64,
                       max_new_tokens=3))
    eng.run(max_ticks=20)
    return (eng.mac_energy_pj_per_param - e0,
            eng.exact_energy_pj_per_param - x0,
            list(eng.energy_log)[n0:])


# --- dense engine: sums, kinds, saving composition --------------------------

def test_dense_engine_accounting(dense_engine):
    eng = dense_engine
    # (a) uniform configs: each round's saving is the table entry
    for rid, c in enumerate((0, 1, 8, 16, 31)):
        d_cfg, d_exact, rows = _round(eng, rid, c)
        assert 1.0 - d_cfg / d_exact == pytest.approx(
            float(MAC_SAVING_FRAC[c]), rel=1e-6, abs=1e-9), c
        # every charge of the round ran at the round's config rate
        for kind, _, pj, _ in rows:
            assert pj == pytest.approx(float(ENERGY_PER_MAC_PJ[c]),
                                       rel=1e-12), kind

    # (b) mixed per-layer vector: saving is the energy-mean composition
    vec = np.asarray([8, 31], np.int32)
    d_cfg, d_exact, _ = _round(eng, 10, vec)
    expect = 1.0 - (float(np.mean(ENERGY_PER_MAC_PJ[vec]))
                    / float(ENERGY_PER_MAC_PJ[0]))
    assert 1.0 - d_cfg / d_exact == pytest.approx(expect, rel=1e-6)

    # (c) the log IS the integral: per-step rows sum exactly (same-order
    # float sum) to the lifetime totals, kinds/tokens line up
    kinds = [k for k, *_ in eng.energy_log]
    assert kinds.count("prefill") == 6          # one per request
    assert kinds.count("decode") == eng.n_decode_steps
    assert len(kinds) == 6 + eng.n_decode_steps
    total = sum(t * pj for _, t, pj, _ in eng.energy_log)
    assert total == pytest.approx(eng.mac_energy_pj_per_param, rel=1e-12)
    tokens = sum(t for _, t, *_ in eng.energy_log)
    assert tokens == eng.n_tokens_charged
    assert eng.exact_energy_pj_per_param == pytest.approx(
        tokens * float(ENERGY_PER_MAC_PJ[0]), rel=1e-12)

    # (d) the report is exactly the scaled integral
    rep = eng.energy_report()
    assert rep["modeled_mac_energy_j"] == pytest.approx(
        eng.macs_per_token * total * 1e-12, rel=1e-12)
    assert rep["saving_frac"] == pytest.approx(
        1.0 - eng.mac_energy_pj_per_param / eng.exact_energy_pj_per_param,
        rel=1e-12)


def test_saving_frac_before_any_work_falls_back_to_current_config():
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=2, max_len=32, approx_cfg=16)
    rep = eng.energy_report()               # no jit compile: no work ran
    assert rep["modeled_mac_energy_j"] == 0.0
    assert rep["saving_frac"] == pytest.approx(
        float(MAC_SAVING_FRAC[16]), rel=1e-6)


# --- MoE: dense share charged at the expert-collapsed config ----------------

def test_moe_dense_share_charged_at_expert_collapsed_config(moe_engine):
    eng = moe_engine
    # cfg 11 has a HIGHER index but LOWER measured MRED than cfg 9 —
    # the collapse must rank by error, not index
    cfg_vec = np.asarray([[[9], [11]], [[31], [0]]], np.int32)  # (L, E, G)
    per_mac = eng._energy_pj_mean(cfg_vec)
    # independent oracle: dense share at ops.collapse_expert_cfg
    collapsed = np.stack([np.asarray(collapse_expert_cfg(layer))
                          for layer in cfg_vec])                # (L, G)
    np.testing.assert_array_equal(collapsed, [[11], [0]])
    f = eng._moe_mac_frac
    expect = (f * float(np.mean(ENERGY_PER_MAC_PJ[cfg_vec]))
              + (1 - f) * float(np.mean(ENERGY_PER_MAC_PJ[collapsed])))
    assert 0.0 < f < 1.0
    assert per_mac == pytest.approx(expect, rel=1e-12)
    # the collapse MATTERS: the naive all-cells mean would differ
    assert per_mac != pytest.approx(
        float(np.mean(ENERGY_PER_MAC_PJ[cfg_vec])), rel=1e-6)


def test_moe_engine_charges_energy_log_at_collapsed_rate(moe_engine):
    eng = moe_engine
    cfg_vec = np.asarray([[[9], [11]], [[31], [8]]], np.int32)
    _, _, rows = _round(eng, 0, cfg_vec)
    rate = eng._energy_pj_mean(cfg_vec)
    assert rows
    for kind, tokens, pj, _ in rows:
        assert pj == pytest.approx(rate, rel=1e-12), kind


# --- probes and speculative passes are billed (PR 9 satellite) -------------

def test_probe_decodes_are_billed_and_excluded_from_serve_counters():
    """Shadow probes are real executed exact-config decodes.  Pre-fix
    they never reached ``_count_energy``, so the energy_log — whose
    rows are documented to sum to the report totals — undercounted what
    actually ran.  Now every probe adds a ``kind="probe"`` row at the
    exact rate, the totals still equal the row sum, and the serve-only
    counters (the scheduler's measured-pJ/token window) exclude it."""
    from repro.serve.scheduler import PowerBudgetScheduler
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(10.0, probe_every=1, retune_every=10**9)
    eng = Engine(params, cfg, max_batch=1, approx_cfg=1, scheduler=sched)
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64,
                       max_new_tokens=4))
    eng.run(max_ticks=40)
    rows = list(eng.energy_log)
    probe_rows = [r for r in rows if r[0] == "probe"]
    assert len(probe_rows) == sched.n_probes > 0
    for _, _, pj, _ in probe_rows:           # probes run at the EXACT rate
        assert pj == pytest.approx(float(ENERGY_PER_MAC_PJ[0]),
                                   rel=1e-12)
    # rows still sum exactly to the lifetime totals, probes included
    assert sum(t * pj for _, t, pj, _ in rows) == pytest.approx(
        eng.mac_energy_pj_per_param, rel=1e-12)
    assert sum(t for _, t, *_ in rows) == eng.n_tokens_charged
    # the serve-only view is the same sum MINUS the probe rows
    assert sum(t * pj for k, t, pj, _ in rows if k != "probe") \
        == pytest.approx(eng.serve_mac_energy_pj_per_param, rel=1e-12)
    assert sum(t for k, t, *_ in rows if k != "probe") \
        == eng.n_serve_tokens_charged < eng.n_tokens_charged


def test_speculative_passes_land_in_the_same_accounting():
    """Draft steps bill at the DRAFT config, each verify pass as one
    service-config weight-pass per slot — and the rows keep summing to
    the totals (the spec path uses the same ``_count_energy``)."""
    from repro.serve.speculative import SpecConfig
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=1, max_len=64,
                 spec=SpecConfig(draft_cfg=8, k=2, max_k=2))
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64,
                       max_new_tokens=6))
    eng.run(max_ticks=60)
    rows = list(eng.energy_log)
    kinds = [k for k, *_ in rows]
    assert "spec_draft" in kinds and "spec_verify" in kinds
    assert kinds.count("spec_verify") == eng.n_verify_steps
    for k, _, pj, _ in rows:
        if k == "spec_draft":             # drafts at the draft config
            assert pj == pytest.approx(float(ENERGY_PER_MAC_PJ[8]),
                                       rel=1e-12)
        elif k == "spec_verify":          # verify at the pool config
            assert pj == pytest.approx(float(ENERGY_PER_MAC_PJ[0]),
                                       rel=1e-12)
    assert sum(t * pj for _, t, pj, _ in rows) == pytest.approx(
        eng.mac_energy_pj_per_param, rel=1e-12)
    assert sum(t for _, t, *_ in rows) == eng.n_tokens_charged
    # spec passes ARE service traffic: they stay in the serve counters
    assert eng.serve_mac_energy_pj_per_param == pytest.approx(
        eng.mac_energy_pj_per_param, rel=1e-12)


# --- per-class attribution (PR 10, DESIGN.md §13) ---------------------------

def test_energy_rows_attribute_to_traffic_classes():
    """Every non-probe charge lands on its request's class (pooled
    decode charges split one row per class), per-class rows sum to the
    per-class serve counters, the class counters sum back to the global
    serve counters, and probe rows stay classless."""
    from repro.serve.scheduler import PowerBudgetScheduler
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(10.0, probe_every=2,
                                 retune_every=10**9)
    eng = Engine(params, cfg, max_batch=2, max_len=64, approx_cfg=1,
                 scheduler=sched)
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64,
                       max_new_tokens=4, cls="interactive"))
    eng.submit(Request(rid=1, prompt=np.arange(8) % 64,
                       max_new_tokens=6, cls="batch"))
    eng.run(max_ticks=60)
    rows = list(eng.energy_log)
    classes = {c for _, _, _, c in rows}
    assert {"interactive", "batch", None} <= classes
    for k, _, _, c in rows:               # probes are classless, and
        assert (c is None) == (k == "probe")   # only probes are
    for name in ("interactive", "batch"):
        assert sum(t * pj for _, t, pj, c in rows if c == name) \
            == pytest.approx(eng.serve_energy_by_class[name], rel=1e-12)
        assert sum(t for _, t, _, c in rows if c == name) \
            == eng.serve_tokens_by_class[name]
    # the class split partitions the serve-only integrals exactly
    assert sum(eng.serve_energy_by_class.values()) == pytest.approx(
        eng.serve_mac_energy_pj_per_param, rel=1e-12)
    assert sum(eng.serve_tokens_by_class.values()) \
        == eng.n_serve_tokens_charged


# --- the shared joules/token view ------------------------------------------

def test_energy_per_token_pj_matches_energy_per_mac():
    for c in (0, 8, 31):
        assert energy_per_token_pj(c, 1e6) == pytest.approx(
            1e6 * energy_per_mac_pj(c), rel=1e-12)
    # vector view: equal-weighted mean over cells
    vec = np.asarray([0, 31], np.int32)
    assert energy_per_token_pj(vec) == pytest.approx(
        float(np.mean(ENERGY_PER_MAC_PJ[vec])), rel=1e-12)


def test_engine_energy_mean_delegates_to_power_model(dense_engine):
    vec = np.asarray([8, 16], np.int32)
    assert dense_engine._energy_pj_mean(vec) == pytest.approx(
        energy_per_token_pj(vec, 1.0, dense_engine._moe_mac_frac),
        rel=1e-12)
