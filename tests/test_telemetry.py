"""Property tests for serve/telemetry.py and the budget re-split
(PR 10 satellites, DESIGN.md §13).

The telemetry window and the per-class re-split are the two pure cores
every serving control loop now reads through, so their contracts get
generated-case coverage (via hypothesis or the deterministic
tests/_hypothesis_compat.py shim):

  * ``RollingWindow``: order statistics are permutation-invariant in
    the window contents, memory is bounded at ``maxlen`` (stats equal
    the stats of exactly the last ``maxlen`` pushes), quantiles are
    monotone in q and bracketed by min/max.
  * ``SpikeDetector``: for a fixed history the score — and therefore
    firing — is monotone non-decreasing in the observed magnitude, and
    the detector never fires before ``min_samples`` of history.
  * ``resplit_shares``: the re-split always sums to the global budget
    (1.0 after normalization) and never takes a class below its floor,
    whenever the floors themselves are feasible (sum ≤ 1).
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.scheduler import resplit_shares
from repro.serve.telemetry import (RollingWindow, SpikeDetector, Streak,
                                   ewma)

N_EXAMPLES = 200


def _values(rng: np.random.Generator, n: int) -> list[float]:
    """n floats over a few orders of magnitude (windows see pJ/token
    scales as happily as utilization fractions)."""
    return [float(v) for v in
            rng.uniform(-10.0, 10.0, size=n) * 10.0 ** rng.integers(-2, 3)]


# --- RollingWindow ----------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n=st.integers(min_value=1, max_value=64),
       q=st.floats(min_value=0.0, max_value=1.0))
def test_window_stats_are_permutation_invariant(seed, n, q):
    rng = np.random.default_rng(seed)
    vals = _values(rng, n)
    a, b = RollingWindow(maxlen=64), RollingWindow(maxlen=64)
    for v in vals:
        a.push(v)
    for v in rng.permutation(vals):
        b.push(float(v))
    assert a.median() == b.median()
    assert np.isclose(a.quantile(q), b.quantile(q), rtol=1e-12, atol=0)
    assert np.isclose(a.mean(), b.mean(), rtol=1e-9, atol=1e-12)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       maxlen=st.integers(min_value=1, max_value=16),
       n=st.integers(min_value=1, max_value=80))
def test_window_memory_is_bounded_at_maxlen(seed, maxlen, n):
    rng = np.random.default_rng(seed)
    vals = _values(rng, n)
    w = RollingWindow(maxlen=maxlen)
    for v in vals:
        w.push(v)
    assert len(w) == min(n, maxlen)
    assert len(w._buf) <= maxlen          # the buffer itself is capped
    # the window IS the last maxlen pushes: evicted samples leave no
    # trace in any statistic
    tail = RollingWindow(maxlen=maxlen)
    for v in vals[-maxlen:]:
        tail.push(v)
    assert w.median() == tail.median()
    assert w.mean() == tail.mean()
    assert w.last == vals[-1]


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n=st.integers(min_value=1, max_value=32),
       q1=st.floats(min_value=0.0, max_value=1.0),
       q2=st.floats(min_value=0.0, max_value=1.0))
def test_quantiles_are_monotone_and_bracketed(seed, n, q1, q2):
    rng = np.random.default_rng(seed)
    w = RollingWindow(maxlen=64)
    vals = _values(rng, n)
    for v in vals:
        w.push(v)
    lo, hi = min(q1, q2), max(q1, q2)
    assert w.quantile(lo) <= w.quantile(hi)
    assert min(vals) <= w.quantile(lo) and w.quantile(hi) <= max(vals)
    assert w.quantile(0.0) == min(vals) and w.quantile(1.0) == max(vals)


def test_empty_window_returns_none():
    w = RollingWindow(maxlen=4)
    assert w.median() is None and w.mean() is None and w.last is None
    w.push(3.0)
    w.clear()
    assert w.median() is None and len(w) == 0


# --- SpikeDetector ----------------------------------------------------------

def _warmed_detector(seed: int, n: int) -> SpikeDetector:
    rng = np.random.default_rng(seed)
    d = SpikeDetector(window=32, threshold=4.0, min_scale=0.05,
                      min_samples=8)
    for v in rng.normal(1.0, 0.1, size=n):
        d.observe(float(v))
    return d


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n=st.integers(min_value=8, max_value=40),
       x1=st.floats(min_value=0.0, max_value=1.0),
       x2=st.floats(min_value=0.0, max_value=1.0))
def test_spike_score_and_firing_are_monotone_in_magnitude(seed, n, x1, x2):
    """Against the SAME history, a bigger excursion always scores at
    least as high — so if magnitude m fires, every magnitude > m fires
    (the detector can't be dodged by spiking harder)."""
    lo, hi = 5.0 * min(x1, x2), 5.0 * max(x1, x2)
    d = _warmed_detector(seed, n)
    assert d.score(lo) <= d.score(hi)
    fire_lo = d.score(lo) >= d.threshold
    fire_hi = d.score(hi) >= d.threshold
    assert fire_hi or not fire_lo
    # observe() agrees with score() on identical twin detectors
    twin = _warmed_detector(seed, n)
    assert d.observe(hi) == fire_hi
    assert twin.observe(lo) == fire_lo


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_spike_detector_holds_fire_before_min_samples(seed):
    rng = np.random.default_rng(seed)
    d = SpikeDetector(window=16, threshold=4.0, min_scale=0.05,
                      min_samples=8)
    for _ in range(8):                    # history < min_samples at each
        assert not d.observe(float(rng.normal(0.0, 0.01)))   # pre-push
    assert d.observe(1e9)                 # the 9th sees 8 = min_samples
    assert d.n_spikes == 1


def test_spike_detector_flat_history_needs_min_scale_excursion():
    """A perfectly flat history (MAD 0) must not turn every epsilon
    into a spike: min_scale floors the denominator."""
    d = SpikeDetector(window=16, threshold=4.0, min_scale=0.05,
                      min_samples=4)
    for _ in range(8):
        d.observe(1.0)
    assert not d.observe(1.0 + 0.05 * 3.9)    # under threshold*min_scale
    assert d.observe(1.0 + 0.05 * 4.1)        # over it


# --- Streak / ewma ----------------------------------------------------------

def test_streak_counts_consecutive_events_only():
    s = Streak()
    assert [s.observe(e) for e in (True, True, False, True, True, True)] \
        == [1, 2, 0, 1, 2, 3]
    s.reset()
    assert s.length == 0 and s.observe(True) == 1


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(prev=st.floats(min_value=0.0, max_value=1.0),
       x=st.floats(min_value=0.0, max_value=1.0),
       alpha=st.floats(min_value=0.0, max_value=1.0))
def test_ewma_is_a_convex_combination(prev, x, alpha):
    out = ewma(prev, x, alpha)
    assert min(prev, x) - 1e-12 <= out <= max(prev, x) + 1e-12
    assert ewma(prev, x, 0.0) == prev and ewma(prev, x, 1.0) == x


# --- resplit_shares ---------------------------------------------------------

def _split_case(seed: int, n_cls: int, floor_frac: float):
    rng = np.random.default_rng(seed)
    names = [f"c{i}" for i in range(n_cls)]
    w = rng.uniform(0.05, 1.0, size=n_cls)
    base = {c: float(v) for c, v in zip(names, w / w.sum())}
    # usage mixes hot (>1), cold (<1), starved-silent (missing) classes
    usage = {c: float(rng.uniform(0.0, 3.0)) for c in names
             if rng.random() < 0.8}
    floors = {c: floor_frac * base[c] for c in names}
    return base, usage, floors


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n_cls=st.integers(min_value=1, max_value=6),
       floor_frac=st.floats(min_value=0.0, max_value=0.9))
def test_resplit_sums_to_global_budget_and_respects_floors(
        seed, n_cls, floor_frac):
    base, usage, floors = _split_case(seed, n_cls, floor_frac)
    out = resplit_shares(base, usage, floors)
    assert set(out) == set(base)
    assert np.isclose(sum(out.values()), 1.0, rtol=0, atol=1e-9)
    for c in base:                        # never starved below floor
        assert out[c] >= floors[c] - 1e-12, (c, out, floors)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n_cls=st.integers(min_value=2, max_value=6))
def test_resplit_moves_share_toward_hot_classes(seed, n_cls):
    """Unspent budget flows to starved classes: the unique hot class
    (usage > 1) gains share, every all-cold competitor donates."""
    base, _, floors = _split_case(seed, n_cls, 0.1)
    names = sorted(base)
    hot = names[0]
    usage = {c: 2.0 if c == hot else 0.5 for c in names}
    out = resplit_shares(base, usage, floors)
    assert out[hot] > base[hot]
    assert np.isclose(sum(out.values()), 1.0, rtol=0, atol=1e-9)


def test_resplit_degenerate_zero_usage_scales_floors():
    base = {"a": 0.5, "b": 0.5}
    out = resplit_shares(base, {"a": 0.0, "b": 0.0},
                         {"a": 0.2, "b": 0.3})
    assert np.isclose(sum(out.values()), 1.0, rtol=0, atol=1e-12)
    assert out["a"] == 0.4 and out["b"] == 0.6
