"""Fault tolerance: auto-resume, failure-replay, straggler detection."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.dist.fault_tolerance import (PreemptionHandler, StragglerMonitor,
                                        resilient_train_loop)


def toy_step(state, batch):
    new = {"w": state["w"] + batch["x"].sum(), "count": state["count"] + 1}
    return new, {"loss": jnp.asarray(float(batch["x"].sum()))}


def data(step):
    return {"x": jnp.ones((2,)) * (step + 1)}


def test_loop_runs_to_completion(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.zeros(()), "count": jnp.zeros((), jnp.int32)}
    final, monitor, last = resilient_train_loop(
        train_step=toy_step, state=state, data_iter=data, checkpointer=ck,
        total_steps=10, checkpoint_every=4)
    assert last == 10
    # w = sum_{s=0..9} 2*(s+1) = 110
    assert float(final["w"]) == pytest.approx(110.0)
    assert ck.latest_step() == 10


def test_resume_from_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.zeros(()), "count": jnp.zeros((), jnp.int32)}
    resilient_train_loop(train_step=toy_step, state=state, data_iter=data,
                         checkpointer=ck, total_steps=5, checkpoint_every=5)
    # a "restarted worker" continues from step 5 with fresh python state
    final, _, last = resilient_train_loop(
        train_step=toy_step, state=state, data_iter=data, checkpointer=ck,
        total_steps=10, checkpoint_every=5)
    assert last == 10
    assert float(final["w"]) == pytest.approx(110.0)   # no double counting


def test_failure_replay_preserves_semantics(tmp_path):
    """A step that crashes once is replayed from the last checkpoint —
    the final state matches the no-failure run exactly."""
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.zeros(()), "count": jnp.zeros((), jnp.int32)}
    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("simulated node failure")

    final, _, last = resilient_train_loop(
        train_step=toy_step, state=state, data_iter=data, checkpointer=ck,
        total_steps=10, checkpoint_every=2, fail_injector=injector)
    assert last == 10
    assert float(final["w"]) == pytest.approx(110.0)


def test_too_many_failures_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.zeros(()), "count": jnp.zeros((), jnp.int32)}

    def always_fail(step):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        resilient_train_loop(
            train_step=toy_step, state=state, data_iter=data,
            checkpointer=ck, total_steps=5, max_retries=2,
            fail_injector=always_fail)


class FakeClock:
    """Deterministic injected clock: each call returns the next scripted
    instant.  Two calls bracket each loop step, so step s takes
    ``durations[s]`` seconds exactly — no wall-clock flakiness."""

    def __init__(self, durations):
        self.times = []
        t = 0.0
        for d in durations:
            self.times += [t, t + d]
            t += d
        self.i = 0

    def __call__(self):
        t = self.times[self.i]
        self.i += 1
        return t


def test_loop_straggler_detection_is_deterministic(tmp_path):
    """The loop's step timing comes from the injected clock, so the
    monitor's EWMA and flags are reproducible byte-for-byte."""
    durations = [1.0] * 10
    durations[7] = 9.0                      # the scripted straggler
    ck = Checkpointer(str(tmp_path))
    state = {"w": jnp.zeros(()), "count": jnp.zeros((), jnp.int32)}
    flagged = []
    monitor = StragglerMonitor(threshold=2.0, warmup_steps=2)
    orig_record = monitor.record
    monitor.record = lambda step, sec: orig_record(
        step, sec, on_straggler=lambda s, t: flagged.append((s, t)))
    _, m, last = resilient_train_loop(
        train_step=toy_step, state=state, data_iter=data, checkpointer=ck,
        total_steps=10, checkpoint_every=100, monitor=monitor,
        clock=FakeClock(durations))
    assert last == 10
    assert flagged == [(7, 9.0)]
    assert m.ewma == pytest.approx(1.0)     # outlier not folded in


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(threshold=2.0, warmup_steps=2)
    flagged = []
    for step in range(20):
        t = 1.0 if step != 15 else 5.0
        m.record(step, t, on_straggler=lambda s, sec: flagged.append(s))
    assert flagged == [15]
    assert m.ewma == pytest.approx(1.0, rel=1e-6)   # outlier not folded in


def test_preemption_handler_install_uninstall():
    h = PreemptionHandler()
    h.install()
    assert not h.preempted
    h._handler(15, None)
    assert h.preempted
    h.uninstall()
