"""Paged serving (PR 8 tentpole, DESIGN.md §11).

Contracts:

* **Bit-identity at equal occupancy**: equal-length streams admitted in
  lockstep produce token streams IDENTICAL to the dense engine's (the
  dense decode ropes/writes every row at the one scalar batch position,
  so equal occupancy is exactly where the two semantics coincide).
* **Zero retraces**: one compiled decode executable and one compiled
  prefill executable serve every stream count, every prompt-length mix,
  a live error-config retune, and preemption churn — tables and lengths
  are data, never shapes.
* **Chunked prefill** continuations are allclose to the one-shot
  prefill (einsum vs flash path), and long prompts advance exactly
  ``prefill_chunk`` tokens per tick.
* **Prefix sharing** reuses full prompt blocks (fewer prefill tokens)
  without changing any request's tokens; **preemption** under a starved
  pool requeues and completes everything; the allocator drains to a
  fully-free pool with refcounts == live references after every
  scenario.
* **Snapshot/restore** round-trips the paged state (tables, lengths,
  refcounts, prefix index, prefill progress) mid-stream, bit-identically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.nn import transformer as T
from repro.serve.engine import Engine, Request
from repro.serve.paged_cache import PagedCacheConfig

RNG = np.random.default_rng(0)


def _small_model():
    cfg = T.ModelConfig(name="demo", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return params, cfg


PARAMS, CFG = _small_model()


def _paged(num_blocks, block_size=16, chunk=16, share=False, **kw):
    return PagedCacheConfig(num_blocks=num_blocks, block_size=block_size,
                            prefill_chunk=chunk, share_prefixes=share)


def _drain(engine, reqs, max_ticks=2000):
    for r in reqs:
        assert engine.submit(r)
    done = engine.run(max_ticks=max_ticks)
    assert all(r.status == "done" for r in done), \
        [(r.rid, r.status) for r in done]
    return {r.rid: list(r.tokens) for r in done}


# --- bit-identity at equal occupancy ---------------------------------------

def test_paged_bit_identical_to_dense_at_equal_occupancy():
    prompts = [RNG.integers(1, 64, size=16) for _ in range(4)]
    dense = Engine(PARAMS, CFG, max_batch=4, max_len=64, prefill_pad=16)
    paged = Engine(PARAMS, CFG, max_batch=4, max_len=64,
                   paged=_paged(2 + 16))
    d = _drain(dense, [Request(rid=i, prompt=p, max_new_tokens=8)
                       for i, p in enumerate(prompts)])
    q = _drain(paged, [Request(rid=i, prompt=p, max_new_tokens=8)
                       for i, p in enumerate(prompts)])
    assert d == q
    paged.allocator.check_consistency(paged._slot_blocks)
    assert paged.allocator.free_blocks() == 16
    assert paged._decode._cache_size() == 1
    assert paged._prefill._cache_size() == 1


def test_solo_stream_bit_identical_to_dense():
    prompt = RNG.integers(1, 64, size=11)
    dense = Engine(PARAMS, CFG, max_batch=1, max_len=64, prefill_pad=16)
    paged = Engine(PARAMS, CFG, max_batch=1, max_len=64,
                   paged=_paged(2 + 4))
    d = _drain(dense, [Request(rid=0, prompt=prompt, max_new_tokens=10)])
    q = _drain(paged, [Request(rid=0, prompt=prompt, max_new_tokens=10)])
    assert d == q


# --- zero retraces ---------------------------------------------------------

def test_one_executable_serves_stream_and_length_churn():
    eng = Engine(PARAMS, CFG, max_batch=8, max_len=64,
                 paged=_paged(2 + 32))
    rid = 0
    for wave, lens in enumerate([(5,), (9, 12), (16, 3, 30, 21),
                                 (7, 7, 7, 7, 7, 7, 7, 7)]):
        if wave == 2:
            eng.set_approx_cfg(31)          # live retune mid-sweep
        reqs = []
        for n in lens:
            reqs.append(Request(rid=rid, prompt=RNG.integers(1, 64, size=n),
                                max_new_tokens=4))
            rid += 1
        _drain(eng, reqs)
    assert eng._decode._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    assert eng._prefill_chunk._cache_size() <= 1   # only len-30 used it
    eng.allocator.check_consistency(eng._slot_blocks)
    assert eng.allocator.free_blocks() == 32


def test_dense_prefill_pad_kills_per_length_retrace():
    """Satellite 1: the dense engine's prefill used to compile once per
    raw prompt length; padded to the chunk boundary it compiles ONCE."""
    eng = Engine(PARAMS, CFG, max_batch=4, max_len=64, prefill_pad=16)
    _drain(eng, [Request(rid=i, prompt=RNG.integers(1, 64, size=n),
                         max_new_tokens=3)
                 for i, n in enumerate((3, 5, 9, 14))])
    assert eng._prefill._cache_size() == 1
    assert eng._decode._cache_size() == 1


# --- chunked prefill -------------------------------------------------------

def test_chunked_prefill_advances_chunk_per_tick():
    eng = Engine(PARAMS, CFG, max_batch=2, max_len=64,
                 paged=_paged(2 + 8, block_size=8, chunk=16))
    eng.submit(Request(rid=0, prompt=RNG.integers(1, 64, size=40),
                       max_new_tokens=8))
    seen = []
    for _ in range(4):
        eng.step()
        seen.append(int(eng.seq_lens[0]))
    # two chunk ticks (16, 32), then the 8-token remainder completes and
    # the slot joins decode THAT tick (40 + 1), then pure decode
    assert seen == [16, 32, 41, 42], seen
    eng.run()


def test_chunk_continuation_matches_one_shot_prefill():
    """The continuation executable (einsum attention over paged K/V) is
    allclose to running the whole prompt through stock prefill."""
    prompt = RNG.integers(1, 64, size=40)
    one = Engine(PARAMS, CFG, max_batch=1, max_len=64,
                 paged=_paged(2 + 4, block_size=16, chunk=64))
    chunked = Engine(PARAMS, CFG, max_batch=1, max_len=64,
                     paged=_paged(2 + 4, block_size=16, chunk=16))
    a = _drain(one, [Request(rid=0, prompt=prompt, max_new_tokens=8)])
    b = _drain(chunked, [Request(rid=0, prompt=prompt, max_new_tokens=8)])
    # greedy argmax streams agree even though the two prefill paths
    # reduce in different orders
    assert a == b


# --- prefix sharing --------------------------------------------------------

def test_prefix_sharing_reuses_blocks_and_preserves_tokens():
    common = RNG.integers(1, 64, size=24)
    tails = [RNG.integers(1, 64, size=6) for _ in range(3)]

    def run(share):
        eng = Engine(PARAMS, CFG, max_batch=4, max_len=64,
                     paged=_paged(2 + 30, block_size=8, chunk=16,
                                  share=share))
        eng.submit(Request(rid=0, prompt=np.concatenate([common, tails[0]]),
                           max_new_tokens=12))
        for _ in range(4):      # first stream registers its full blocks
            eng.step()
        for i, tail in enumerate(tails[1:], start=1):
            eng.submit(Request(rid=i, prompt=np.concatenate([common, tail]),
                               max_new_tokens=6))
        done = eng.run()
        assert all(r.status == "done" for r in done)
        eng.allocator.check_consistency(eng._slot_blocks)
        assert eng.allocator.free_blocks() == 30
        return eng, {r.rid: list(r.tokens) for r in done}

    sharing, toks_share = run(True)
    isolated, toks_iso = run(False)
    assert toks_share == toks_iso          # sharing never changes output
    assert sharing.n_shared_blocks > 0
    assert isolated.n_shared_blocks == 0
    assert sharing.n_prefill_tokens <= 0.7 * isolated.n_prefill_tokens


# --- preemption ------------------------------------------------------------

def test_preemption_requeues_and_completes_on_starved_pool():
    eng = Engine(PARAMS, CFG, max_batch=3, max_len=64,
                 paged=_paged(2 + 9, block_size=8, chunk=16))
    done = _drain(eng, [Request(rid=i, prompt=RNG.integers(1, 64, size=12),
                                max_new_tokens=24) for i in range(3)])
    assert eng.n_preempted > 0
    assert all(len(t) == 24 for t in done.values())
    eng.allocator.check_consistency(eng._slot_blocks)
    assert eng.allocator.free_blocks() == 9
    assert eng._decode._cache_size() == 1


def test_preempted_stream_matches_unstarved_run():
    """Preemption-by-recompute replays the exact prefix, so the resumed
    stream's tokens equal an uncontended run's."""
    prompts = [RNG.integers(1, 64, size=12) for _ in range(3)]
    starved = Engine(PARAMS, CFG, max_batch=3, max_len=64,
                     paged=_paged(2 + 9, block_size=8, chunk=16))
    roomy = Engine(PARAMS, CFG, max_batch=3, max_len=64,
                   paged=_paged(2 + 24, block_size=8, chunk=16))
    a = _drain(starved, [Request(rid=i, prompt=p, max_new_tokens=20)
                         for i, p in enumerate(prompts)])
    b = _drain(roomy, [Request(rid=i, prompt=p, max_new_tokens=20)
                       for i, p in enumerate(prompts)])
    assert starved.n_preempted > 0 and roomy.n_preempted == 0
    # per-row decode depends only on the row's own state, so requeued
    # streams reproduce their tokens exactly
    assert a == b


# --- backpressure ----------------------------------------------------------

def test_backpressure_reports_free_block_watermark():
    eng = Engine(PARAMS, CFG, max_batch=2, max_len=64,
                 paged=_paged(2 + 8, block_size=8, chunk=16))
    bp0 = eng.backpressure
    assert bp0["kv_free_blocks"] == 8 and bp0["kv_utilization"] == 0.0
    eng.submit(Request(rid=0, prompt=RNG.integers(1, 64, size=16),
                       max_new_tokens=4))
    eng.step()
    bp = eng.backpressure
    assert bp["kv_free_blocks"] < 8 and bp["kv_utilization"] > 0.0
    eng.run()


# --- snapshot / restore ----------------------------------------------------

def test_paged_snapshot_restore_resumes_bit_identically(tmp_path):
    prompts = [RNG.integers(1, 64, size=n) for n in (24, 40, 9)]

    def fresh(ck=None):
        eng = Engine(PARAMS, CFG, max_batch=3, max_len=64,
                     paged=_paged(2 + 12, block_size=8, chunk=16,
                                  share=True),
                     checkpointer=ck)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=10))
        return eng

    ref = fresh()
    baseline = {r.rid: list(r.tokens) for r in ref.run()}

    ck = Checkpointer(str(tmp_path / "snap"))
    eng = fresh(ck)
    for _ in range(4):          # stop mid-prefill AND mid-decode
        eng.step()
    eng.save_snapshot()

    heir = Engine(PARAMS, CFG, max_batch=3, max_len=64,
                  paged=_paged(2 + 12, block_size=8, chunk=16, share=True),
                  checkpointer=ck)
    heir.restore_snapshot()
    assert np.array_equal(heir.block_tables, eng.block_tables)
    assert np.array_equal(heir.seq_lens, eng.seq_lens)
    assert np.array_equal(heir.allocator.refcounts, eng.allocator.refcounts)
    assert heir._prefill_progress.keys() == eng._prefill_progress.keys()
    heir.allocator.check_consistency(heir._slot_blocks)
    resumed = {r.rid: list(r.tokens) for r in heir.run()}
    assert resumed == baseline
    heir.allocator.check_consistency(heir._slot_blocks)
    assert heir.allocator.free_blocks() == 12
