"""Shared test fixtures. NOTE: no XLA_FLAGS here — unit tests must see
the real single-CPU device; multi-device tests spawn subprocesses.

Sanitizer mode: the whole suite runs under
``jax_numpy_rank_promotion='raise'`` — every mixed-rank elementwise op
in src/ spells its broadcast out explicitly (repro.core.quantization.
expand_left), so a silent left-padding broadcast is a bug, not a
convenience.  ``REPRO_DEBUG_NANS=1`` additionally turns on
``jax_debug_nans`` (opt-in: it disables some fusions and slows the
suite, so it is not the default)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import _hypothesis_compat  # noqa: F401  (installs a hypothesis stub when absent)

jax.config.update("jax_numpy_rank_promotion", "raise")
if os.environ.get("REPRO_DEBUG_NANS") == "1":
    jax.config.update("jax_debug_nans", True)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced_devices(code: str, n_devices: int = 8, timeout=560):
    """Run `code` in a subprocess with `n_devices` forced host CPU
    devices (jax freezes topology at backend init, so multi-device
    semantics can never run in the main test process).  XLA_FLAGS is
    OVERWRITTEN, not appended: the subprocess must be hermetic — an
    inherited force-device flag would conflict with ours.  Failures
    propagate via the exit code + stderr."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # subprocesses inherit the suite's strict-broadcast sanitizer
    env["JAX_NUMPY_RANK_PROMOTION"] = "raise"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
