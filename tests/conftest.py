"""Shared test fixtures. NOTE: no XLA_FLAGS here — unit tests must see
the real single-CPU device; multi-device tests spawn subprocesses."""
import numpy as np
import pytest

import _hypothesis_compat  # noqa: F401  (installs a hypothesis stub when absent)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
