"""Shared test fixtures. NOTE: no XLA_FLAGS here — unit tests must see
the real single-CPU device; multi-device tests spawn subprocesses."""
import os
import subprocess
import sys

import numpy as np
import pytest

import _hypothesis_compat  # noqa: F401  (installs a hypothesis stub when absent)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_forced_devices(code: str, n_devices: int = 8, timeout=560):
    """Run `code` in a subprocess with `n_devices` forced host CPU
    devices (jax freezes topology at backend init, so multi-device
    semantics can never run in the main test process).  XLA_FLAGS is
    OVERWRITTEN, not appended: the subprocess must be hermetic — an
    inherited force-device flag would conflict with ours.  Failures
    propagate via the exit code + stderr."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, \
        f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
