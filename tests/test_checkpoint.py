"""Checkpointer: atomic roundtrip, retention, resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(100, t, metadata={"loss": 1.25})
    restored, meta = ck.restore(t)
    assert meta["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last_k=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree())
    assert ck.latest_step() == 4
    assert ck.steps() == [3, 4]          # gc kept last 2


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(5, tree())
    ck.wait()
    assert ck.latest_step() == 5
    restored, _ = ck.restore(tree())
    assert float(restored["params"]["w"][0, 0]) == 0.0


def test_no_tmp_left_behind(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(9, tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree())
    bad = tree()
    bad["params"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(AssertionError):
        ck.restore(bad)


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last_k=5)
    t = tree()
    ck.save(1, t)
    t2 = jax.tree.map(lambda x: x + 1, t)
    ck.save(2, t2)
    r1, _ = ck.restore(t, step=1)
    assert float(r1["step"]) == 7.0
    r2, _ = ck.restore(t, step=2)
    assert float(r2["step"]) == 8.0


def test_elastic_restore_onto_sharding(tmp_path):
    """Checkpoints are mesh-independent: restore with explicit shardings
    (single-device here; the multi-device path is exercised in
    test_multidevice.py)."""
    ck = Checkpointer(str(tmp_path))
    t = tree()
    ck.save(3, t)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = ck.restore(t, shardings=sh)
    assert restored["params"]["w"].sharding == sh["params"]["w"]
