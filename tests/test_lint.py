"""repro-lint's own test coverage (DESIGN.md §9).

The fixture corpus in tests/fixtures/lint/ holds one good/bad pair per
rule; the three bad fixtures marked "historical" reproduce real bugs
from the repo's past — the PR 3 rescale reassociation, the PR 4
wall-clock default, and a traced-config-in-shape retrace — so the
linter can never silently stop catching them.
"""
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from tools.lint import lint_file, lint_paths            # noqa: E402
from tools.lint.engine import SUPPRESS_RE, FileContext  # noqa: E402

FIXTURES = REPO / "tests" / "fixtures" / "lint"

# fixture stem -> rule id every finding must carry
BAD = {
    "bad_trace_safety": "trace-safety",
    "bad_cfg_shape": "cfg-shape",                 # historical: retrace
    "bad_single_rounding": "single-rounding",     # historical: PR 3
    "bad_bounded_state": "bounded-state",
    "bad_resilience_tick": "bounded-state",       # PR 7 chaos tick path
    "bad_injected_clock": "injected-clock",       # historical: PR 4
    "bad_pallas_hygiene": "pallas-hygiene",
    "bad_table_shape": "cfg-shape",               # PR 8 paged-KV operands
    "bad_spec_shape": "cfg-shape",                # PR 9 speculative knobs
    "bad_telemetry_shape": "cfg-shape",           # PR 10 telemetry/budgets
    "bad_telemetry_state": "bounded-state",       # PR 10 window buffers
}
GOOD = ["good_trace_safety", "good_cfg_shape", "good_single_rounding",
        "good_bounded_state", "good_resilience_tick",
        "good_injected_clock", "good_pallas_hygiene",
        "good_suppression", "good_table_shape", "good_spec_shape",
        "good_telemetry_shape", "good_telemetry_state"]


@pytest.mark.parametrize("stem,rule_id", sorted(BAD.items()))
def test_bad_fixture_flags_its_rule(stem, rule_id):
    findings = lint_file(FIXTURES / f"{stem}.py")
    assert findings, f"{stem} produced no findings"
    assert {f.rule for f in findings} == {rule_id}, findings


@pytest.mark.parametrize("stem", GOOD)
def test_good_fixture_is_clean(stem):
    assert lint_file(FIXTURES / f"{stem}.py") == []


def test_historical_bugs_each_have_a_fixture():
    """The three bugs that motivated repro-lint stay reproduced."""
    rescale = (FIXTURES / "bad_single_rounding.py").read_text()
    assert "(acc * x_scale) * w_scale" in rescale
    clock = (FIXTURES / "bad_injected_clock.py").read_text()
    assert "default_factory=time.time" in clock
    shape = (FIXTURES / "bad_cfg_shape.py").read_text()
    assert "jnp.zeros((cfg, 4))" in shape


@pytest.mark.parametrize("stem", sorted(BAD))
def test_cli_exits_nonzero_on_bad_fixture(stem):
    r = subprocess.run(
        [sys.executable, "-m", "tools.lint",
         str(FIXTURES / f"{stem}.py")],
        capture_output=True, text=True, cwd=REPO)
    assert r.returncode != 0, r.stdout


def test_cli_exits_zero_on_good_fixtures():
    r = subprocess.run(
        [sys.executable, "-m", "tools.lint"]
        + [str(FIXTURES / f"{s}.py") for s in GOOD],
        capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stdout


def test_reasonless_suppression_is_a_finding_and_does_not_suppress():
    findings = lint_file(FIXTURES / "bad_suppression.py")
    rules = sorted(f.rule for f in findings)
    assert rules == ["injected-clock", "suppression"], findings


def test_suppression_regex_accepts_dash_variants():
    for sep in ("—", "--", ":"):
        m = SUPPRESS_RE.search(f"x = 1  # repro-lint: disable=foo {sep} why")
        assert m and m.group(2) == "why", sep
    m = SUPPRESS_RE.search("x = 1  # repro-lint: disable=foo")
    assert m and m.group(2) is None


def test_scope_pragma_overrides_path(tmp_path):
    f = tmp_path / "anywhere.py"
    f.write_text("# repro-lint: scope=src/repro/serve/x.py\n"
                 "import time\nt = time.time()\n")
    assert {x.rule for x in lint_file(f)} == {"injected-clock"}
    g = tmp_path / "unscoped.py"
    g.write_text("import time\nt = time.time()\n")
    assert lint_file(g) == []          # out of every rule's path scope


def test_src_lints_clean_with_reasoned_suppressions():
    findings = lint_paths([REPO / "src"])
    assert findings == [], "\n".join(map(str, findings))
    # every suppression in src/ carries a reason
    for path in sorted((REPO / "src").rglob("*.py")):
        ctx = FileContext(path)
        for ln, (_ids, reason) in ctx.suppressions.items():
            assert reason, f"{ctx.rel}:{ln} reasonless suppression"


def test_docs_group_clean_on_repo():
    from tools.lint import docs_rules
    assert docs_rules.run() == []


def test_retrace_sentinel_passes():
    from tools.lint import retrace
    assert retrace.run() == []
