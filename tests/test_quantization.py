"""Quantizer + operand-truncation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantization import (QMAX, QTensor, fake_quant, quantize,
                                     quantize_np, truncate_operand_lsb)


def test_roundtrip_error_bound(rng):
    x = rng.normal(size=(64, 32)).astype(np.float32)
    qt = quantize(jnp.asarray(x))
    err = np.abs(np.asarray(qt.dequantize()) - x)
    assert err.max() <= float(qt.scale) * 0.5 + 1e-7


def test_per_channel_beats_per_tensor(rng):
    x = rng.normal(size=(64, 8)).astype(np.float32)
    x[:, 3] *= 100.0    # one hot channel
    per_t = np.abs(np.asarray(quantize(jnp.asarray(x)).dequantize()) - x)
    per_c = np.abs(np.asarray(quantize(jnp.asarray(x), axis=1).dequantize()) - x)
    assert per_c[:, :3].max() < per_t[:, :3].max()


def test_numpy_jax_quantizers_agree(rng):
    x = rng.normal(size=(16, 16)).astype(np.float32)
    qn, sn = quantize_np(x)
    qj = quantize(jnp.asarray(x))
    assert np.array_equal(qn, np.asarray(qj.values))
    assert sn == pytest.approx(float(qj.scale), rel=1e-6)


def test_values_in_signed_magnitude_range(rng):
    x = rng.normal(size=(100,)).astype(np.float32) * 1e3
    q = np.asarray(quantize(jnp.asarray(x)).values)
    assert q.min() >= -QMAX and q.max() <= QMAX   # -128 never produced


def test_fake_quant_straight_through_grad():
    x = jnp.linspace(-1, 1, 32)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v)))(x)
    assert np.allclose(np.asarray(g), 1.0)


@given(depth=st.integers(0, 6), gate=st.sampled_from([0, 16, 32, 64]),
       rtn=st.booleans())
@settings(max_examples=60, deadline=None)
def test_truncation_properties(depth, gate, rtn):
    v = jnp.arange(-127, 128, dtype=jnp.int8)
    t = np.asarray(truncate_operand_lsb(v, depth, gate, rtn)).astype(np.int64)
    orig = np.arange(-127, 128)
    assert np.abs(t).max() <= 127                     # stays in int8 range
    assert np.all(np.sign(t) * np.sign(orig) >= 0)    # sign never flips
    assert np.abs(t - orig).max() <= (1 << depth) if depth else (t == orig).all()
    if gate > 0:
        small = np.abs(orig) < gate
        assert np.array_equal(t[small], orig[small])  # gated values exact
    if depth > 0:
        big = np.abs(orig) >= max(gate, 1)
        trunc_mags = np.abs(t[big])
        in_range = trunc_mags < 127
        assert np.all(trunc_mags[in_range] % (1 << depth) == 0)


def test_qtensor_is_pytree():
    qt = quantize(jnp.ones((4, 4)))
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 2
    rebuilt = jax.tree.map(lambda x: x, qt)
    assert isinstance(rebuilt, QTensor)


def test_qtensor_reshape():
    import numpy as np
    import pytest
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 8))
    # per-tensor scale: any reshape is valid
    qt = quantize(x)
    r = qt.reshape(4, 16)
    assert r.values.shape == (4, 16) and r.axis is None
    np.testing.assert_array_equal(np.asarray(r.values),
                                  np.asarray(qt.values).reshape(4, 16))
    # last-axis (channel) scale: reshape must preserve the channel dim
    qt2 = quantize(x.reshape(8, 8), axis=1)
    r2 = qt2.reshape(2, 4, 8)
    assert r2.axis == 2 and r2.values.shape == (2, 4, 8)
    with pytest.raises(AssertionError):
        qt2.reshape(4, 16)          # would mix channels across scales


def _expert_bank(e=3, k=8, n=16, seed=4):
    """Stacked (E, K, N) bank with (E, N) per-expert per-channel scales
    (the quantize_lm_params / moe.quantize_expert_bank layout)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (e, k, n))
    return w, jax.vmap(lambda m: quantize(m, axis=1))(w)


def test_qtensor_stacked_bank_take():
    import numpy as np
    w, bank = _expert_bank()
    assert bank.values.shape == (3, 8, 16) and bank.scale.shape == (3, 16)
    for i in range(3):
        one = bank.take(i)
        ref = quantize(w[i], axis=1)
        np.testing.assert_array_equal(np.asarray(one.values),
                                      np.asarray(ref.values))
        np.testing.assert_array_equal(np.asarray(one.scale),
                                      np.asarray(ref.scale))
        assert one.axis == ref.axis
    # traced index works too (expert banks are gathered in-trace)
    one = bank.take(jnp.asarray(1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(one.values),
                                  np.asarray(bank.values[1]))


def test_qtensor_stacked_bank_dequantize_and_reshape():
    import numpy as np
    import pytest
    w, bank = _expert_bank()
    # dequantize understands the stacked layout directly
    deq = bank.dequantize()
    ref = jnp.stack([bank.take(i).dequantize() for i in range(3)])
    np.testing.assert_array_equal(np.asarray(deq), np.asarray(ref))
    # reshape may split/merge middle dims while keeping the stacked
    # leading axis and the trailing channel axis
    r = bank.reshape(3, 2, 4, 16)
    assert r.values.shape == (3, 2, 4, 16) and r.axis == 2
    np.testing.assert_array_equal(
        np.asarray(r.dequantize().reshape(3, 8, 16)), np.asarray(deq))
    with pytest.raises(AssertionError):
        bank.reshape(3, 16, 8)        # would mix channels across scales
    with pytest.raises(AssertionError):
        bank.reshape(24, 16)          # would mix experts across scales
