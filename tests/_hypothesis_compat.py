"""Fallback ``hypothesis`` shim so the tier-1 suite collects everywhere.

Four test modules use hypothesis property tests.  When the real package
is installed (see requirements-dev.txt) this module is a no-op and the
genuine shrinking/fuzzing machinery runs.  When it is absent — the
pinned CI/container image does not ship it — importing this module
installs a minimal deterministic stand-in into ``sys.modules`` BEFORE the
test modules import it (conftest.py imports us at collection time):

  * ``strategies.integers/sampled_from/booleans`` draw from a seeded
    ``random.Random`` — deterministic per test, reproducible across runs;
  * ``@given(**strategies)`` turns the test into a loop over
    ``max_examples`` drawn examples (first failure raises with the
    drawn arguments in the message);
  * ``@settings(...)`` records max_examples/deadline on the function.

This trades shrinking and coverage-guided generation for zero external
dependencies; the property assertions themselves run unchanged.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

try:                                    # real hypothesis wins when present
    import hypothesis                   # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value=None, max_value=None):
    lo = -(2 ** 31) if min_value is None else min_value
    hi = 2 ** 31 - 1 if max_value is None else max_value
    return _Strategy(lambda rng: rng.randint(lo, hi))


def _sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def _floats(min_value=0.0, max_value=1.0, **_):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn
    return deco


def _given(*arg_strategies, **kw_strategies):
    assert not arg_strategies, \
        "shim supports keyword strategies only (as the test suite uses)"

    def deco(fn):
        max_examples = getattr(fn, "_stub_settings",
                               {}).get("max_examples", DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(f"repro-shim:{fn.__module__}.{fn.__name__}")
            for i in range(max_examples):
                drawn = {name: s.example_from(rng)
                         for name, s in kw_strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: {drawn}"
                    ) from e

        # hide the strategy-filled parameters from pytest (it would
        # otherwise look for fixtures named like them)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in kw_strategies])
        del wrapper.__wrapped__
        return wrapper
    return deco


def _install_stub():
    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    mod.assume = lambda cond: None
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.sampled_from = _sampled_from
    st.booleans = _booleans
    st.floats = _floats
    mod.strategies = st
    mod.__is_repro_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


if not HAVE_HYPOTHESIS:
    _install_stub()
