"""Power-model calibration against the paper's published endpoints."""
import numpy as np
import pytest

from repro.core import power_model as pm


def test_exact_network_power():
    assert pm.network_power_mw(0) == pytest.approx(5.55, abs=1e-6)


def test_min_accuracy_network_power():
    assert pm.network_power_mw(31) == pytest.approx(4.81, abs=0.005)


def test_max_network_improvement():
    assert pm.network_improvement_pct(31) == pytest.approx(13.33, abs=0.05)


def test_max_mac_saving():
    assert pm.mac_saving(31) == pytest.approx(0.4436, abs=1e-4)


def test_max_neuron_saving():
    neuron_saving = 1 - pm.neuron_power_mw(31) / pm.neuron_power_mw(0)
    assert neuron_saving == pytest.approx(0.2478, abs=1e-3)


def test_saving_monotone_in_config_index():
    s = pm.MAC_SAVING_FRAC
    assert s[0] == 0.0
    assert np.all(np.diff(s[1:]) >= -1e-12)


def test_power_bounds():
    for c in range(32):
        assert pm.NETWORK_POWER_MIN_MW - 1e-6 <= pm.network_power_mw(c) \
            <= pm.NETWORK_POWER_EXACT_MW + 1e-6


def test_mac_energy_consistent_with_power():
    # E = P/f at the paper's 100 MHz, 1 MAC/cycle
    e = pm.MAC_POWER_EXACT_MW * 1e-3 / pm.PAPER_CLOCK_HZ * 1e12
    assert pm.MAC_ENERGY_EXACT_PJ == pytest.approx(e)


def test_model_energy_scaling():
    assert pm.model_energy_mj(1e9, 0) > pm.model_energy_mj(1e9, 31)
