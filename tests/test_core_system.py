"""End-to-end paper-system tests: quantized MLP, hardware simulator,
dynamic power controller, data pipelines, serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import DynamicPowerController, select_uniform_config
from repro.core.hw_sim import simulate
from repro.core.power_model import MAC_SAVING_FRAC
from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
from repro.data.synthetic_mnist import load_mnist, reduce_features
from repro.nn import mlp_paper as M

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def trained_mlp():
    """Small but real training run on procedural MNIST."""
    data = load_mnist(n_train=1500, n_test=400, seed=0)
    from repro.train.optimizer import adamw, apply_updates
    params = M.init_params(KEY)
    opt = adamw(lr=3e-3)
    state = opt.init(params)

    def loss_fn(p, x, y):
        lp = jax.nn.log_softmax(M.apply_float(p, x))
        return -jnp.take_along_axis(lp, y[:, None], axis=1).mean()

    @jax.jit
    def step(p, s, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    rng = np.random.default_rng(0)
    for epoch in range(20):
        idx = rng.permutation(len(data.train_x))
        for i in range(0, len(idx) - 127, 128):
            b = idx[i:i + 128]
            params, state, _ = step(params, state,
                                    jnp.asarray(data.train_x[b]),
                                    jnp.asarray(data.train_y[b]))
    qm = M.QuantizedMLP.from_float(params, data.train_x[:500])
    return params, qm, data


def test_quantization_preserves_accuracy(trained_mlp):
    params, qm, data = trained_mlp
    float_acc = float((np.argmax(np.asarray(M.apply_float(
        params, jnp.asarray(data.test_x))), axis=1) == data.test_y).mean())
    q_acc = qm.accuracy(data.test_x, data.test_y, config=0)
    assert q_acc > 0.5                       # the model actually works
    assert abs(float_acc - q_acc) < 0.05     # int8 pipeline tracks float


def test_paper_claim_accuracy_drop_below_1pct(trained_mlp):
    """The paper's headline: worst-config accuracy drop < 1% (0.92%)."""
    _, qm, data = trained_mlp
    acc0 = qm.accuracy(data.test_x, data.test_y, config=0)
    acc31 = qm.accuracy(data.test_x, data.test_y, config=31)
    assert acc0 - acc31 < 0.02   # small test set: allow 2x the paper's 0.92%


def test_accumulator_fits_21_bits(trained_mlp):
    _, qm, data = trained_mlp
    assert qm.max_abs_accumulator(data.test_x[:200]) < 2 ** 20


def test_operand_vs_lut_method_close(trained_mlp):
    """TPU operand-truncation adaptation tracks the bit-exact ASIC model
    at the network level (argmax agreement)."""
    _, qm, data = trained_mlp
    x = data.test_x[:200]
    # operand truncation is a *different* approximation family than
    # product truncation: exact at cfg 0, high agreement at mild configs,
    # and divergence grows with depth (t is split across both operands,
    # so deep configs overshoot the product-truncation error — DESIGN §2)
    for cfg, floor in ((0, 0.999), (8, 0.85), (31, 0.7)):
        p_lut = qm.predict(x, config=cfg, method="lut")
        p_op = qm.predict(x, config=cfg, method="operand")
        agree = float((p_lut == p_op).mean())
        assert agree > floor, (cfg, agree)


def test_hw_sim_equivalence_and_cycles(trained_mlp):
    _, qm, data = trained_mlp
    imgs = data.test_x[:25]
    res = simulate(qm, imgs, config=0)
    vec = qm.predict(imgs, config=0)
    assert (res.predictions == vec).all()
    # cycle model: per image 3x62 (hidden states) + 30 + 1 (max circuit)
    assert res.cycles == 25 * (3 * 62 + 30 + 1) + 1
    assert res.mac_ops == 25 * (3 * 62 + 30) * 10


def test_hw_sim_power_matches_paper(trained_mlp):
    _, qm, data = trained_mlp
    r0 = simulate(qm, data.test_x[:10], config=0)
    r31 = simulate(qm, data.test_x[:10], config=31)
    assert r0.avg_power_mw == pytest.approx(5.55, abs=0.05)
    assert r31.avg_power_mw == pytest.approx(4.81, abs=0.05)


def test_uniform_controller(trained_mlp):
    _, qm, data = trained_mlp
    x, y = data.test_x[:300], data.test_y[:300]
    best, accs = select_uniform_config(
        lambda c: qm.accuracy(x, y, c), budget=0.02,
        configs=[0, 1, 8, 16, 24, 31])
    assert best in (0, 1, 8, 16, 24, 31)
    assert accs[0] - accs[best] <= 0.02
    assert MAC_SAVING_FRAC[best] >= 0.0


def test_greedy_controller_allocates_within_budget():
    """Synthetic sensitivity model: layer A cheap to approximate, layer B
    expensive — the controller should push A harder than B."""
    sens = {"A": 0.001, "B": 0.05}

    def loss_fn(assignment):
        return sum(sens[l] * (MAC_SAVING_FRAC[c] / MAC_SAVING_FRAC[31])
                   for l, c in assignment.items() if c > 0)

    ctrl = DynamicPowerController(["A", "B"], loss_fn,
                                  probe_configs=(8, 16, 31))
    ctrl.calibrate()
    assignment = ctrl.allocate(loss_budget=0.01)
    assert assignment["A"] >= assignment["B"]
    assert loss_fn(assignment) <= 0.01 + 1e-9


# --- data pipelines ---------------------------------------------------------

def test_synthetic_lm_deterministic_and_shardable():
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=16, global_batch=8)
    full = SyntheticLM(cfg).batch(3)
    shards = [SyntheticLM(cfg, shard=i, num_shards=4).batch(3)
              for i in range(4)]
    rebuilt = np.zeros_like(full["tokens"])
    for i, sh in enumerate(shards):
        rebuilt[i::4] = sh["tokens"]
    np.testing.assert_array_equal(rebuilt, full["tokens"])
    again = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    assert full["tokens"].max() < 64
    np.testing.assert_array_equal(full["labels"], full["tokens"] * 0
                                  + full["labels"])


def test_mnist_features_shape_and_determinism():
    d1 = load_mnist(n_train=50, n_test=20, seed=3)
    d2 = load_mnist(n_train=50, n_test=20, seed=3)
    assert d1.train_x.shape == (50, 62)
    np.testing.assert_array_equal(d1.train_x, d2.train_x)
    # random-projection features may be negative (signed-magnitude ok)
    assert np.isfinite(d1.train_x).all()
    assert len(np.unique(d1.train_y)) == 10


def test_reduce_features_is_linear():
    rng = np.random.default_rng(0)
    a = rng.random((4, 28, 28)).astype(np.float32)
    b = rng.random((4, 28, 28)).astype(np.float32)
    fa, fb = reduce_features(a), reduce_features(b)
    fab = reduce_features(a + b)
    np.testing.assert_allclose(fab, fa + fb, rtol=1e-4, atol=1e-5)


# --- serving engine ---------------------------------------------------------

def test_engine_continuous_batching():
    from repro.nn import transformer as T
    from repro.serve.engine import Engine, Request
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(KEY, cfg)
    eng = Engine(params, cfg, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(5):   # more requests than slots -> queueing
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, 64, size=8 + rid),
                           max_new_tokens=6))
    done = eng.run(max_ticks=200)
    assert len(done) == 5
    for r in done:
        assert len(r.tokens) == 6
        assert all(0 <= t < 64 for t in r.tokens)
    rep = eng.energy_report()
    assert rep["modeled_mac_energy_j"] <= rep["exact_mac_energy_j"]


def test_engine_approx_cfg_runs():
    from repro.nn import transformer as T
    from repro.serve.engine import Engine, Request
    cfg = T.ModelConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(KEY, cfg)
    eng = Engine(params, cfg, max_batch=1, max_len=32, approx_cfg=31)
    eng.submit(Request(rid=0, prompt=np.arange(8) % 64, max_new_tokens=4))
    done = eng.run(max_ticks=50)
    assert len(done) == 1 and len(done[0].tokens) == 4
    assert eng.energy_report()["saving_frac"] == pytest.approx(0.4436,
                                                               abs=1e-3)
