"""Chaos-hardened serving (PR 7 tentpole, DESIGN.md §10).

Contract: every injected fault is DETECTED, the response is bounded
(rollback / retry / quarantine / snapshot-restore / rejection), chaos
runs compile ZERO extra executables, and recovery is bit-identical to
an uninjected run wherever the fault left no policy change behind.
Everything here runs on a deterministic FakeClock and a seeded
``FaultInjector`` — a failing scenario is a replayable seed, not an
anecdote.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.power_model import MAC_SAVING_FRAC
from repro.dist.fault_tolerance import PreemptionHandler
from repro.serve.brownout import BrownoutController
from repro.serve.engine import Engine, Request
from repro.serve.faults import FaultEvent, FaultInjector, InjectedFault
from repro.serve.scheduler import PowerBudgetScheduler
from repro.serve.traffic import TrafficClass, TrafficGenerator, slo_report


def _small_model():
    from repro.nn import transformer as T
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return T, cfg, params


class FakeClock:
    """Deterministic injected time source: each read advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def _prompt(lo, n=5):
    return np.arange(lo, lo + n, dtype=np.int32)


def _tokens(completed):
    return sorted((r.rid, tuple(r.tokens)) for r in completed
                  if r.status == "done")


# --- shared-pool isolation (the splice regression) --------------------------

def test_batched_decode_matches_solo():
    """Each slot's continuation must equal its solo run: the pre-PR-7
    ``_splice_cache`` indexed the LAYER axis instead of the batch axis,
    so one request's prefill rows silently corrupted every other
    in-flight request's cache (and solo engines never wrote layer 1 at
    all).  Pinned here for good."""
    T, cfg, params = _small_model()

    def solo(prompt):
        e = Engine(params, cfg, max_batch=1, max_len=48)
        e.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
        return [tuple(r.tokens) for r in e.run()][0]

    eng = Engine(params, cfg, max_batch=2, max_len=48)
    eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=_prompt(10), max_new_tokens=6))
    both = {r.rid: tuple(r.tokens) for r in eng.run()}
    assert both[0] == solo(_prompt(0))
    assert both[1] == solo(_prompt(10))


# --- bounded admission ------------------------------------------------------

def test_queue_overflow_rejects_explicitly():
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, queue_capacity=2, clock=FakeClock())
    reqs = [Request(rid=i, prompt=_prompt(i)) for i in range(3)]
    assert eng.submit(reqs[0]) and eng.submit(reqs[1])
    assert not eng.submit(reqs[2])
    assert reqs[2].status == "rejected"
    assert eng.n_rejected == 1 and len(eng.queue) == 2
    bp = eng.backpressure
    assert bp["queued"] == 2 and bp["utilization"] == 1.0
    assert bp["rejected"] == 1


# --- deadlines --------------------------------------------------------------

def test_ttft_deadline_expires_queued_request():
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=1, clock=FakeClock())
    eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8))
    # slot-starved behind rid 0; its TTFT budget (5 ms = 5 clock
    # reads) burns down while it waits in the queue
    late = Request(rid=1, prompt=_prompt(10), max_new_tokens=8,
                   ttft_slo_s=0.005)
    eng.submit(late)
    done = eng.run()
    assert late.status == "expired" and late.tokens == []
    assert eng.n_expired == 1
    assert {r.rid for r in done if r.status == "done"} == {0}


def test_e2e_deadline_evicts_active_slot():
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=1, clock=FakeClock())
    req = Request(rid=0, prompt=_prompt(0), max_new_tokens=10_000,
                  e2e_slo_s=0.05)
    eng.submit(req)
    eng.run(max_ticks=500)
    assert req.status == "expired"
    assert req.tokens, "should have decoded before the deadline hit"
    assert req.finished_at - req.submitted_at > 0.05
    assert eng.slots == [None]


# --- NaN/Inf guard ----------------------------------------------------------

@pytest.mark.parametrize("payload", [float("nan"), float("inf")])
def test_nan_guard_rollback_is_bit_identical(payload):
    """Transient logits corruption at the exact config: the guard rolls
    the step back (cache uncommitted, rng untouched) and re-decodes next
    tick — the finished tokens must equal an uninjected run's, with
    zero extra compiled executables."""
    T, cfg, params = _small_model()

    def run(inj):
        eng = Engine(params, cfg, max_batch=2, max_len=64,
                     clock=FakeClock(), fault_injector=inj)
        eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=_prompt(10), max_new_tokens=8))
        done = eng.run()
        return eng, _tokens(done)

    _, want = run(None)
    inj = FaultInjector([FaultEvent(tick=2, kind="nan_logits",
                                    value=payload),
                         FaultEvent(tick=4, kind="nan_logits", slot=1,
                                    value=payload)])
    eng, got = run(inj)
    assert got == want
    assert eng.n_nan_events == 2 and eng.n_quarantined == 3
    assert inj.counts["nan_logits"] == 2
    assert eng._decode._cache_size() == 1
    assert eng._prefill._cache_size() == 1


def test_nan_quarantine_steps_config_toward_exact():
    """At an aggressive config the guard must also move POLICY: one
    cell steps one notch toward exact (strictly lower saving) per
    event — the paper's knob as the recovery axis."""
    T, cfg, params = _small_model()
    inj = FaultInjector([FaultEvent(tick=3, kind="nan_logits")])
    eng = Engine(params, cfg, max_batch=1, approx_cfg=31,
                 clock=FakeClock(), fault_injector=inj)
    before = eng.approx_cfg.copy()
    eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8))
    done = eng.run()
    assert eng.n_nan_events == 1
    assert (MAC_SAVING_FRAC[eng.approx_cfg].sum()
            < MAC_SAVING_FRAC[before].sum())
    assert done[0].status == "done"
    assert all(np.isfinite(done[0].tokens).all() for _ in [0])


def test_nan_quarantine_uses_scheduler_backoff_when_attached():
    """With a scheduler attached the guard routes through
    ``scheduler.quarantine`` — the SAME one-notch ``_backoff`` rule as
    probe hysteresis, so the two responses cannot fight."""
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(10.0, probe_every=10**9,
                                 retune_every=10**9)
    inj = FaultInjector([FaultEvent(tick=3, kind="nan_logits")])
    eng = Engine(params, cfg, max_batch=1, approx_cfg=8, scheduler=sched,
                 clock=FakeClock(), fault_injector=inj)
    eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8))
    eng.run()
    assert sched.n_backoffs == 1
    assert any(h["event"] == "backoff" for h in sched.history)
    # the backoff wrote the engine config: saving strictly dropped
    assert (MAC_SAVING_FRAC[eng.approx_cfg].sum()
            < 2 * MAC_SAVING_FRAC[8])


# --- retry + backoff --------------------------------------------------------

def test_step_failure_retries_then_recovers_bit_identically():
    T, cfg, params = _small_model()

    def run(inj):
        eng = Engine(params, cfg, max_batch=2, clock=FakeClock(),
                     fault_injector=inj, retry_base_s=1e-3,
                     retry_cap_s=4e-3)
        eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=_prompt(10), max_new_tokens=8))
        return eng, _tokens(eng.run(max_ticks=200))

    _, want = run(None)
    inj = FaultInjector([FaultEvent(tick=2, kind="step_fail"),
                         FaultEvent(tick=3, kind="step_fail")])
    eng, got = run(inj)
    assert got == want
    assert eng.n_retries == 2
    assert "InjectedFault" in eng.last_error
    assert all(r.retries == 2 for r in eng.completed)
    assert eng._decode._cache_size() == 1


def test_request_out_of_retries_is_failed():
    T, cfg, params = _small_model()
    inj = FaultInjector([FaultEvent(tick=1, kind="step_fail")])
    eng = Engine(params, cfg, max_batch=1, clock=FakeClock(),
                 fault_injector=inj, max_retries=0, retry_base_s=1e-3)
    req = Request(rid=0, prompt=_prompt(0), max_new_tokens=8)
    eng.submit(req)
    eng.run(max_ticks=100)
    assert req.status == "failed" and eng.n_failed == 1


def test_retry_backoff_is_capped_exponential_with_deterministic_jitter():
    T, cfg, params = _small_model()
    clock = FakeClock()
    eng = Engine(params, cfg, clock=clock, retry_base_s=0.01,
                 retry_cap_s=0.03, seed=7)
    waits = []
    for _ in range(4):
        now = clock.t
        eng._record_failure([], now, RuntimeError("x"))
        waits.append(eng._backoff_until - now)
    # exponential then capped, each with ≤10% jitter on top
    for w, base in zip(waits, [0.01, 0.02, 0.03, 0.03]):
        assert base <= w <= base * 1.1 + 1e-12, (w, base)
    # deterministic: same seed and failure ordinal → same jitter
    eng2 = Engine(params, cfg, clock=FakeClock(), retry_base_s=0.01,
                  retry_cap_s=0.03, seed=7)
    eng2._record_failure([], 0.0, RuntimeError("x"))
    assert eng2._backoff_until == pytest.approx(
        waits[0], abs=0.0), "jitter must replay from (seed, ordinal)"


# --- clock skew / stall -----------------------------------------------------

def test_clock_skew_burns_deadlines_from_skewed_time():
    """A 10 s skew jump must expire a queued request's TTFT budget even
    though almost no ticks elapsed — deadlines fire from the injected
    (faulted) clock, never from tick counts."""
    T, cfg, params = _small_model()
    inj = FaultInjector([FaultEvent(tick=2, kind="clock_skew",
                                    skew_s=10.0)])
    eng = Engine(params, cfg, max_batch=1, clock=FakeClock(),
                 fault_injector=inj)
    eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=32))
    late = Request(rid=1, prompt=_prompt(10), ttft_slo_s=5.0)
    eng.submit(late)
    eng.run()
    assert late.status == "expired"


def test_stall_with_headroom_recovers_bit_identically():
    """A straggler tick under generous SLOs: time jumps, nothing
    expires, and the token stream is untouched."""
    T, cfg, params = _small_model()

    def run(inj):
        eng = Engine(params, cfg, max_batch=1, clock=FakeClock(),
                     fault_injector=inj)
        eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8,
                           ttft_slo_s=60.0, e2e_slo_s=60.0))
        return eng, _tokens(eng.run())

    _, want = run(None)
    eng, got = run(FaultInjector([FaultEvent(tick=3, kind="stall",
                                             stall_s=2.0)]))
    assert got == want and eng.n_expired == 0


# --- snapshot / restore -----------------------------------------------------

def test_snapshot_restore_resumes_bit_identically(tmp_path):
    """Kill-and-resume: a fresh engine restoring mid-stream must finish
    with exactly the uninterrupted run's tokens."""
    T, cfg, params = _small_model()

    def fresh(ck):
        return Engine(params, cfg, max_batch=2, max_len=64,
                      clock=FakeClock(), checkpointer=ck)

    ck = Checkpointer(str(tmp_path / "snap"))
    eng = fresh(ck)
    eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=10))
    eng.submit(Request(rid=1, prompt=_prompt(10), max_new_tokens=10))
    for _ in range(4):
        eng.step()
    step = eng.save_snapshot()
    mid = {r.rid: list(r.tokens)
           for r in eng.slots if r is not None}
    want = _tokens(eng.run())

    eng2 = fresh(ck)
    eng2.restore_snapshot(step)
    assert {r.rid: list(r.tokens)
            for r in eng2.slots if r is not None} == mid
    assert eng2.n_decode_steps == 4
    assert _tokens(eng2.run()) == want
    assert eng2._decode._cache_size() == 1


def test_nan_cache_self_heals_from_snapshot(tmp_path):
    """Poisoned KV state is the fault rollback can't fix (the poisoned
    cache IS the rollback target): the engine must detect the
    persistent strikes and restore the last auto-snapshot — and the
    finished tokens still match the uninjected run exactly."""
    T, cfg, params = _small_model()

    def run(inj, ck):
        eng = Engine(params, cfg, max_batch=2, max_len=64,
                     clock=FakeClock(), fault_injector=inj,
                     checkpointer=ck, snapshot_every=2,
                     nan_max_strikes=1)
        eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=_prompt(10), max_new_tokens=8))
        return eng, _tokens(eng.run(max_ticks=200))

    _, want = run(None, Checkpointer(str(tmp_path / "a")))
    inj = FaultInjector([FaultEvent(tick=4, kind="nan_cache", slot=1)])
    eng, got = run(inj, Checkpointer(str(tmp_path / "b")))
    assert got == want
    assert eng.n_restores >= 1 and eng.n_nan_events >= 1
    assert eng._decode._cache_size() == 1


# --- graceful drain ---------------------------------------------------------

def test_preemption_drains_without_new_admissions():
    T, cfg, params = _small_model()
    eng = Engine(params, cfg, max_batch=1, clock=FakeClock())
    first = Request(rid=0, prompt=_prompt(0), max_new_tokens=6)
    starved = Request(rid=1, prompt=_prompt(10), max_new_tokens=6)
    eng.submit(first)
    eng.submit(starved)
    eng.step()                       # rid 0 admitted
    h = PreemptionHandler()
    h._handler(15, None)             # SIGTERM flag, no real signal
    done = eng.run(preemption=h)
    assert first.status == "done"
    assert starved.status == "queued" and len(eng.queue) == 1
    assert {r.rid for r in done} == {0}
    # a draining engine also refuses new work explicitly
    assert not eng.submit(Request(rid=2, prompt=_prompt(20)))


def test_preemption_snapshot_handoff_is_bit_identical(tmp_path):
    """Preempt mid-stream with a checkpointer: the engine snapshots and
    exits; a successor restores and finishes EXACTLY the uninterrupted
    run's tokens — in-flight slot and still-queued request included."""
    T, cfg, params = _small_model()

    def fresh(ck):
        return Engine(params, cfg, max_batch=1, max_len=64,
                      clock=FakeClock(), checkpointer=ck)

    ref = Engine(params, cfg, max_batch=1, max_len=64,
                 clock=FakeClock())
    for lo, rid in ((0, 0), (10, 1)):
        ref.submit(Request(rid=rid, prompt=_prompt(lo),
                           max_new_tokens=6))
    want = _tokens(ref.run())

    ck = Checkpointer(str(tmp_path / "snap"))
    eng = fresh(ck)
    for lo, rid in ((0, 0), (10, 1)):
        eng.submit(Request(rid=rid, prompt=_prompt(lo),
                           max_new_tokens=6))
    for _ in range(3):
        eng.step()
    h = PreemptionHandler()
    h._handler(15, None)
    eng.run(preemption=h)
    assert eng.n_snapshots == 1
    assert any(r is not None for r in eng.slots), \
        "preemption should have left work in flight"

    eng2 = fresh(ck)
    eng2.restore_snapshot()
    assert _tokens(eng2.run()) == want


# --- power-gated admission + brownout ---------------------------------------

def test_power_gate_cheaper_configs_buy_concurrency():
    """The brownout lever itself: under a pJ/tick admission cap, the
    exact pool fits 2 slots but the max-saving pool fits all 4."""
    T, cfg, params = _small_model()
    probe = Engine(params, cfg)
    exact_tok = (probe._energy_pj_mean(probe.approx_cfg)
                 * probe.macs_per_token)
    cap = 2.5 * exact_tok

    def active_after_admit(approx_cfg):
        eng = Engine(params, cfg, max_batch=4, approx_cfg=approx_cfg,
                     power_cap_pj_per_tick=cap, clock=FakeClock())
        for i in range(4):
            eng.submit(Request(rid=i, prompt=_prompt(i)))
        eng.step()
        return sum(s is not None for s in eng.slots)

    assert active_after_admit(0) == 2
    assert active_after_admit(31) == 4    # 4 × 0.556 ≈ 2.23 < 2.5


def test_brownout_escalates_and_recovers_with_hysteresis():
    T, cfg, params = _small_model()
    bo = BrownoutController(ladder=(0, 31), high_watermark=0.5,
                            low_watermark=0.25, hold_ticks=2)
    eng = Engine(params, cfg, max_batch=1, queue_capacity=4,
                 brownout=bo, clock=FakeClock())
    for i in range(4):
        eng.submit(Request(rid=i, prompt=_prompt(i), max_new_tokens=4))
    eng.run(max_ticks=200)
    assert bo.n_escalations >= 1, "queue pressure must escalate"
    assert bo.n_recoveries == bo.n_escalations, \
        "a drained queue must recover every level"
    assert bo.level == 0
    assert np.all(eng.approx_cfg == 0), "base config restored exactly"
    assert any(level > 0 for level, _, _ in bo.history)


def test_brownout_composes_with_scheduler_via_budget_scale():
    """With a scheduler attached the brownout must NOT write configs —
    it scales the scheduler's budget and the next retune re-plans."""
    T, cfg, params = _small_model()
    exact_pj = float(
        Engine(params, cfg).macs_per_token
        * Engine(params, cfg)._energy_pj_mean(np.zeros(2, np.int32)))
    sched = PowerBudgetScheduler(exact_pj, probe_every=10**9,
                                 retune_every=2)
    bo = BrownoutController(ladder=(0, 31), high_watermark=0.5,
                            low_watermark=0.25, hold_ticks=2)
    eng = Engine(params, cfg, max_batch=1, queue_capacity=4,
                 scheduler=sched, brownout=bo, clock=FakeClock())
    for i in range(4):
        eng.submit(Request(rid=i, prompt=_prompt(i), max_new_tokens=4))
    eng.run(max_ticks=300)
    scales = [h for h in bo.history]
    assert bo.n_escalations >= 1
    # while browned out the scheduler's effective budget tightened
    assert any(s < 1.0
               for s in [1.0 - MAC_SAVING_FRAC[31]] if scales)
    assert sched.budget_scale == 1.0, "recovery must restore the scale"
    assert eng._decode._cache_size() == 1


# --- probe feedback chaos ---------------------------------------------------

def test_drop_and_dup_probe_change_feedback_multiplicity():
    T, cfg, params = _small_model()
    sched = PowerBudgetScheduler(10.0, probe_every=1,
                                 retune_every=10**9)
    inj = FaultInjector([FaultEvent(tick=2, kind="dup_probe"),
                         FaultEvent(tick=3, kind="drop_probe")])
    eng = Engine(params, cfg, max_batch=1, approx_cfg=1,
                 scheduler=sched, clock=FakeClock(), fault_injector=inj)
    eng.submit(Request(rid=0, prompt=_prompt(0), max_new_tokens=8))
    counts = []
    while any(s is not None for s in eng.slots) or eng.queue:
        before = sched.n_probes
        eng.step()
        counts.append(sched.n_probes - before)
    assert counts[2] == 2, "dup_probe delivers feedback twice"
    assert counts[3] == 0, "drop_probe suppresses feedback"
    assert all(c == 1 for i, c in enumerate(counts) if i not in (2, 3))


# --- traffic harness --------------------------------------------------------

def test_traffic_is_replayable_per_tick():
    classes = (TrafficClass("chat", ttft_slo_s=0.1, e2e_slo_s=1.0),
               TrafficClass("batch", weight=0.5, prompt_len=12))
    g1 = TrafficGenerator(classes, rate_per_tick=2.0, seed=42)
    g2 = TrafficGenerator(classes, rate_per_tick=2.0, seed=42)
    for tick in (0, 7, 3, 7):     # any access order, same answers
        a, b = g1.arrivals(tick), g2.arrivals(tick)
        assert [(r.rid, r.cls, r.prompt.tolist()) for r in a] \
            == [(r.rid, r.cls, r.prompt.tolist()) for r in b]
    assert any(g1.arrivals(t) for t in range(8))
    g3 = TrafficGenerator(classes, rate_per_tick=2.0, seed=43)
    assert any([(r.rid, r.prompt.tolist()) for r in g1.arrivals(t)]
               != [(r.rid, r.prompt.tolist()) for r in g3.arrivals(t)]
               for t in range(8)), "different seed, different trace"


def test_traffic_spike_multiplies_rate_and_slo_report_scores():
    classes = (TrafficClass("chat", ttft_slo_s=0.1, e2e_slo_s=1.0),)
    g = TrafficGenerator(classes, rate_per_tick=1.0, seed=0,
                         spikes=((10, 20, 4.0),))
    assert g.rate_at(5) == 1.0 and g.rate_at(10) == 4.0
    assert g.rate_at(19) == 4.0 and g.rate_at(20) == 1.0
    spike = sum(len(g.arrivals(t)) for t in range(10, 20))
    base = sum(len(g.arrivals(t)) for t in range(0, 10))
    assert spike > base

    met = Request(rid=0, prompt=_prompt(0), cls="chat", status="done",
                  submitted_at=0.0, first_token_at=0.05,
                  finished_at=0.5, ttft_slo_s=0.1, e2e_slo_s=1.0)
    missed = Request(rid=1, prompt=_prompt(0), cls="chat", status="done",
                     submitted_at=0.0, first_token_at=0.2,
                     finished_at=0.5, ttft_slo_s=0.1, e2e_slo_s=1.0)
    lost = Request(rid=2, prompt=_prompt(0), cls="chat",
                   status="rejected", submitted_at=0.0)
    rep = slo_report([met, missed, lost])
    chat = rep["classes"]["chat"]
    assert chat["offered"] == 3 and chat["served"] == 2
    assert chat["availability"] == pytest.approx(2 / 3)
    assert chat["slo_attainment"] == pytest.approx(1 / 2)
    assert rep["total"]["rejected"] == 1
