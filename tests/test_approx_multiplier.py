"""Unit + property tests for the error-configurable multiplier model."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.approx_multiplier import (CONFIG_TABLE, EXACT_TABLE, N_CONFIGS,
                                          approx_multiply_magnitude,
                                          approx_multiply_signed,
                                          config_params, exhaustive_products)
from repro.core.error_metrics import (PAPER_TABLE_I, multiplier_error_stats,
                                      summary_table)

mags = st.integers(min_value=0, max_value=127)
signed = st.integers(min_value=-127, max_value=127)
configs = st.integers(min_value=0, max_value=31)


def test_config_zero_is_exact():
    assert np.array_equal(exhaustive_products(0), EXACT_TABLE)


def test_config_table_has_31_distinct_entries():
    assert len(CONFIG_TABLE) == N_CONFIGS - 1 == 31
    assert len(set(CONFIG_TABLE)) == 31


def test_no_approx_config_is_exact():
    for c in range(1, 32):
        assert (exhaustive_products(c) != EXACT_TABLE).any(), c


@given(a=mags, b=mags, c=configs)
@settings(max_examples=300, deadline=None)
def test_commutativity(a, b, c):
    pa = approx_multiply_magnitude(np.array(a), np.array(b), c)
    pb = approx_multiply_magnitude(np.array(b), np.array(a), c)
    assert int(pa) == int(pb)


@given(a=mags, b=mags, c=configs)
@settings(max_examples=300, deadline=None)
def test_error_bounded_by_truncation_depth(a, b, c):
    """|approx - exact| < 2^t + compensation bound."""
    approx = int(approx_multiply_magnitude(np.array(a), np.array(b), c))
    exact = a * b
    if c == 0:
        assert approx == exact
    else:
        _, t, _ = config_params(c)
        assert abs(approx - exact) <= (1 << t)


@given(a=mags, b=mags, c=st.integers(min_value=1, max_value=31))
@settings(max_examples=300, deadline=None)
def test_gating_small_operands_exact(a, b, c):
    """Below the operand gate, the multiplier is exact."""
    _, _, gate = config_params(c)
    if gate > 0 and (a < gate or b < gate):
        approx = int(approx_multiply_magnitude(np.array(a), np.array(b), c))
        assert approx == a * b


@given(a=signed, b=signed, c=configs)
@settings(max_examples=300, deadline=None)
def test_sign_handling_is_xor(a, b, c):
    """Sign is exact (XOR of operand signs); magnitude is the unsigned
    approximate product — the paper's MAC datapath invariant."""
    p = int(approx_multiply_signed(np.array(a), np.array(b), c))
    mag = int(approx_multiply_magnitude(np.array(abs(a)), np.array(abs(b)), c))
    assert p == np.sign(a) * np.sign(b) * mag


def test_zero_operand_gives_zero():
    for c in range(32):
        assert int(approx_multiply_magnitude(np.array(0), np.array(77), c)) == 0
        assert int(approx_multiply_magnitude(np.array(77), np.array(0), c)) == 0


def test_jax_numpy_paths_agree():
    import jax.numpy as jnp
    a = np.arange(128, dtype=np.int32)
    b = np.arange(127, -1, -1, dtype=np.int32)
    for c in (0, 5, 17, 31):
        np_out = approx_multiply_magnitude(a, b, c)
        jx_out = np.asarray(approx_multiply_magnitude(jnp.asarray(a),
                                                      jnp.asarray(b), c))
        assert np.array_equal(np_out, jx_out), c


# --- Table I envelope (paper validation) -----------------------------------

def test_er_envelope_matches_paper():
    s = summary_table()
    # our ER envelope brackets the paper's within 1.5 percentage points
    assert abs(s["er_min"] - PAPER_TABLE_I["er_min"]) < 0.015
    assert abs(s["er_max"] - PAPER_TABLE_I["er_max"]) < 0.015
    assert abs(s["er_avg"] - PAPER_TABLE_I["er_avg"]) < 0.05


def test_mred_envelope_reasonable():
    s = summary_table()
    assert s["mred_max"] <= PAPER_TABLE_I["mred_max"] * 1.05
    assert s["mred_min"] <= PAPER_TABLE_I["mred_min"]
    # average within the paper's order of magnitude
    assert 0.25 * PAPER_TABLE_I["mred_avg"] <= s["mred_avg"] \
        <= 1.5 * PAPER_TABLE_I["mred_avg"]


def test_stats_exact_config():
    s = multiplier_error_stats(0)
    assert s.er == 0.0 and s.mred == 0.0 and s.nmed == 0.0
