"""Pallas kernels vs ref.py oracles (interpret=True on CPU): shape/dtype
sweeps per the assignment spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.approx_matmul import (approx_dense,
                                      approx_matmul_operand_blocked)
from repro.core.quantization import quantize
from repro.kernels.approx_mac.ops import approx_dense_pallas, approx_mac
from repro.kernels.approx_mac.ref import approx_mac_matmul_ref
from repro.kernels.flash_attention.ops import flash_attn
from repro.nn.attention import ref_attention

RNG = np.random.default_rng(42)


# --- approx_mac -------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 256, 128), (64, 128, 64), (100, 200, 60), (256, 512, 384),
    (1, 256, 128), (130, 260, 129),
])
@pytest.mark.parametrize("cfg", [0, 1, 8, 16, 24, 31])
def test_approx_mac_bit_exact(m, k, n, cfg):
    a = jnp.asarray(RNG.integers(-127, 128, (m, k)), jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 128, (k, n)), jnp.int8)
    out = approx_mac(a, b, cfg, interpret=True)
    ref = approx_mac_matmul_ref(a, b, cfg)
    assert out.dtype == jnp.int32
    assert jnp.array_equal(out, ref), (m, k, n, cfg)


def test_approx_mac_batched():
    a = jnp.asarray(RNG.integers(-127, 128, (2, 3, 64, 128)), jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 128, (128, 64)), jnp.int8)
    out = approx_mac(a, b, 8, interpret=True)
    ref = approx_mac_matmul_ref(a.reshape(-1, 128), b, 8).reshape(2, 3, 64, 64)
    assert jnp.array_equal(out, ref)


@given(bm=st.sampled_from([64, 128]), bk=st.sampled_from([128, 256]),
       cfg=st.integers(0, 31))
@settings(max_examples=12, deadline=None)
def test_approx_mac_block_shape_invariance(bm, bk, cfg):
    """Result is independent of the BlockSpec tiling."""
    a = jnp.asarray(RNG.integers(-127, 128, (64, 128)), jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 128, (128, 64)), jnp.int8)
    out = approx_mac(a, b, cfg, bm=bm, bn=64, bk=bk, interpret=True)
    ref = approx_mac_matmul_ref(a, b, cfg)
    assert jnp.array_equal(out, ref)


@given(bm=st.sampled_from([64, 128]), bn=st.sampled_from([64, 128]),
       bk=st.sampled_from([128, 256]))
@settings(max_examples=8, deadline=None)
def test_approx_mac_per_block_configs_block_shape_invariance(bm, bn, bk):
    """Mixed per-N-block configs are defined on the LOGICAL 128-column
    grid semantics: the result must match the blocked oracle for every
    (bm, bn, bk) tiling whose n-blocking matches the vector length."""
    a = jnp.asarray(RNG.integers(-127, 128, (64, 128)), jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 128, (128, 2 * bn)), jnp.int8)
    vec = jnp.asarray([1, 29], jnp.int32)
    out = approx_mac(a, b, vec, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = approx_matmul_operand_blocked(a, b, vec, bn)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (100, 200, 60),
                                   (1, 256, 128)])
@pytest.mark.parametrize("cfg", [0, 8, 31])
def test_fused_dense_matches_xla_reference(m, k, n, cfg):
    """Fused f32-in/f32-out kernel (quantize + truncate + MAC + rescale
    in ONE pallas_call) vs the three-pass XLA reference: bit-identical,
    including non-tile-multiple shapes (padding)."""
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)) * 0.05, jnp.float32)
    w_qt = quantize(w, axis=1)
    out = approx_dense_pallas(x, w_qt, config=cfg, interpret=True,
                              compute_dtype=jnp.float32)
    ref = approx_dense(x, w_qt, cfg)
    assert out.dtype == jnp.float32
    assert jnp.array_equal(out, ref), (m, k, n, cfg)


def test_fused_dense_batched_leading_dims():
    x = jnp.asarray(RNG.normal(size=(2, 3, 16, 96)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(96, 64)) * 0.1, jnp.float32)
    w_qt = quantize(w, axis=1)
    out = approx_dense_pallas(x, w_qt, config=8, interpret=True,
                              compute_dtype=jnp.float32)
    ref = approx_dense(x, w_qt, 8)
    assert out.shape == (2, 3, 16, 64)
    assert jnp.array_equal(out, ref)


# --- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("b,sq,skv,h,kv,hd,causal,window,cap", [
    (2, 128, 128, 4, 4, 128, True, 0, 0.0),
    (2, 128, 128, 4, 2, 128, True, 0, 0.0),     # GQA
    (1, 256, 256, 4, 1, 128, True, 64, 0.0),    # MQA + window
    (1, 128, 128, 2, 2, 128, True, 0, 50.0),    # gemma2 softcap
    (2, 100, 100, 4, 4, 120, True, 0, 0.0),     # danube hd=120 (pad)
    (1, 64, 192, 2, 2, 128, False, 0, 0.0),     # cross attention
    (1, 96, 96, 2, 2, 128, True, 32, 30.0),     # window + softcap
])
def test_flash_attention_matches_ref(b, sq, skv, h, kv, hd, causal, window,
                                     cap):
    ks = jax.random.split(jax.random.PRNGKey(b * sq + skv + h), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, kv, hd))
    v = jax.random.normal(ks[2], (b, skv, kv, hd))
    out = flash_attn(q, k, v, causal=causal, window=window, logit_cap=cap,
                     bq=64, bk=64, interpret=True)
    ref = ref_attention(q, k, v, causal=causal, window=window, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 128), dtype)
    k = jax.random.normal(ks[1], (2, 128, 4, 128), dtype)
    v = jax.random.normal(ks[2], (2, 128, 4, 128), dtype)
    out = flash_attn(q, k, v, bq=64, bk=64, interpret=True)
    ref = ref_attention(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 128))
    k = jax.random.normal(ks[1], (1, 128, 2, 128))
    v = jax.random.normal(ks[2], (1, 128, 2, 128))
    outs = [flash_attn(q, k, v, bq=bq, bk=bk, interpret=True)
            for bq, bk in [(32, 32), (64, 128), (128, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


# --- paged gather-attention decode (PR 8) -----------------------------------

def _paged_case(b, pages, bs, h, kv, hd, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    nb = 2 + b * pages
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k_pool = jax.random.normal(ks[1], (nb, bs, kv, hd))
    v_pool = jax.random.normal(ks[2], (nb, bs, kv, hd))
    # zero the reserved ZERO_BLOCK like the engine pool
    k_pool = k_pool.at[0].set(0.0)
    v_pool = v_pool.at[0].set(0.0)
    rng = np.random.default_rng(seed)
    lens = rng.integers(1, pages * bs + 1, size=b).astype(np.int32)
    tables = np.zeros((b, pages), np.int32)
    nxt = 2
    for i in range(b):
        for j in range(-(-int(lens[i]) // bs)):
            tables[i, j] = nxt
            nxt += 1
    return q, k_pool, v_pool, jnp.asarray(tables), jnp.asarray(lens)


@pytest.mark.parametrize("b,pages,bs,h,kv,hd,cap", [
    (2, 4, 16, 4, 4, 128, 0.0),
    (3, 2, 8, 4, 2, 128, 0.0),      # GQA, partial last block
    (1, 4, 16, 2, 1, 128, 0.0),     # MQA
    (2, 3, 16, 2, 2, 64, 50.0),     # hd pad + softcap
])
def test_paged_attention_kernel_matches_reference(b, pages, bs, h, kv, hd,
                                                  cap):
    from repro.kernels.flash_attention.paged_attention import (
        paged_attention_reference, paged_decode_attention)
    q, kp, vp, tables, lens = _paged_case(b, pages, bs, h, kv, hd, seed=b)
    out = paged_decode_attention(q, kp, vp, tables, lens, logit_cap=cap,
                                 interpret=True)
    ref = paged_attention_reference(q, kp, vp, tables, lens, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_unowned_pages_are_inert():
    """Rows must not read pages they don't own: scribbling on every
    block OUTSIDE the tables (incl. TRASH_BLOCK) changes nothing."""
    from repro.kernels.flash_attention.paged_attention import (
        paged_decode_attention)
    q, kp, vp, tables, lens = _paged_case(2, 4, 16, 4, 4, 128, seed=11)
    owned = {0} | {int(x) for x in np.asarray(tables).ravel()}
    a = paged_decode_attention(q, kp, vp, tables, lens, interpret=True)
    for blk in range(kp.shape[0]):
        if blk not in owned:
            kp = kp.at[blk].set(999.0)
            vp = vp.at[blk].set(-999.0)
    b = paged_decode_attention(q, kp, vp, tables, lens, interpret=True)
    assert jnp.array_equal(a, b)
