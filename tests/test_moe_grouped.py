"""Grouped-expert Pallas approx-MAC GEMM (PR 3 tentpole).

Contract: folding the MoE expert loop into ONE kernel grid changes
nothing but wall-clock — the grouped pallas_call is BIT-IDENTICAL to
the per-expert ``lax.map`` path and to the blocked grouped reference
(``ref.approx_mac_grouped_ref``) for all 32 configs, per-expert config
vectors/matrices, and ragged/empty expert slices, and sweeping
per-expert configs through the Engine triggers ZERO recompilations.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approx_multiplier import N_CONFIGS
from repro.core.quantization import quantize
from repro.kernels.approx_mac.ops import (_approx_grouped_fused_jit,
                                          approx_dense_grouped_pallas,
                                          approx_mac, collapse_expert_cfg)
from repro.kernels.approx_mac.ref import approx_mac_grouped_ref
from repro.nn.moe import moe_ffn, quantize_expert_bank

RNG = np.random.default_rng(21)
E, M, K, N = 3, 24, 64, 192          # N -> 2 kernel blocks (128 + pad)

X = jnp.asarray(RNG.normal(size=(E, M, K)), jnp.float32)
W = jnp.asarray(RNG.normal(size=(E, K, N)) * 0.05, jnp.float32)
BANK = quantize_expert_bank(W)


def _t(c):
    return jnp.asarray(c, jnp.int32)


# --- op level: grouped kernel vs the blocked grouped reference --------------

@pytest.mark.parametrize("cfg", range(N_CONFIGS))
def test_grouped_op_matches_ref_all_configs(cfg):
    """Acceptance: every one of the 32 configs, uniform across experts —
    one compiled executable (the config is a traced scalar)."""
    out = approx_dense_grouped_pallas(X, BANK, config=_t(cfg),
                                      interpret=True,
                                      compute_dtype=jnp.float32)
    ref = approx_mac_grouped_ref(X, BANK.values, BANK.scale,
                                 np.full((E, 1), cfg))
    assert jnp.array_equal(out, ref), cfg


def test_grouped_op_per_expert_vector():
    """Each expert at its own config inside ONE kernel launch."""
    vec = jnp.asarray([0, 31, 8], jnp.int32)
    out = approx_dense_grouped_pallas(X, BANK, config=vec, interpret=True,
                                      compute_dtype=jnp.float32)
    ref = approx_mac_grouped_ref(X, BANK.values, BANK.scale,
                                 np.asarray([[0], [31], [8]]))
    assert jnp.array_equal(out, ref)
    # differs from any uniform config (the knob really is per-expert)
    uni = approx_dense_grouped_pallas(X, BANK, config=_t(8), interpret=True,
                                      compute_dtype=jnp.float32)
    assert not jnp.array_equal(out, uni)


def test_grouped_op_per_expert_per_block_matrix():
    """(E, g) matrices: per-expert AND per-neuron-block in one call.
    N=256 -> group spans == block spans, so rows map through exactly."""
    w = jnp.asarray(RNG.normal(size=(E, K, 256)) * 0.05, jnp.float32)
    bank = quantize_expert_bank(w)
    mat = jnp.asarray([[0, 31], [8, 8], [11, 2]], jnp.int32)
    out = approx_dense_grouped_pallas(X, bank, config=mat, interpret=True,
                                      compute_dtype=jnp.float32)
    ref = approx_mac_grouped_ref(X, bank.values, bank.scale,
                                 np.asarray(mat))
    assert jnp.array_equal(out, ref)


def test_grouped_op_straddling_groups_collapse():
    """N=192: block 0 (cols 0-127) straddles the 2-group boundary at 96
    -> it runs the lowest-measured-MRED config of the two groups, same
    conservative rule as the dense path (cfg 11 has a higher index but
    lower MRED than cfg 9)."""
    from repro.kernels.approx_mac.ops import _mred_table_dev
    mred = np.asarray(_mred_table_dev())
    assert mred[11] < mred[9]
    mat = jnp.asarray([[11, 9], [9, 11], [0, 0]], jnp.int32)
    out = approx_dense_grouped_pallas(X, BANK, config=mat, interpret=True,
                                      compute_dtype=jnp.float32)
    ref = approx_mac_grouped_ref(X, BANK.values, BANK.scale,
                                 np.asarray([[11, 9], [11, 11], [0, 0]]))
    assert jnp.array_equal(out, ref)


def test_grouped_op_ragged_and_empty_experts():
    """group_rows: expert 1 empty, expert 2 ragged (7 of 24 rows) — the
    invalid rows are excluded from the shared activation scale and come
    back zero, even when they hold garbage."""
    rows = jnp.asarray([M, 0, 7], jnp.int32)
    xg = X.at[1].set(1e3).at[2, 7:].set(-99.0)   # garbage in invalid rows
    vec = jnp.asarray([0, 31, 8], jnp.int32)
    out = approx_dense_grouped_pallas(xg, BANK, config=vec,
                                      group_rows=rows, interpret=True,
                                      compute_dtype=jnp.float32)
    ref = approx_mac_grouped_ref(xg, BANK.values, BANK.scale,
                                 np.asarray([[0], [31], [8]]),
                                 group_rows=rows)
    assert jnp.array_equal(out, ref)
    assert not np.any(np.asarray(out[1]))
    assert not np.any(np.asarray(out[2, 7:]))
    assert np.any(np.asarray(out[2, :7]))


def test_grouped_op_zero_retrace():
    """Config values, per-expert vectors, and raggedness are all traced:
    sweeping them shares one executable per argument SHAPE."""
    approx_dense_grouped_pallas(X, BANK, config=_t(0), interpret=True)
    approx_dense_grouped_pallas(X, BANK, config=jnp.zeros((E,), jnp.int32),
                                group_rows=jnp.full((E,), M, jnp.int32),
                                interpret=True)
    n0 = _approx_grouped_fused_jit._cache_size()
    for cfg in range(N_CONFIGS):
        approx_dense_grouped_pallas(X, BANK, config=_t(cfg), interpret=True)
        approx_dense_grouped_pallas(
            X, BANK, config=jnp.asarray([cfg, (cfg + 7) % 32, 3], jnp.int32),
            group_rows=jnp.asarray([M, cfg % M, 7], jnp.int32),
            interpret=True)
    assert _approx_grouped_fused_jit._cache_size() == n0


# --- collapse rule for GEMMs without an expert axis -------------------------

def test_collapse_expert_cfg_lowest_mred_with_index_tiebreak():
    from repro.kernels.approx_mac.ops import _mred_table_dev
    mred = np.asarray(_mred_table_dev())
    assert mred[11] < mred[9]
    got = collapse_expert_cfg(jnp.asarray([[9, 0], [11, 31]], jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), [11, 0])
    # identical rows collapse to themselves
    got = collapse_expert_cfg(jnp.asarray([[5, 7], [5, 7]], jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), [5, 7])


def test_dense_layer_collapses_expert_axis():
    """An (E, g) engine config reaching a dense GEMM (no expert axis)
    must equal the explicitly collapsed (g,) vector."""
    from repro.nn.layers import dense
    x = jnp.asarray(RNG.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(64, 256)) * 0.05, jnp.float32)
    mat = jnp.asarray([[9, 0], [11, 31]], jnp.int32)
    out = dense(x, w, approx_cfg=mat, backend="pallas", interpret=True,
                compute_dtype=jnp.float32)
    ref = dense(x, w, approx_cfg=collapse_expert_cfg(mat), backend="pallas",
                interpret=True, compute_dtype=jnp.float32)
    assert jnp.array_equal(out, ref)


# --- MoE layer: grouped vs lax.map bit-identity -----------------------------

KEY = jax.random.PRNGKey(11)


def _moe_params(d, e, f):
    ks = jax.random.split(KEY, 4)
    return {"router": jax.random.normal(ks[0], (d, e)) * 0.5,
            "w_up": jax.random.normal(ks[1], (e, d, f)) / np.sqrt(d),
            "w_down": jax.random.normal(ks[2], (e, f, d)) / np.sqrt(f),
            "w_gate": jax.random.normal(ks[3], (e, d, f)) / np.sqrt(d)}


MOE_KW = dict(n_experts=4, top_k=2, capacity_factor=4.0, n_groups=1,
              backend="pallas", interpret=True)


@pytest.mark.parametrize("cfg", [0, 1, 8, 11, 16, 24, 31])
def test_moe_grouped_matches_laxmap(cfg):
    """Acceptance: dense MoE on the pallas backend — the grouped path is
    bit-identical to the per-expert lax.map path."""
    p = _moe_params(16, 4, 32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (32, 16))
    yg, _ = moe_ffn(x, p, approx_cfg=_t(cfg), grouped=True, **MOE_KW)
    ym, _ = moe_ffn(x, p, approx_cfg=_t(cfg), grouped=False, **MOE_KW)
    assert jnp.array_equal(yg, ym), cfg


@pytest.mark.slow
def test_moe_grouped_matches_laxmap_all_32():
    """The full 32-config sweep (the subset above is the tier-1 guard)."""
    p = _moe_params(16, 4, 32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (32, 16))
    for cfg in range(N_CONFIGS):
        yg, _ = moe_ffn(x, p, approx_cfg=_t(cfg), grouped=True, **MOE_KW)
        ym, _ = moe_ffn(x, p, approx_cfg=_t(cfg), grouped=False, **MOE_KW)
        assert jnp.array_equal(yg, ym), cfg


def test_moe_grouped_matches_laxmap_per_expert_configs():
    """Mixed per-expert config vectors and matrices: each expert of one
    MoE layer at its own error config, both paths bit-identical (and the
    result really depends on which expert gets which config)."""
    p = _moe_params(16, 4, 32)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (32, 16))
    outs = []
    for cfgv in (jnp.asarray([[0], [31], [8], [11]], jnp.int32),
                 jnp.asarray([[31], [0], [11], [8]], jnp.int32),
                 jnp.asarray([[0, 31], [8, 8], [11, 9], [2, 2]], jnp.int32)):
        yg, _ = moe_ffn(x, p, approx_cfg=cfgv, grouped=True, **MOE_KW)
        ym, _ = moe_ffn(x, p, approx_cfg=cfgv, grouped=False, **MOE_KW)
        assert jnp.array_equal(yg, ym), cfgv.shape
        outs.append(yg)
    assert not jnp.array_equal(outs[0], outs[1])   # permuted experts differ


def test_moe_shared_group_vector_broadcasts_over_experts():
    """A legacy (g,) per-neuron-group vector (no expert axis) must mean
    the same thing as the (E, g) matrix with identical rows."""
    p = _moe_params(16, 4, 32)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (32, 16))
    vec = jnp.asarray([8, 31], jnp.int32)
    mat = jnp.broadcast_to(vec[None, :], (4, 2))
    y_vec, _ = moe_ffn(x, p, approx_cfg=vec, grouped=True, **MOE_KW)
    y_mat, _ = moe_ffn(x, p, approx_cfg=mat, grouped=True, **MOE_KW)
    assert jnp.array_equal(y_vec, y_mat)


def test_moe_prequantized_bank_matches_float_params():
    """Expert weights pre-quantized into stacked banks (engine init) vs
    float weights bank-quantized per trace: not a bit of difference —
    on the pallas backend AND the XLA backend (the XLA float branch
    must use the same per-expert per-channel bank quantization)."""
    p = _moe_params(16, 4, 32)
    pq = dict(p)
    for k in ("w_gate", "w_up", "w_down"):
        pq[k] = quantize_expert_bank(p[k])
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (32, 16))
    for cfg in (_t(0), _t(8), jnp.asarray([[0], [31], [8], [11]], jnp.int32)):
        y_f, _ = moe_ffn(x, p, approx_cfg=cfg, grouped=True, **MOE_KW)
        y_q, _ = moe_ffn(x, pq, approx_cfg=cfg, grouped=True, **MOE_KW)
        assert jnp.array_equal(y_f, y_q)
    xla_kw = dict(MOE_KW, backend="xla", interpret=False)
    for cfg in (_t(0), _t(8), _t(31)):
        y_f, _ = moe_ffn(x, p, approx_cfg=cfg, **xla_kw)
        y_q, _ = moe_ffn(x, pq, approx_cfg=cfg, **xla_kw)
        assert jnp.array_equal(y_f, y_q)


# --- model + engine level ----------------------------------------------------

def _moe_model(mac_backend="pallas", **over):
    from repro.nn import transformer as T
    base = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=64, n_experts=4, top_k=2,
                capacity_factor=4.0, scan_layers=False, remat=False,
                q_chunk=8, loss_chunks=1, compute_dtype=jnp.float32,
                mac_backend=mac_backend,
                mac_interpret=mac_backend == "pallas")
    base.update(over)
    cfg = T.ModelConfig(**base)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return T, cfg, params


def test_quantize_lm_params_builds_expert_banks_bit_identical():
    """Pre-quantizing MoE expert weights at init (stacked QTensor banks)
    must not change a bit of the pallas forward vs float params."""
    from repro.core.quantization import QTensor
    T, cfg, params = _moe_model()
    qp = T.quantize_lm_params(params, cfg)
    # 2 layers of pattern ("global",) stack into the scan group: the
    # expert bank gains a leading layer axis on top of the expert axis
    mlp = qp["blocks"]["scan"]["b0"]["mlp"]
    assert isinstance(mlp["w_up"], QTensor)
    assert mlp["w_up"].values.shape == (2, 4, 32, 64)
    assert mlp["w_up"].scale.shape == (2, 4, 64)
    assert not isinstance(mlp["router"], QTensor)      # router stays float
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    for c in (0, 8, 31):
        h_f = T.forward(params, cfg, toks, approx_cfg=_t(c))
        h_q = T.forward(qp, cfg, toks, approx_cfg=_t(c))
        np.testing.assert_array_equal(np.asarray(h_f), np.asarray(h_q))


def test_forward_per_layer_per_expert_config_tensor():
    """(n_layers, E, g) config tensors flow through forward; uniform
    expert rows reproduce the per-layer vector exactly."""
    T, cfg, params = _moe_model()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    uni = T.forward(params, cfg, toks,
                    approx_cfg=jnp.asarray([8, 31], jnp.int32))
    ten = T.forward(params, cfg, toks,
                    approx_cfg=jnp.full((2, 4, 1), 1, jnp.int32)
                    .at[0].set(8).at[1].set(31))
    np.testing.assert_array_equal(np.asarray(uni), np.asarray(ten))
    mixed = T.forward(params, cfg, toks,
                      approx_cfg=jnp.asarray([[[0], [31], [8], [11]],
                                              [[8], [8], [0], [2]]],
                                             jnp.int32))
    assert mixed.shape == uni.shape
    assert not jnp.array_equal(mixed, uni)


def test_engine_per_expert_sweep_zero_retraces():
    """Acceptance: a scripted per-expert config sweep through the Engine
    (cfg_experts = n_experts, grouped kernel, pre-quantized banks)
    completes with zero retraces after warmup."""
    from repro.serve.engine import Engine, Request
    T, cfg, params = _moe_model()
    eng = Engine(params, cfg, max_batch=2, max_len=32, cfg_experts=4)
    assert eng.approx_cfg.shape == (2, 4, 1)
    prompt = np.arange(8) % 64

    def one_round(c):
        eng.set_approx_cfg(c)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
        done, eng.completed = eng.run(max_ticks=50), []
        assert len(done) == 1 and len(done[0].tokens) == 2

    one_round(0)    # warmup: compiles one prefill + one decode executable
    sizes = (eng._decode._cache_size(), eng._prefill._cache_size())
    rng = np.random.default_rng(0)
    for c in (1, 8, 31):
        one_round(c)                                   # uniform
        one_round(rng.integers(0, 32, (2, 4, 1)))      # per-expert
    # (layer, expert) allocation keys + a pinned per-expert request ride
    # the same executables
    eng.apply_allocation({(0, 2): 31, "layer_1": 8, 1: 4})
    eng.submit(Request(rid=9, prompt=prompt, max_new_tokens=2,
                       approx_cfg=np.full((2, 4, 1), 31)))
    done, eng.completed = eng.run(max_ticks=50), []
    assert len(done) == 1
    assert (eng._decode._cache_size(), eng._prefill._cache_size()) == sizes


def test_engine_apply_allocation_expert_keys():
    from repro.serve.engine import Engine
    T, cfg, params = _moe_model()
    eng = Engine(params, cfg, max_batch=1, max_len=32, cfg_experts=4)
    eng.apply_allocation({(0, 1): 8, (0, 3): 31, "layer_1": 2})
    np.testing.assert_array_equal(eng.approx_cfg[..., 0],
                                  [[0, 8, 0, 31], [2, 2, 2, 2]])
    for bad in ({(0, 4): 8}, {(2, 0): 8}, {(0, 1, 2): 8}):
        with pytest.raises(ValueError):
            eng.apply_allocation(bad)
    # tuple keys need an expert axis
    eng2 = Engine(params, cfg, max_batch=1, max_len=32)
    with pytest.raises(ValueError):
        eng2.apply_allocation({(0, 1): 8})


def test_engine_pool_join_per_expert():
    """The lowest-measured-MRED pool join extends elementwise to the
    expert axis (cfg 11 has a higher index but lower MRED than 9)."""
    from repro.serve.engine import Engine, Request, _mred_table
    T, cfg, params = _moe_model()
    eng = Engine(params, cfg, max_batch=2, max_len=32, cfg_experts=4)
    assert _mred_table()[11] < _mred_table()[9]
    eng.submit(Request(rid=0, prompt=np.arange(6) % 64, max_new_tokens=8,
                       approx_cfg=np.asarray([[9, 8, 0, 31],
                                              [31, 0, 9, 9]])[..., None]))
    eng.submit(Request(rid=1, prompt=np.arange(9) % 64, max_new_tokens=8,
                       approx_cfg=np.asarray([[11, 31, 0, 8],
                                              [8, 0, 11, 9]])[..., None]))
    eng._admit()
    np.testing.assert_array_equal(
        eng._pool_cfg()[..., 0], [[11, 8, 0, 8], [8, 0, 11, 9]])


def test_engine_energy_weights_expert_axis_by_moe_mac_share():
    """Per-expert configs only reach the expert GEMMs; dense GEMMs run
    at the expert-collapsed config — the energy integral must charge
    them there, not at the per-expert mean."""
    from repro.serve.engine import _ENERGY_PJ, Engine
    T, cfg, params = _moe_model()
    eng = Engine(params, cfg, max_batch=1, max_len=32, cfg_experts=4)
    assert 0.0 < eng._moe_mac_frac < 1.0
    # expert 0 exact, the rest at cfg 31: dense GEMMs collapse to exact
    vec = np.zeros((2, 4, 1), np.int32)
    vec[:, 1:] = 31
    e_mean = float(np.mean(_ENERGY_PJ[vec]))
    f = eng._moe_mac_frac
    expect = f * e_mean + (1.0 - f) * float(_ENERGY_PJ[0])
    assert np.isclose(eng._energy_pj_mean(vec), expect)
    # the naive whole-tensor mean would under-charge the dense share
    assert eng._energy_pj_mean(vec) > e_mean
    # uniform tensors degenerate to the plain mean
    assert np.isclose(eng._energy_pj_mean(np.full((2, 4, 1), 31)),
                      float(_ENERGY_PJ[31]))


def test_engine_cfg_experts_requires_pallas_and_matching_count():
    from repro.serve.engine import Engine
    T, cfg, params = _moe_model(mac_backend="xla")
    with pytest.raises(AssertionError):
        Engine(params, cfg, max_batch=1, max_len=32, cfg_experts=4)
    T, cfg_p, params_p = _moe_model()
    with pytest.raises(AssertionError):
        Engine(params_p, cfg_p, max_batch=1, max_len=32, cfg_experts=8)
