"""Chaos matrix for the resilience subsystem (PR 7, DESIGN.md §10).

Runs the serving engine through every injected-fault scenario the
fault model names (NaN/Inf logits, decode step failure, clock skew,
stall, kill-and-restore) plus a 2x overload spike served with and
without the brownout controller, all on a deterministic FakeClock and
seeded injectors/traffic — the whole matrix is replayable bit-for-bit.

Acceptance bars (ENFORCED — a violation raises, which the harness
turns into the ERROR row CI greps for):

  * every fault scenario recovers: all requests finish "done" and the
    finished token streams are BIT-IDENTICAL to the uninjected
    baseline's;
  * zero retraces under chaos: each engine ends with exactly one
    compiled decode and one compiled prefill executable;
  * under the overload spike, the brownout path holds availability at
    1.0 (no rejections) by stepping the config ladder down, while the
    exact-only path demonstrably sheds load (availability < 1.0);
  * browned-out serving spends strictly less modeled MAC energy per
    token than exact-only serving.

``run_chaos_matrix`` returns the machine-readable scenario table;
``benchmarks/run.py`` writes it to BENCH_resilience.json (CI artifact).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np


class FakeClock:
    """Deterministic injected time source: each read advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def _small_model():
    import jax
    import jax.numpy as jnp
    from repro.nn import transformer as T
    cfg = T.ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                        head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _tokens(completed):
    return sorted((r.rid, tuple(r.tokens)) for r in completed
                  if r.status == "done")


def _require(ok: bool, msg: str):
    if not ok:
        raise RuntimeError(f"resilience bar violated: {msg}")


def run_chaos_matrix() -> dict:
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.core.power_model import MAC_SAVING_FRAC
    from repro.serve.brownout import BrownoutController
    from repro.serve.engine import Engine, Request
    from repro.serve.faults import FaultEvent, FaultInjector
    from repro.serve.traffic import (TrafficClass, TrafficGenerator,
                                     slo_report)

    cfg, params = _small_model()

    # --- fault scenarios: fixed 3-request workload, faults injected ---
    def serve(injector, checkpointer=None, snapshot_every=0,
              max_ticks=400):
        eng = Engine(params, cfg, max_batch=2, max_len=64,
                     clock=FakeClock(), fault_injector=injector,
                     checkpointer=checkpointer,
                     snapshot_every=snapshot_every,
                     retry_base_s=1e-3, retry_cap_s=4e-3, seed=0)
        for rid, lo in enumerate((0, 10, 20)):
            eng.submit(Request(
                rid=rid, prompt=np.arange(lo, lo + 5, dtype=np.int32),
                max_new_tokens=10, ttft_slo_s=60.0, e2e_slo_s=60.0))
        ticks = 0
        t0 = time.perf_counter()
        while ((eng.queue and not eng._draining)
               or any(s is not None for s in eng.slots)) \
                and ticks < max_ticks:
            eng.step()
            ticks += 1
        wall_s = time.perf_counter() - t0
        return eng, _tokens(eng.completed), ticks, wall_s

    base_eng, want, base_ticks, base_s = serve(None)
    _require(len(want) == 3, f"baseline must finish 3 requests: {want}")

    plans = {
        "nan_logits": [FaultEvent(tick=2, kind="nan_logits"),
                       FaultEvent(tick=5, kind="nan_logits", slot=1,
                                  value=float("inf"))],
        "step_fail": [FaultEvent(tick=2, kind="step_fail"),
                      FaultEvent(tick=3, kind="step_fail")],
        "clock_skew": [FaultEvent(tick=3, kind="clock_skew",
                                  skew_s=2.0)],
        "stall": [FaultEvent(tick=4, kind="stall", stall_s=2.0)],
    }
    scenarios = [{"scenario": "baseline", "ticks": base_ticks,
                  "recovery_ticks": 0, "faults_fired": 0,
                  "bit_identical": True, "zero_retraces": True,
                  "wall_s": round(base_s, 3),
                  **base_eng.resilience_report()}]
    print(f"resilience_baseline,{base_s * 1e6 / base_ticks:.1f},"
          f"ticks={base_ticks};requests=3")

    for name, plan in plans.items():
        inj = FaultInjector(plan, seed=0)
        eng, got, ticks, wall = serve(inj)
        identical = got == want
        retraces_ok = (eng._decode._cache_size() == 1
                       and eng._prefill._cache_size() == 1)
        _require(identical, f"{name}: tokens diverged from baseline")
        _require(retraces_ok, f"{name}: chaos run retraced "
                 f"(decode={eng._decode._cache_size()}, "
                 f"prefill={eng._prefill._cache_size()})")
        _require(sum(inj.counts.values()) == len(plan),
                 f"{name}: {inj.counts} fired, planned {len(plan)}")
        row = {"scenario": name, "ticks": ticks,
               "recovery_ticks": ticks - base_ticks,
               "faults_fired": sum(inj.counts.values()),
               "bit_identical": identical, "zero_retraces": retraces_ok,
               "wall_s": round(wall, 3), **eng.resilience_report()}
        scenarios.append(row)
        print(f"resilience_{name},{wall * 1e6 / max(ticks, 1):.1f},"
              f"recovery_ticks={row['recovery_ticks']};"
              f"faults={row['faults_fired']};bit_identical=True;"
              f"zero_retraces=True")

    # --- kill-and-restore: a successor engine finishes the stream ----
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp)
        eng = Engine(params, cfg, max_batch=2, max_len=64,
                     clock=FakeClock(), checkpointer=ck, seed=0)
        for rid, lo in enumerate((0, 10, 20)):
            eng.submit(Request(
                rid=rid, prompt=np.arange(lo, lo + 5, dtype=np.int32),
                max_new_tokens=10, ttft_slo_s=60.0, e2e_slo_s=60.0))
        for _ in range(4):
            eng.step()
        step = eng.save_snapshot()

        succ = Engine(params, cfg, max_batch=2, max_len=64,
                      clock=FakeClock(), checkpointer=ck, seed=0)
        t0 = time.perf_counter()
        succ.restore_snapshot(step)
        restore_s = time.perf_counter() - t0
        got = _tokens(succ.run())
        identical = got == want
        retraces_ok = succ._decode._cache_size() == 1
        _require(identical,
                 "snapshot_restore: successor tokens diverged")
        _require(retraces_ok, "snapshot_restore: successor retraced")
        scenarios.append({
            "scenario": "snapshot_restore", "ticks": 4,
            "recovery_ticks": 0, "faults_fired": 1,
            "bit_identical": identical, "zero_retraces": retraces_ok,
            "restore_s": round(restore_s, 4),
            **succ.resilience_report()})
        print(f"resilience_snapshot_restore,{restore_s * 1e6:.1f},"
              f"bit_identical=True;zero_retraces=True;"
              f"restores={succ.n_restores}")

    # --- 2x overload spike: brownout-by-config vs exact-only ---------
    probe = Engine(params, cfg)
    exact_tok_pj = (probe._energy_pj_mean(probe.approx_cfg)
                    * probe.macs_per_token)
    cap = 2.5 * exact_tok_pj     # 2 slots at exact, all 4 at cfg 31

    def spike_run(with_brownout: bool):
        gen = TrafficGenerator(
            (TrafficClass("chat", prompt_len=6, max_new_tokens=6),),
            rate_per_tick=0.15, seed=11, spikes=((10, 70, 4.0),))
        bo = BrownoutController(ladder=(0, 31), high_watermark=0.3,
                                low_watermark=0.1, hold_ticks=4) \
            if with_brownout else None
        eng = Engine(params, cfg, max_batch=4, max_len=64,
                     queue_capacity=6, power_cap_pj_per_tick=cap,
                     brownout=bo, clock=FakeClock(), seed=0)
        offered = []
        t0 = time.perf_counter()
        for t in range(110):
            for r in gen.arrivals(t):
                offered.append(r)
                eng.submit(r)
            eng.step()
        eng.run(max_ticks=200)   # drain the tail
        wall = time.perf_counter() - t0
        pj_tok = (eng.mac_energy_pj_per_param
                  / max(eng.n_tokens_charged, 1) * eng.macs_per_token)
        return eng, bo, slo_report(offered), len(offered), pj_tok, wall

    eng_b, bo, rep_b, offered_b, pj_b, wall_b = spike_run(True)
    eng_x, _, rep_x, offered_x, pj_x, wall_x = spike_run(False)
    _require(offered_b == offered_x,
             "traffic replay broke: offered loads differ")

    avail_b = rep_b["total"]["availability"]
    avail_x = rep_x["total"]["availability"]
    _require(avail_b == 1.0,
             f"brownout must hold availability at 1.0, got {avail_b} "
             f"({eng_b.n_rejected} rejected)")
    _require(avail_x < 1.0,
             f"exact-only spike should shed load, got {avail_x}")
    _require(bo.n_escalations >= 1, "spike never escalated brownout")
    _require(bo.level == 0 and bo.n_recoveries == bo.n_escalations,
             f"brownout must recover after the spike "
             f"(level={bo.level}, esc={bo.n_escalations}, "
             f"rec={bo.n_recoveries})")
    _require(np.all(eng_b.approx_cfg == 0),
             "recovery must restore the exact base config")
    _require(pj_b < pj_x,
             f"brownout must cut energy/token: {pj_b:.1f} vs {pj_x:.1f}")
    for eng, tag in ((eng_b, "brownout"), (eng_x, "exact")):
        _require(eng._decode._cache_size() == 1
                 and eng._prefill._cache_size() == 1,
                 f"spike({tag}) retraced the decode executable")

    saving = 1.0 - pj_b / pj_x
    spike_rows = []
    for tag, eng, bo_, rep, pj, wall in (
            ("overload_spike_brownout", eng_b, bo, rep_b, pj_b, wall_b),
            ("overload_spike_exact", eng_x, None, rep_x, pj_x, wall_x)):
        spike_rows.append({
            "scenario": tag, "offered": offered_b,
            "availability": rep["total"]["availability"],
            "slo_attainment": rep["total"]["slo_attainment"],
            "classes": rep["classes"],
            "energy_pj_per_token": pj,
            "escalations": bo_.n_escalations if bo_ else 0,
            "recoveries": bo_.n_recoveries if bo_ else 0,
            "zero_retraces": True, "wall_s": round(wall, 3),
            **eng.resilience_report()})
        print(f"resilience_{tag},{wall * 1e6 / 110:.1f},"
              f"availability={rep['total']['availability']:.3f};"
              f"rejected={eng.n_rejected};pj_per_token={pj:.1f}")
    print(f"resilience_brownout_saving,0.0,"
          f"energy_saving={saving * 100:.1f}%;"
          f"ladder_cfg31_saving={MAC_SAVING_FRAC[31] * 100:.1f}%")
    scenarios.extend(spike_rows)

    return {
        "bench": "resilience",
        "model": {"n_layers": 2, "d_model": 32, "vocab": 64},
        "power_cap_pj_per_tick": cap,
        "exact_pj_per_token": exact_tok_pj,
        "brownout_energy_saving": saving,
        "scenarios": scenarios,
        "bars": {"bit_identical_recovery": True, "zero_retraces": True,
                 "spike_availability_with_brownout": avail_b,
                 "spike_availability_exact_only": avail_x},
    }
