"""The PR-9 tentpole quantified: approx-draft self-speculative decoding
(DESIGN.md §12).

Four measurements on the briefly-trained demo LM, bars ENFORCED (a
violation raises and becomes the harness's ERROR row, which CI greps
for):

* **token identity** — the speculative stream (dense AND paged) must be
  IDENTICAL to the non-speculative exact greedy stream: every emitted
  token is the verifier's own argmax, so this is identity by
  construction and any diff is a rewind/window bug;
* **zero retraces** — a (k, draft-cfg) sweep retargeted live through
  ``Engine.set_spec`` must keep every jit cache at ONE entry: k is a
  host loop count and draft_cfg is traced data, so sweeping them
  compiles nothing;
* **throughput** — tokens emitted per verify weight-pass
  (``n_spec_emitted / n_verify_steps`` = 1 + mean accepted drafts) must
  exceed 1.0: speculation must beat one-token-per-step decoding;
* **energy** — modeled serve pJ per emitted token under speculation
  (drafts billed at the draft config, verifies at the service config)
  must come in BELOW the non-speculative exact baseline.

Acceptance rate per (k, draft_cfg) cell is reported alongside.  All
timings are CPU correctness-path numbers; TPU is the perf target.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paged_serving import _drain, _model, _paged_engine


def _reqs(seed, n=4, plen=16, new=24):
    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(1, 64, size=plen),
                    max_new_tokens=new) for i in range(n)]


def _dense_engine(params, cfg, spec=None):
    from repro.serve.engine import Engine
    return Engine(params, cfg, max_batch=4, max_len=64, prefill_pad=16,
                  spec=spec)


def _paged_spec_engine(params, cfg, spec):
    from repro.serve.engine import Engine
    from repro.serve.paged_cache import PagedCacheConfig
    return Engine(params, cfg, max_batch=4, max_len=64,
                  paged=PagedCacheConfig(num_blocks=2 + 24,
                                         block_size=16,
                                         prefill_chunk=16),
                  spec=spec)


def _serve_pj_per_token(eng):
    """Modeled serve-side MAC pJ per EMITTED token — drafts, verifies,
    prefills, and plain decodes all included; probe overhead excluded."""
    return (eng.serve_mac_energy_pj_per_param * eng.macs_per_token
            / max(eng.n_tokens_emitted, 1))


def _identity_and_sweep(params, cfg):
    """One dense + one paged speculative engine, retargeted across the
    (k, draft_cfg) grid; every wave's stream must equal the exact
    greedy baseline captured from a non-speculative engine."""
    from repro.serve.speculative import SpecConfig
    base = _dense_engine(params, cfg)
    for r in _reqs(0):
        base.submit(r)
    want = _drain(base)

    sweep = ((3, 8), (1, 5), (5, 20), (2, 31))
    spec0 = SpecConfig(draft_cfg=sweep[0][1], k=sweep[0][0], max_k=5)
    dense = _dense_engine(params, cfg, spec=spec0)
    paged = _paged_spec_engine(params, cfg, spec0)
    cells = []
    for k, dcfg in sweep:
        spec = SpecConfig(draft_cfg=dcfg, k=k, max_k=5)
        for eng, name in ((dense, "dense"), (paged, "paged")):
            eng.set_spec(spec)
            t0, a0, v0 = (eng.n_spec_ticks, eng.n_spec_emitted,
                          eng.n_verify_steps)
            for r in _reqs(0):
                eng.submit(r)
            got = _drain(eng)
            if got != want:
                raise RuntimeError(
                    f"speculative {name} stream (k={k}, draft_cfg={dcfg}) "
                    f"NOT identical to exact greedy: {got} vs {want}")
            v = eng.n_verify_steps - v0
            cells.append({"path": name, "k": k, "draft_cfg": dcfg,
                          "spec_ticks": eng.n_spec_ticks - t0,
                          "tokens_per_verify_step":
                              (eng.n_spec_emitted - a0) / max(v, 1)})
        paged.allocator.check_consistency(paged._slot_blocks)

    caches = {"dense_decode": dense._decode._cache_size(),
              "dense_verify": dense._verify._cache_size(),
              "paged_decode": paged._decode._cache_size(),
              "paged_prefill_chunk": paged._prefill_chunk._cache_size()}
    bad = {k: v for k, v in caches.items() if v != 1}
    if bad:
        raise RuntimeError(f"(k, draft-cfg) sweep retraced: {bad}")
    return {"sweep": cells, "executables": caches, "identical": True}


def _throughput_and_energy(params, cfg):
    """Spec vs non-spec exact on the same workload: tokens per verify
    weight-pass > 1 and serve pJ/emitted-token strictly below exact."""
    from repro.serve.speculative import SpecConfig

    def mk_dense(s):
        return _dense_engine(params, cfg, spec=s)

    def mk_paged(s):
        if s is None:
            return _paged_engine(params, cfg, max_batch=4, max_len=64,
                                 num_blocks=2 + 24)
        return _paged_spec_engine(params, cfg, s)

    rows = []
    for name, mk in (("dense", mk_dense), ("paged", mk_paged)):
        base = mk(None)
        for r in _reqs(1):
            base.submit(r)
        t0 = time.perf_counter()
        want = _drain(base)
        base_s = time.perf_counter() - t0
        base_pj = _serve_pj_per_token(base)

        spec = mk(SpecConfig(draft_cfg=8, k=3, max_k=5))
        for r in _reqs(1):
            spec.submit(r)
        t0 = time.perf_counter()
        got = _drain(spec)
        spec_s = time.perf_counter() - t0
        if got != want:
            raise RuntimeError(f"spec {name} A/B stream diverged")
        tv = spec.n_spec_emitted / max(spec.n_verify_steps, 1)
        spec_pj = _serve_pj_per_token(spec)
        if tv <= 1.0:
            raise RuntimeError(
                f"throughput bar violated ({name}): "
                f"{tv:.2f} tokens/verify-step (must be > 1)")
        if spec_pj >= base_pj:
            raise RuntimeError(
                f"energy bar violated ({name}): spec {spec_pj:.0f} "
                f"pJ/token >= exact {base_pj:.0f}")
        # accepted drafts = emitted minus the one correction/bonus token
        # each slot-verify contributes; rate is over tokens DRAFTED
        acc = ((spec.n_spec_emitted - spec.n_verify_steps)
               / max(spec.n_draft_tokens, 1))
        rows.append({"path": name, "k": 3, "draft_cfg": 8,
                     "tokens_per_verify_step": tv,
                     "acceptance_rate": acc,
                     "spec_pj_per_token": spec_pj,
                     "exact_pj_per_token": base_pj,
                     "energy_frac": spec_pj / base_pj,
                     "spec_wall_s": spec_s, "exact_wall_s": base_s})
    return {"ab": rows}


def run_speculative() -> dict:
    params, cfg = _model()
    out = {"bench": "speculative", "mode": "cpu-interpret",
           "model": {"n_layers": 2, "d_model": 32, "vocab": 64}}
    t0 = time.perf_counter()
    out["identity_sweep"] = _identity_and_sweep(params, cfg)
    print(f"spec_identity_sweep,{(time.perf_counter()-t0)*1e6:.1f},"
          f"identical=True;cells={len(out['identity_sweep']['sweep'])};"
          f"executables=1_each")
    t0 = time.perf_counter()
    out["throughput_energy"] = _throughput_and_energy(params, cfg)
    for r in out["throughput_energy"]["ab"]:
        print(f"spec_ab_{r['path']},{(time.perf_counter()-t0)*1e6:.1f},"
              f"tokens_per_verify={r['tokens_per_verify_step']:.2f};"
              f"acceptance={r['acceptance_rate']*100:.0f}%;"
              f"pj_frac_of_exact={r['energy_frac']:.2f}")
    return out


if __name__ == "__main__":
    import json
    result = run_speculative()
    with open("BENCH_spec_decode.json", "w") as fh:
        json.dump(result, fh, indent=2)
