"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
  memory term     = HLO_bytes_per_device / HBM_bw             [s]
  collective term = collective_bytes_per_device / link_bw     [s]
dominant = argmax; MODEL_FLOPS = 6*N*D (train, N=active params) or
2*N*D (prefill) or 2*N per token (decode); usefulness ratio =
MODEL_FLOPS / (HLO_FLOPs * n_devices).

HLO numbers are the scan-corrected ("corrected") values from the probe
extrapolation (see launch/dryrun.py).  xLSTM gets an analytic sLSTM
correction (the per-timestep scan body is invisible to HloCostAnalysis).
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs.registry import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

DRYRUN_DIR = "experiments/dryrun"


def active_params(cfg) -> float:
    """Active parameters per token (MoE counts top_k experts only)."""
    n = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    per_layer_attn = (cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads)
                      * cfg.head_dim
                      + cfg.n_heads * cfg.head_dim * cfg.d_model)
    kinds = cfg.layer_kinds()
    total = float(n)
    for kind in kinds:
        if kind in ("global", "local"):
            total += per_layer_attn
            if cfg.n_experts:
                glu = 3
                total += (cfg.top_k * glu * cfg.d_model * cfg.d_ff
                          + cfg.d_model * cfg.n_experts)
            elif cfg.d_ff:
                glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
                total += glu * cfg.d_model * cfg.d_ff
        elif kind == "recurrent":
            total += (2 * cfg.d_model * cfg.lru_width
                      + cfg.lru_width * cfg.d_model
                      + 2 * cfg.lru_width * cfg.lru_width)
            glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
            total += glu * cfg.d_model * cfg.d_ff
        elif kind == "mlstm":
            di = int(cfg.d_model * cfg.mlstm_proj_factor)
            total += 2 * cfg.d_model * di + 3 * di * di + di * cfg.d_model
        elif kind == "slstm":
            total += (4 * cfg.d_model * cfg.d_model
                      + 4 * cfg.d_model * cfg.d_model // cfg.n_heads
                      + 3 * cfg.d_model * int(cfg.d_model * 4 / 3))
    if cfg.encoder_decoder:   # encoder layers (same shape as decoder attn+mlp)
        total += cfg.n_enc_layers * (per_layer_attn
                                     + 2 * cfg.d_model * cfg.d_ff)
    return total


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    n = active_params(cfg)
    if cell.step == "train":
        tokens = cell.global_batch * cell.seq_len
        if cfg.encoder_decoder:
            tokens = cell.global_batch * (cell.seq_len + cell.seq_len // 8)
        return 6.0 * n * tokens
    if cell.step == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch      # decode: one token per row


def slstm_correction(arch: str, shape: str) -> float:
    """Analytic per-device flops of the sLSTM time scan (invisible to
    HloCostAnalysis): recurrent einsum + ~10 elementwise ops per step."""
    if arch != "xlstm-350m":
        return 0.0
    cfg = get_config(arch)
    cell = SHAPES[shape]
    if cell.step == "decode":
        return 0.0   # single step is fully visible
    n_slstm = sum(1 for k in cfg.layer_kinds() if k == "slstm")
    d = cfg.d_model
    hd = d // cfg.n_heads
    per_step = 2 * d * 4 * hd + 10 * 4 * d          # recurrent matmul + elementwise
    tokens = cell.global_batch * cell.seq_len
    mult = 3.0 if cell.step == "train" else 1.0     # fwd+bwd
    return n_slstm * tokens * per_step * mult / 256.0


def load_cells(mesh_tag: str = "pod16x16") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh_tag, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def roofline_row(rec: dict) -> dict:
    arch, shape = rec["arch"], rec["shape"]
    nd = rec["n_devices"]
    corr = rec.get("corrected") or {}
    flops = corr.get("flops_per_device") or rec["cost"]["flops_per_device"]
    flops += slstm_correction(arch, shape)
    bts = corr.get("bytes_per_device") or rec["cost"]["bytes_per_device"]
    coll = (corr.get("collectives") or rec["collectives"]).get(
        "total_bytes", 0)
    t_c = flops / PEAK_BF16_FLOPS
    t_m = bts / HBM_BW
    t_x = coll / (3 * ICI_BW)          # ~3 usable ICI links per v5e chip
    dominant = ["compute", "memory", "collective"][
        int(np.argmax([t_c, t_m, t_x]))]
    mf = model_flops(arch, shape)
    useful = mf / max(flops * nd, 1.0)
    bound = max(t_c, t_m, t_x)
    roofline_frac = t_c / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape, "n_devices": nd,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant, "model_flops": mf,
        "useful_ratio": useful, "roofline_frac": roofline_frac,
        "mem_gib": rec["memory"]["peak_estimate_bytes"] / 2 ** 30,
    }


def print_roofline_csv(mesh_tag: str = "pod16x16"):
    rows = [roofline_row(r) for r in load_cells(mesh_tag)]
    for r in rows:
        derived = (f"compute_s={r['compute_s']:.3e};memory_s="
                   f"{r['memory_s']:.3e};collective_s={r['collective_s']:.3e};"
                   f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};"
                   f"roofline_frac={r['roofline_frac']:.2f}")
        print(f"roofline_{r['arch']}_{r['shape']},0.0,{derived}")


def markdown_table(mesh_tag: str = "pod16x16") -> str:
    rows = [roofline_row(r) for r in load_cells(mesh_tag)]
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | mem GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {r['mem_gib']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
