"""Shared benchmark utilities: timing + the trained quantized MLP used by
the Fig 5/6/7 reproductions (trained once per process, cached)."""
from __future__ import annotations

import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


def time_call(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or \
            isinstance(r, (jnp.ndarray, tuple, list, dict)) else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


@lru_cache(maxsize=1)
def trained_quantized_mlp():
    """Train the paper MLP on the (procedural) MNIST data and quantize."""
    from repro.data.synthetic_mnist import load_mnist
    from repro.nn import mlp_paper as M
    from repro.train.optimizer import adamw, apply_updates

    data = load_mnist(n_train=6000, n_test=2000, seed=0)
    params = M.init_params(jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3, weight_decay=1e-4)
    state = opt.init(params)

    def loss_fn(p, x, y):
        lp = jax.nn.log_softmax(M.apply_float(p, x))
        return -jnp.take_along_axis(lp, y[:, None], axis=1).mean()

    @jax.jit
    def step(p, s, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    rng = np.random.default_rng(0)
    for epoch in range(30):
        idx = rng.permutation(len(data.train_x))
        for i in range(0, len(idx) - 127, 128):
            b = idx[i:i + 128]
            params, state, _ = step(params, state,
                                    jnp.asarray(data.train_x[b]),
                                    jnp.asarray(data.train_y[b]))
    qm = M.QuantizedMLP.from_float(params, data.train_x[:2000])
    return params, qm, data
