"""The PR-8 tentpole quantified: paged KV serving (DESIGN.md §11).

Five measurements on the demo LM, bars ENFORCED (a violation raises and
becomes the harness's ERROR row, which CI greps for):

* **bit-identity** — dense vs paged token streams at equal occupancy
  (equal-length lockstep greedy streams) must be IDENTICAL;
* **stream sweep** — ONE paged engine serves 4 / 16 / 64 / 256
  concurrent streams (tokens/s + modeled energy/token per wave) with a
  live error-config retune mid-sweep and ZERO retraces: one compiled
  decode executable for the whole sweep;
* **capacity at fixed HBM** — on a pool byte-equal to the dense
  engine's 4x64 cache, the paged engine must hold >= 3x the dense
  engine's concurrent streams with zero preemptions;
* **chunked prefill** — under a long-prompt-heavy trace, interleaving
  chunk-sized prefill slices must cut the P99 decode-tick stall
  (>= 1.2x) without degrading first-token attainment;
* **prefix reuse** — 8 streams sharing a 64-token prefix must spend
  <= 0.6x the prefill tokens of the no-sharing run with IDENTICAL
  output streams.

All timings are CPU correctness-path numbers; TPU is the perf target.
"""
from __future__ import annotations

import time

import numpy as np


def _model():
    """Briefly-trained demo LM.  A random-init model has near-uniform
    logits, so every argmax is a near-tie and flips under the int8
    datapath's shared-dynamic-range quantization (the activation scale
    is per-tensor: batch composition perturbs every row at the last
    grid bit).  Training restores the margins the token-stream bars
    rely on — same reasoning as bench_scheduler."""
    import jax
    import jax.numpy as jnp
    from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
    from repro.nn import transformer as T
    from repro.train import optimizer as opt_mod
    from repro.train.step import build_train_step, init_state
    cfg = T.ModelConfig(name="demo", n_layers=2, d_model=32, n_heads=2,
                        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
                        scan_layers=False, remat=False, q_chunk=8,
                        loss_chunks=1, compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(SyntheticLMConfig(vocab_size=64, seq_len=48,
                                         global_batch=16, n_templates=4,
                                         seed=0))
    opt = opt_mod.adamw(lr=4e-3)
    train = jax.jit(build_train_step(cfg, opt))
    state = init_state(params, opt)
    for i in range(300):
        b = data.batch(i)
        state, _m = train(state,
                          {k: jnp.asarray(v) for k, v in b.items()})
    import numpy as _np
    params = jax.tree.map(_np.asarray, state["params"])
    return params, cfg


def _paged_engine(params, cfg, *, max_batch, max_len, num_blocks,
                  block_size=16, chunk=16, share=False):
    from repro.serve.engine import Engine
    from repro.serve.paged_cache import PagedCacheConfig
    return Engine(params, cfg, max_batch=max_batch, max_len=max_len,
                  paged=PagedCacheConfig(num_blocks=num_blocks,
                                         block_size=block_size,
                                         prefill_chunk=chunk,
                                         share_prefixes=share))


def _drain(eng, max_ticks=5000):
    done = eng.run(max_ticks=max_ticks)
    bad = [r.rid for r in done if r.status != "done"]
    if bad:
        raise RuntimeError(f"requests did not finish: {bad}")
    return {r.rid: list(r.tokens) for r in done}


def _bit_identity(params, cfg):
    from repro.serve.engine import Engine, Request
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 64, size=16) for _ in range(4)]
    dense = Engine(params, cfg, max_batch=4, max_len=64, prefill_pad=16)
    paged = _paged_engine(params, cfg, max_batch=4, max_len=64,
                          num_blocks=2 + 16)
    for i, p in enumerate(prompts):
        dense.submit(Request(rid=i, prompt=p, max_new_tokens=12))
        paged.submit(Request(rid=i, prompt=p, max_new_tokens=12))
    d, q = _drain(dense), _drain(paged)
    if d != q:
        raise RuntimeError(f"paged decode NOT bit-identical to dense at "
                           f"equal occupancy: {d} vs {q}")
    paged.allocator.check_consistency(paged._slot_blocks)
    return {"streams": 4, "prompt_len": 16, "new_tokens": 12,
            "identical": True}


def _stream_sweep(params, cfg):
    from repro.serve.engine import Request
    rng = np.random.default_rng(1)
    eng = _paged_engine(params, cfg, max_batch=256, max_len=64,
                        num_blocks=2 + 1024)
    rid = 0
    waves = []
    for wave, n_streams in enumerate((4, 16, 64, 256)):
        if wave == 2:
            eng.set_approx_cfg(16)      # live knob turn mid-sweep
        reqs = []
        for _ in range(n_streams):
            reqs.append(Request(
                rid=rid, prompt=rng.integers(1, 64, size=int(
                    rng.integers(4, 25))), max_new_tokens=16))
            rid += 1
        e0, n0 = eng.mac_energy_pj_per_param, eng.n_tokens_charged
        t0 = time.perf_counter()
        for r in reqs:
            if not eng.submit(r):
                raise RuntimeError("queue overflow in sweep")
        _drain(eng)
        dt = time.perf_counter() - t0
        new_tokens = 16 * n_streams
        pj_tok = ((eng.mac_energy_pj_per_param - e0)
                  / max(eng.n_tokens_charged - n0, 1) * eng.macs_per_token)
        waves.append({"streams": n_streams,
                      "approx_cfg": 16 if wave >= 2 else 0,
                      "tokens_per_s": new_tokens / dt,
                      "mac_pj_per_token": pj_tok,
                      "wall_s": dt})
        eng.allocator.check_consistency(eng._slot_blocks)
    n_exec = eng._decode._cache_size()
    if n_exec != 1:
        raise RuntimeError(
            f"stream sweep retraced: {n_exec} decode executables")
    if eng._prefill._cache_size() != 1:
        raise RuntimeError("prefill retraced across prompt lengths")
    return {"waves": waves, "decode_executables": n_exec,
            "preempted": eng.n_preempted}


def _capacity(params, cfg):
    """Same HBM, more streams: the dense 4x64 cache is 256 token-rows;
    16 usable blocks of 16 is the SAME byte count, paged."""
    from repro.serve.engine import Request
    rng = np.random.default_rng(2)
    eng = _paged_engine(params, cfg, max_batch=16, max_len=64,
                        num_blocks=2 + 16)
    for i in range(16):
        eng.submit(Request(rid=i, prompt=rng.integers(1, 64, size=8),
                           max_new_tokens=6))
    peak = 0
    while eng.step():
        peak = max(peak, sum(s is not None for s in eng.slots))
    _drain(eng)
    dense_streams = 4
    if peak < 3 * dense_streams:
        raise RuntimeError(
            f"capacity bar violated: peak {peak} concurrent streams "
            f"< 3x dense ({dense_streams})")
    if eng.n_preempted:
        raise RuntimeError(
            f"capacity run preempted {eng.n_preempted} streams")
    return {"pool_token_rows": 256, "dense_streams": dense_streams,
            "paged_streams": peak, "ratio": peak / dense_streams,
            "preempted": 0}


def _chunked_prefill_ab(params, cfg):
    """4 short decode streams + 6 long prompts arriving every 5 ticks.
    chunk=16 interleaves a 16-token slice per tick; chunk=256 swallows
    each long prompt whole and stalls every in-flight stream for that
    tick.  Ticks are wall-timed AFTER a warmup drain so compilation
    never lands inside the measured trace."""
    from repro.serve.engine import Request
    long_len, deadline_ticks = 256, 40

    def run(chunk):
        rng = np.random.default_rng(3)
        eng = _paged_engine(params, cfg, max_batch=10, max_len=320,
                            num_blocks=2 + 128, chunk=chunk)
        # warmup: compile decode + both prefill paths off the clock
        eng.submit(Request(rid=900, prompt=rng.integers(1, 64,
                                                        size=long_len),
                           max_new_tokens=2))
        eng.submit(Request(rid=901, prompt=rng.integers(1, 64, size=8),
                           max_new_tokens=2))
        _drain(eng)
        for i in range(4):      # short interactive streams
            eng.submit(Request(rid=i, prompt=rng.integers(1, 64, size=8),
                               max_new_tokens=48))
        submitted_at, first_at = {}, {}
        tick_times = []
        tick = 0
        running = True
        while running:
            if tick % 5 == 2 and tick < 30:     # long prompts trickle in
                rid = 100 + tick
                eng.submit(Request(rid=rid,
                                   prompt=rng.integers(1, 64,
                                                       size=long_len),
                                   max_new_tokens=8))
                submitted_at[rid] = tick
            t0 = time.perf_counter()
            running = eng.step()
            tick_times.append(time.perf_counter() - t0)
            tick += 1
            for r in eng.slots:
                if r is not None and r.tokens and r.rid not in first_at:
                    first_at[r.rid] = tick
            if tick > 4000:
                raise RuntimeError("chunked-prefill trace did not drain")
        ttft = [first_at.get(rid, 10 ** 9) - t0
                for rid, t0 in submitted_at.items()]
        attained = sum(t <= deadline_ticks for t in ttft) / len(ttft)
        p99 = float(np.percentile(np.asarray(tick_times) * 1e6, 99))
        return p99, attained

    p99_chunked, att_chunked = run(16)
    p99_oneshot, att_oneshot = run(256)
    ratio = p99_oneshot / p99_chunked
    if ratio < 1.2:
        raise RuntimeError(
            f"chunked prefill bar violated: P99 tick stall improved only "
            f"{ratio:.2f}x (< 1.2x)")
    if att_chunked < att_oneshot:
        raise RuntimeError(
            f"chunked prefill degraded TTFT attainment: "
            f"{att_chunked:.2f} < {att_oneshot:.2f}")
    return {"long_prompt_len": long_len, "chunk": 16,
            "p99_tick_us_chunked": p99_chunked,
            "p99_tick_us_oneshot": p99_oneshot,
            "p99_improvement": ratio,
            "ttft_attainment_chunked": att_chunked,
            "ttft_attainment_oneshot": att_oneshot,
            "ttft_deadline_ticks": deadline_ticks}


def _prefix_reuse(params, cfg):
    from repro.serve.engine import Request
    rng = np.random.default_rng(4)
    common = rng.integers(1, 64, size=64)
    tails = [rng.integers(1, 64, size=8) for _ in range(8)]

    def run(share):
        eng = _paged_engine(params, cfg, max_batch=8, max_len=128,
                            num_blocks=2 + 80, block_size=16, chunk=16,
                            share=share)
        eng.submit(Request(rid=0, prompt=np.concatenate([common, tails[0]]),
                           max_new_tokens=24))
        for _ in range(6):      # register the leader's full blocks
            eng.step()
        for i, tail in enumerate(tails[1:], start=1):
            eng.submit(Request(rid=i, prompt=np.concatenate([common, tail]),
                               max_new_tokens=12))
        toks = _drain(eng)
        eng.allocator.check_consistency(eng._slot_blocks)
        return eng, toks

    sharing, toks_share = run(True)
    isolated, toks_iso = run(False)
    if toks_share != toks_iso:
        raise RuntimeError("prefix sharing changed output tokens")
    frac = sharing.n_prefill_tokens / isolated.n_prefill_tokens
    if frac > 0.6:
        raise RuntimeError(
            f"prefix-reuse bar violated: sharing spent {frac:.2f}x the "
            "prefill tokens (bar <= 0.6x)")
    return {"streams": 8, "shared_prefix_len": 64,
            "shared_blocks": sharing.n_shared_blocks,
            "prefill_tokens_sharing": sharing.n_prefill_tokens,
            "prefill_tokens_isolated": isolated.n_prefill_tokens,
            "prefill_token_frac": frac}


def run_paged_serving() -> dict:
    params, cfg = _model()
    out = {"bench": "paged_serving", "mode": "cpu-interpret",
           "model": {"n_layers": 2, "d_model": 32, "vocab": 64}}
    t0 = time.perf_counter()
    out["bit_identity"] = _bit_identity(params, cfg)
    print(f"paged_bit_identity,{(time.perf_counter()-t0)*1e6:.1f},"
          f"identical=True;streams=4")
    t0 = time.perf_counter()
    out["stream_sweep"] = _stream_sweep(params, cfg)
    for w in out["stream_sweep"]["waves"]:
        print(f"paged_sweep_{w['streams']}_streams,"
              f"{w['wall_s']*1e6:.1f},tok_per_s={w['tokens_per_s']:.1f};"
              f"pj_per_tok={w['mac_pj_per_token']:.0f};"
              f"cfg={w['approx_cfg']}")
    print(f"paged_zero_retrace,0.0,"
          f"decode_executables={out['stream_sweep']['decode_executables']}")
    t0 = time.perf_counter()
    out["capacity"] = _capacity(params, cfg)
    print(f"paged_capacity_fixed_hbm,{(time.perf_counter()-t0)*1e6:.1f},"
          f"streams={out['capacity']['paged_streams']}_vs_dense_"
          f"{out['capacity']['dense_streams']};"
          f"ratio={out['capacity']['ratio']:.1f}x")
    t0 = time.perf_counter()
    out["chunked_prefill"] = _chunked_prefill_ab(params, cfg)
    cp = out["chunked_prefill"]
    print(f"paged_chunked_prefill,{(time.perf_counter()-t0)*1e6:.1f},"
          f"p99_improvement={cp['p99_improvement']:.2f}x;"
          f"ttft_attainment={cp['ttft_attainment_chunked']:.2f}")
    t0 = time.perf_counter()
    out["prefix_reuse"] = _prefix_reuse(params, cfg)
    pr = out["prefix_reuse"]
    print(f"paged_prefix_reuse,{(time.perf_counter()-t0)*1e6:.1f},"
          f"prefill_frac={pr['prefill_token_frac']:.2f};"
          f"shared_blocks={pr['shared_blocks']}")
    return out


if __name__ == "__main__":
    import json
    result = run_paged_serving()
    with open("BENCH_paged_serving.json", "w") as fh:
        json.dump(result, fh, indent=2)
