"""Benchmark harness — one function per paper table/figure plus the
kernel micro-benchmarks and the roofline reader.

Prints ``name,us_per_call,derived`` CSV rows (derived = the quantity the
paper's table/figure reports, as name=value pairs).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table1 fig5  # subset
"""
from __future__ import annotations

import sys
import time

import numpy as np


def bench_table1_multiplier_metrics():
    """Paper Table I: ER/MRED/NMED min/max/avg over the 31 approx configs."""
    from repro.core.error_metrics import PAPER_TABLE_I, summary_table
    t0 = time.perf_counter()
    s = summary_table()
    us = (time.perf_counter() - t0) * 1e6
    derived = ";".join(
        f"{k}={s[k]*100:.4f}%(paper {PAPER_TABLE_I[k]*100:.4f}%)"
        for k in ("er_min", "er_max", "er_avg", "mred_min", "mred_max",
                  "mred_avg", "nmed_avg"))
    print(f"table1_multiplier_metrics,{us:.1f},{derived}")


def bench_fig5_power_improvement():
    """Paper Fig 5: % network power improvement per config."""
    from repro.core.power_model import network_improvement_pct
    t0 = time.perf_counter()
    imps = [network_improvement_pct(c) for c in range(32)]
    us = (time.perf_counter() - t0) * 1e6
    derived = (f"max={max(imps):.2f}%(paper 13.33%);"
               f"avg_cfg1-31={np.mean(imps[1:]):.2f}%;"
               f"curve={'|'.join(f'{i:.1f}' for i in imps)}")
    print(f"fig5_power_improvement,{us:.1f},{derived}")


def bench_fig6_power_accuracy():
    """Paper Fig 6: network power + MLP accuracy per config."""
    from benchmarks.common import time_call, trained_quantized_mlp
    from repro.core.power_model import network_power_mw
    params, qm, data = trained_quantized_mlp()
    x, y = data.test_x, data.test_y
    t0 = time.perf_counter()
    accs = [qm.accuracy(x, y, config=c) for c in range(32)]
    us = (time.perf_counter() - t0) * 1e6 / 32
    powers = [network_power_mw(c) for c in range(32)]
    derived = (f"acc_cfg0={accs[0]*100:.2f}%;acc_min={min(accs)*100:.2f}%;"
               f"acc_avg_1-31={np.mean(accs[1:])*100:.2f}%;"
               f"drop_worst={(accs[0]-min(accs))*100:.2f}%(paper 0.92%);"
               f"power_mw_cfg0={powers[0]:.2f}(paper 5.55);"
               f"power_mw_cfg31={powers[31]:.2f}(paper 4.81)")
    print(f"fig6_power_accuracy,{us:.1f},{derived}")


def bench_fig7_tradeoff():
    """Paper Fig 7: accuracy <-> power trade-off (+ controller pick)."""
    from benchmarks.common import trained_quantized_mlp
    from repro.core.controller import select_uniform_config
    from repro.core.power_model import network_power_mw
    params, qm, data = trained_quantized_mlp()
    x, y = data.test_x[:1000], data.test_y[:1000]
    t0 = time.perf_counter()
    best, accs = select_uniform_config(lambda c: qm.accuracy(x, y, c),
                                       budget=0.01)
    us = (time.perf_counter() - t0) * 1e6
    pairs = "|".join(f"{network_power_mw(c):.2f}:{accs[c]*100:.1f}"
                     for c in (0, 1, 8, 16, 24, 31))
    derived = (f"controller_pick=cfg{best};"
               f"power_at_pick={network_power_mw(best):.2f}mW;"
               f"acc_at_pick={accs[best]*100:.2f}%;power:acc={pairs}")
    print(f"fig7_tradeoff,{us:.1f},{derived}")


def bench_hw_sim():
    """Cycle-accurate datapath throughput + energy (Section III-C/D)."""
    from benchmarks.common import trained_quantized_mlp
    from repro.core.hw_sim import CLOCK_HZ, simulate
    _, qm, data = trained_quantized_mlp()
    imgs = data.test_x[:20]
    t0 = time.perf_counter()
    res = simulate(qm, imgs, config=0)
    us = (time.perf_counter() - t0) * 1e6 / len(imgs)
    cyc_per_img = res.cycles / len(imgs)
    fps = CLOCK_HZ / cyc_per_img
    derived = (f"cycles_per_image={cyc_per_img:.0f};imgs_per_s@100MHz={fps:.0f};"
               f"power={res.avg_power_mw:.3f}mW(paper 5.55)")
    print(f"hw_sim_datapath,{us:.1f},{derived}")


def bench_approx_mac_kernel():
    """approx-MAC matmul micro-bench: XLA int8 path vs f32 matmul."""
    import jax
    import jax.numpy as jnp
    from benchmarks.common import time_call
    from repro.core.approx_matmul import approx_matmul_operand
    rng = np.random.default_rng(0)
    m = k = n = 512
    a8 = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    af = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    bf = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    f_exact = jax.jit(lambda x, w: x @ w)
    f_q0 = jax.jit(lambda x, w: approx_matmul_operand(x, w, 0))
    f_q31 = jax.jit(lambda x, w: approx_matmul_operand(x, w, 31))
    t_f = time_call(f_exact, af, bf)
    t_q0 = time_call(f_q0, a8, b8)
    t_q31 = time_call(f_q31, a8, b8)
    print(f"approx_mac_f32_matmul_512,{t_f:.1f},GFLOP/s="
          f"{2*m*k*n/t_f/1e3:.1f}")
    print(f"approx_mac_int8_cfg0_512,{t_q0:.1f},GOP/s={2*m*k*n/t_q0/1e3:.1f}")
    print(f"approx_mac_int8_cfg31_512,{t_q31:.1f},overhead_vs_cfg0="
          f"{t_q31/t_q0:.2f}x")


def bench_pallas_kernels_interpret():
    """Pallas kernels in interpret mode (correctness-path timing only —
    TPU is the performance target, see EXPERIMENTS.md §Roofline)."""
    import jax.numpy as jnp
    from benchmarks.common import time_call
    from repro.kernels.approx_mac.ops import approx_mac
    from repro.kernels.flash_attention.ops import flash_attn
    import jax
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-127, 128, (128, 256)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (256, 128)), jnp.int8)
    t = time_call(lambda: approx_mac(a, b, 8, interpret=True), iters=3)
    print(f"pallas_approx_mac_interpret_128x256x128,{t:.1f},mode=interpret")
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 128)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 128)), jnp.float32)
    t = time_call(lambda: flash_attn(q, k, k, bq=64, bk=64, interpret=True),
                  iters=3)
    print(f"pallas_flash_attn_interpret_b1s128,{t:.1f},mode=interpret")


def bench_pallas_path():
    """The PR-2 tentpole quantified: the fused approx-MAC serving path.

    Three A/Bs on one float-in/float-out approx dense —
      * backend: XLA operand path vs the fused Pallas kernel;
      * fusion: one pallas_call vs the PR-1 quantize->kernel->rescale
        three-pass pipeline (two extra HBM round-trips);
      * per-tile: a mixed per-N-block config vector on the same
        executable (the per-neuron knob costs nothing extra);
    plus the (bm, bn, bk) block-shape autotune sweep.  Emits CSV rows
    AND machine-readable BENCH_pallas_path.json (the perf trajectory
    artifact; uploaded by CI).  On CPU the kernel runs in interpret
    mode — the numbers are correctness-path timings, the ranking is
    only meaningful on TPU.
    """
    import json

    import jax
    import jax.numpy as jnp
    from benchmarks.common import time_call
    from repro.core.quantization import quantize
    from repro.kernels.approx_mac.ops import (approx_dense_pallas,
                                              autotune_block_shapes,
                                              default_interpret)
    from repro.nn.layers import dense

    interpret = default_interpret()
    iters = 3 if interpret else 20
    m, k, n = (256, 256, 256) if interpret else (1024, 1024, 1024)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    w_qt = quantize(w, axis=1)
    cfg = jnp.asarray(8, jnp.int32)

    f_xla = jax.jit(lambda x, c: dense(x, w_qt, approx_cfg=c,
                                       compute_dtype=jnp.float32))
    f_fused = jax.jit(lambda x, c: dense(x, w_qt, approx_cfg=c,
                                         backend="pallas",
                                         interpret=interpret,
                                         compute_dtype=jnp.float32))
    f_unfused = jax.jit(lambda x, c: approx_dense_pallas(
        x, w_qt, config=c, fused=False, interpret=interpret,
        compute_dtype=jnp.float32))
    t_xla = time_call(f_xla, x, cfg, iters=iters)
    t_fused = time_call(f_fused, x, cfg, iters=iters)
    t_unfused = time_call(f_unfused, x, cfg, iters=iters)
    # per-neuron knob: a mixed per-N-block config vector, same executable
    cfg_vec = jnp.asarray([(31 * i) // max(n // 128 - 1, 1)
                           for i in range(n // 128)], jnp.int32)
    t_mixed = time_call(f_fused, x, cfg_vec, iters=iters)
    tune = autotune_block_shapes(
        m, k, n, config=8, interpret=interpret, iters=iters,
        candidates=((128, 128, 128), (128, 128, 256), (256, 128, 256))
        if interpret else None)
    best = tune[0] if tune and "us" in tune[0] else None

    mode = "interpret" if interpret else "tpu"
    print(f"pallas_path_xla_{m}x{k}x{n},{t_xla:.1f},mode={mode}")
    print(f"pallas_path_fused_{m}x{k}x{n},{t_fused:.1f},"
          f"xla_vs_pallas={t_xla/t_fused:.2f}x")
    print(f"pallas_path_unfused_{m}x{k}x{n},{t_unfused:.1f},"
          f"fused_speedup={t_unfused/t_fused:.2f}x")
    print(f"pallas_path_mixed_cfg_{m}x{k}x{n},{t_mixed:.1f},"
          f"per_tile_overhead={t_mixed/t_fused:.2f}x")
    if best:
        print(f"pallas_path_autotune,{best['us']:.1f},"
              f"best=bm{best['bm']}_bn{best['bn']}_bk{best['bk']}")

    out = {
        "bench": "pallas_path",
        "mode": mode,
        "shape": {"m": m, "k": k, "n": n},
        "config": 8,
        "xla_vs_pallas": {"xla_us": t_xla, "pallas_fused_us": t_fused,
                          "speedup": t_xla / t_fused},
        "fused_vs_unfused": {"fused_us": t_fused, "unfused_us": t_unfused,
                             "speedup": t_unfused / t_fused},
        "mixed_per_block_config": {"us": t_mixed,
                                   "cfg_vec": cfg_vec.tolist()},
        "autotune": tune,
    }
    with open("BENCH_pallas_path.json", "w") as f:
        json.dump(out, f, indent=2)


def bench_moe_path():
    """The PR-3 tentpole quantified: grouped expert GEMM vs lax.map.

    Three A/Bs on a dense-MoE FFN through the pallas backend —
      * expert loop: ONE grouped pallas_call (expert axis in the kernel
        grid) vs one kernel launch per expert under lax.map;
      * per-expert knob: a mixed (E, 1) per-expert config matrix on the
        same grouped executable (the expert knob costs nothing extra);
      * expert-count scaling: both paths at E = 2 / 4 / 8;
    Emits CSV rows AND machine-readable BENCH_moe_pallas.json (uploaded
    by CI).  On CPU the kernels run in interpret mode — the numbers are
    correctness-path timings; TPU is the performance target.
    """
    import json

    import jax
    import jax.numpy as jnp
    from benchmarks.common import time_call
    from repro.kernels.approx_mac.ops import default_interpret
    from repro.nn.moe import moe_ffn

    interpret = default_interpret()
    iters = 3 if interpret else 20
    t, d, f, k = (64, 64, 128, 2) if interpret else (4096, 1024, 4096, 2)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    mode = "interpret" if interpret else "tpu"
    scaling = []
    for e in (2, 4, 8):
        params = {
            "router": jnp.asarray(rng.normal(size=(d, e)) * 0.5,
                                  jnp.float32),
            "w_gate": jnp.asarray(rng.normal(size=(e, d, f)) / np.sqrt(d),
                                  jnp.float32),
            "w_up": jnp.asarray(rng.normal(size=(e, d, f)) / np.sqrt(d),
                                jnp.float32),
            "w_down": jnp.asarray(rng.normal(size=(e, f, d)) / np.sqrt(f),
                                  jnp.float32),
        }

        def run(grouped, cfg):
            fn = jax.jit(lambda xx, cc: moe_ffn(
                xx, params, n_experts=e, top_k=k, capacity_factor=1.25,
                n_groups=1, approx_cfg=cc, backend="pallas",
                interpret=interpret, grouped=grouped)[0])
            return time_call(fn, x, cfg, iters=iters)

        cfg8 = jnp.asarray(8, jnp.int32)
        t_map = run(False, cfg8)
        t_grp = run(True, cfg8)
        # per-expert knob: one config per expert, same grouped executable
        cfg_e = jnp.asarray([(31 * i) // max(e - 1, 1)
                             for i in range(e)], jnp.int32)[:, None]
        t_mix = run(True, cfg_e)
        scaling.append({"experts": e, "lax_map_us": t_map,
                        "grouped_us": t_grp, "speedup": t_map / t_grp,
                        "mixed_per_expert_us": t_mix,
                        "per_expert_overhead": t_mix / t_grp})
        print(f"moe_path_laxmap_e{e},{t_map:.1f},mode={mode}")
        print(f"moe_path_grouped_e{e},{t_grp:.1f},"
              f"laxmap_vs_grouped={t_map / t_grp:.2f}x")
        print(f"moe_path_mixed_per_expert_e{e},{t_mix:.1f},"
              f"per_expert_overhead={t_mix / t_grp:.2f}x")

    out = {
        "bench": "moe_path",
        "mode": mode,
        "shape": {"tokens": t, "d_model": d, "d_ff": f, "top_k": k},
        "config": 8,
        "expert_scaling": scaling,
    }
    with open("BENCH_moe_pallas.json", "w") as fh:
        json.dump(out, fh, indent=2)


def bench_pallas():
    """CI entry: interpret-mode kernel timings + the fused-path A/B."""
    bench_pallas_kernels_interpret()
    bench_pallas_path()


def bench_scheduler():
    """The PR-4 tentpole quantified: the online power-budget scheduler.

    Trains the demo LM briefly on the synthetic stream (the paper's
    dynamic power control presumes a TRAINED network — a random-init
    model has no logit margins for the error knob to preserve), then
    serves a continuous request stream through ONE engine while a
    ``PowerBudgetScheduler`` is retargeted across three distinct
    joules/token budgets.  Per budget, after a convergence window, a
    measurement window scores

      * measured energy/token (the engine's executed-config integral)
        vs the budget — the acceptance bar is within 5 %;
      * shadow-probe token agreement (exact-config re-decode of the
        same step) — the bar is >= 99 %;
      * zero recompilations across the whole sweep (hard assert).

    Emits CSV rows AND machine-readable BENCH_scheduler.json (uploaded
    by CI with the ERROR-row guard).
    """
    import json

    import jax
    import jax.numpy as jnp
    from repro.core.power_model import energy_per_token_pj
    from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
    from repro.nn import transformer as T
    from repro.serve.engine import Engine, Request
    from repro.serve.scheduler import PowerBudgetScheduler
    from repro.train import optimizer as opt_mod
    from repro.train.step import build_train_step, init_state

    cfg = T.ModelConfig(
        name="demo-lm", n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=256, scan_layers=False,
        remat=False, q_chunk=32, loss_chunks=1,
        compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=256, seq_len=48, global_batch=16, n_templates=4,
        seed=0))
    opt = opt_mod.adamw(lr=4e-3)
    train = jax.jit(build_train_step(cfg, opt))
    state = init_state(params, opt)
    train_steps = 400
    t0 = time.perf_counter()
    for i in range(train_steps):
        b = data.batch(i)
        state, metrics = train(state,
                               {k: jnp.asarray(v) for k, v in b.items()})
    train_s = time.perf_counter() - t0
    loss = float(metrics["loss"])
    params = jax.tree.map(np.asarray, state["params"])

    sched = PowerBudgetScheduler(0.0, retune_every=8, probe_every=1,
                                 seed=0)
    eng = Engine(params, cfg, max_batch=4, max_len=64, scheduler=sched)
    exact_pj = energy_per_token_pj(np.zeros(cfg.n_layers, np.int32),
                                   eng.macs_per_token)
    rng = np.random.default_rng(0)
    rid = [0]

    def run_ticks(n):
        for _ in range(n):
            while len(eng.queue) < 4:
                eng.submit(Request(rid=rid[0],
                                   prompt=rng.integers(0, 256, size=8),
                                   max_new_tokens=12))
                rid[0] += 1
            eng.step()

    converge_ticks = measure_ticks = 100
    rows = []
    warm = None
    for frac in (0.92, 0.85, 0.78):
        budget = frac * exact_pj
        sched.set_budget(budget)
        run_ticks(converge_ticks)
        if warm is None:   # jit caches warm after the first phase ramp
            warm = (eng._decode._cache_size(), eng._prefill._cache_size())
        p0, a0 = sched.n_probes, sched.n_agree
        e0, n0 = eng.mac_energy_pj_per_param, eng.n_tokens_charged
        t0 = time.perf_counter()
        run_ticks(measure_ticks)
        us_tick = (time.perf_counter() - t0) * 1e6 / measure_ticks
        probes = sched.n_probes - p0
        agree = (sched.n_agree - a0) / max(probes, 1)
        measured = ((eng.mac_energy_pj_per_param - e0)
                    / (eng.n_tokens_charged - n0) * eng.macs_per_token)
        rel_err = abs(measured - budget) / budget
        rows.append({
            "budget_frac_of_exact": frac,
            "budget_pj_per_token": budget,
            "measured_pj_per_token": measured,
            "rel_err": rel_err,
            "tail_agreement": agree,
            "tail_probes": probes,
            "backoffs": sched.n_backoffs,
            "allocation": sched._tensor(sched.assignment).tolist(),
        })
        print(f"scheduler_budget_{frac},{us_tick:.1f},"
              f"budget_pj={budget:.0f};measured_pj={measured:.0f};"
              f"rel_err={rel_err*100:.2f}%;agreement={agree*100:.2f}%;"
              f"alloc={'|'.join(map(str, rows[-1]['allocation']))}")

    now = (eng._decode._cache_size(), eng._prefill._cache_size())
    if now != warm:
        raise RuntimeError(f"scheduler sweep recompiled: {warm} -> {now}")
    print(f"scheduler_zero_retraces,0.0,executables={now}"
          f";train_loss={loss:.3f};train_s={train_s:.1f}")

    out = {
        "bench": "scheduler",
        "model": {"n_layers": 4, "d_model": 64, "vocab": 256,
                  "train_steps": train_steps, "train_loss": loss},
        "exact_pj_per_token": exact_pj,
        "converge_ticks": converge_ticks,
        "measure_ticks": measure_ticks,
        "budgets": rows,
        "zero_retraces": True,
        "probes_total": sched.n_probes,
        "agreement_total": (sched.n_agree / sched.n_probes
                            if sched.n_probes else None),
    }
    with open("BENCH_scheduler.json", "w") as fh:
        json.dump(out, fh, indent=2)

    # the acceptance bars are ENFORCED, not just reported: a regression
    # in budget convergence or probe agreement must fail CI (the raise
    # becomes an ERROR row, which the workflow greps for) — currently
    # well inside the bars (rel_err <= ~1.4%, agreement 100%)
    bad = [r for r in rows
           if r["rel_err"] > 0.05 or r["tail_agreement"] < 0.99]
    if bad:
        raise RuntimeError(
            f"scheduler acceptance bars violated (>5% budget error or "
            f"<99% agreement): {bad}")


def bench_resilience():
    """The PR-7 tentpole quantified: the chaos matrix.

    Serves a fixed workload through every injected-fault scenario
    (NaN/Inf logits, decode step failure, clock skew, stall,
    kill-and-restore) and a 2x overload spike with/without the
    brownout controller — all on a FakeClock with seeded injectors and
    traffic, so the matrix replays bit-for-bit.  The bars (bit-
    identical recovery, zero retraces under chaos, availability 1.0
    under the spike via the config ladder) are ENFORCED in
    ``benchmarks/resilience.py``: a violation raises and becomes the
    ERROR row CI greps for.  Emits BENCH_resilience.json (CI artifact).
    """
    import json

    from benchmarks.resilience import run_chaos_matrix

    out = run_chaos_matrix()
    with open("BENCH_resilience.json", "w") as fh:
        json.dump(out, fh, indent=2)


def bench_sharded_decode():
    """The PR-5 tentpole quantified: the Engine on a TP/SP mesh.

    jax freezes the device topology at backend init, so the measurement
    body (``benchmarks/sharded_decode.py``) runs in a SUBPROCESS with 8
    forced host devices — same isolation as tests/test_multidevice.py.
    The subprocess enforces token bit-identity between the single-host
    and (2, 4)-mesh engines and a zero-retrace live retune of the
    replicated config tensor, then writes BENCH_sharded_decode.json
    (CI artifact); any violation raises here and becomes the harness's
    ERROR row, which CI greps for.
    """
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    # preserve inherited platform flags, but OUR device count must win
    # (a conflicting inherited force-device flag would be ambiguous)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        flags + ["--xla_force_host_platform_device_count=8"])
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_decode"],
        capture_output=True, text=True, timeout=560, env=env)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded_decode subprocess failed:\n{r.stderr[-2000:]}")


def bench_lm_energy_model():
    """The paper's knob projected onto the assigned archs: modeled MAC
    energy per generated token, exact vs cfg31 (DESIGN.md §2)."""
    from repro.configs.registry import ARCH_IDS, get_config
    from repro.core.power_model import energy_per_mac_pj
    t0 = time.perf_counter()
    rows = []
    for arch in ("gemma2-27b", "qwen2.5-3b", "dbrx-132b"):
        cfg = get_config(arch)
        # MACs/token ~= N_active (one multiply-add per weight)
        if cfg.n_experts:
            active_ratio = cfg.top_k / cfg.n_experts
            n = (cfg.n_layers * (cfg.d_model * (cfg.n_heads + 2 *
                 cfg.n_kv_heads) * cfg.head_dim + cfg.n_heads * cfg.head_dim
                 * cfg.d_model + 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
                 * active_ratio))
        else:
            glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
            n = cfg.n_layers * (cfg.d_model * (cfg.n_heads + 2 *
                cfg.n_kv_heads) * cfg.head_dim + cfg.n_heads * cfg.head_dim
                * cfg.d_model + glu * cfg.d_model * cfg.d_ff)
        e0 = n * energy_per_mac_pj(0) * 1e-12
        e31 = n * energy_per_mac_pj(31) * 1e-12
        rows.append(f"{arch}:exact={e0*1e3:.2f}mJ/tok,cfg31={e31*1e3:.2f}mJ"
                    f"(-{(1-e31/e0)*100:.1f}%)")
    us = (time.perf_counter() - t0) * 1e6
    print(f"lm_energy_model,{us:.1f},{';'.join(rows)}")


def bench_roofline_table():
    """Reads the dry-run artifacts; see benchmarks/roofline.py."""
    from benchmarks.roofline import print_roofline_csv
    print_roofline_csv()


def bench_runtime_config_switch():
    """The PR-1 tentpole quantified: cost of changing the error config.

    static  — config baked into the trace: every new config pays a full
              jit trace+compile (the pre-PR-1 behavior);
    runtime — config as a traced int32: switching is one gather, all 32
              configs share one executable.
    """
    import jax
    import jax.numpy as jnp
    from benchmarks.common import time_call
    from repro.core.approx_matmul import approx_matmul_operand
    rng = np.random.default_rng(0)
    m = k = n = 512
    a8 = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)

    # static: fresh jit per config (cache miss == the recompile cost)
    t0 = time.perf_counter()
    for c in range(32):
        f = jax.jit(lambda x, w, c=c: approx_matmul_operand(x, w, c))
        jax.block_until_ready(f(a8, b8))
    static_us = (time.perf_counter() - t0) * 1e6 / 32

    f_rt = jax.jit(approx_matmul_operand)
    jax.block_until_ready(f_rt(a8, b8, jnp.asarray(0, jnp.int32)))  # warmup

    def sweep():
        out = None
        for c in range(32):
            out = f_rt(a8, b8, jnp.asarray(c, jnp.int32))
        return out

    runtime_us = time_call(sweep, iters=5) / 32
    print(f"runtime_config_switch,{runtime_us:.1f},"
          f"static_recompile_per_cfg={static_us:.1f}us;"
          f"speedup={static_us/max(runtime_us, 1e-9):.0f}x;"
          f"executables=1_vs_32")


def bench_paged_serving():
    """The PR-8 tentpole quantified: paged KV serving.

    Dense-vs-paged bit-identity at equal occupancy, a 4->256 concurrent
    stream sweep through ONE decode executable (live error-config
    retune mid-sweep, zero retraces), >= 3x concurrent streams on a
    pool byte-equal to the dense cache, chunked prefill's P99 tick-
    stall improvement under a long-prompt trace, and prefix-reuse
    prefill-token savings with identical outputs.  The bars are
    ENFORCED in ``benchmarks/paged_serving.py``: a violation raises and
    becomes the ERROR row CI greps for.  Emits BENCH_paged_serving.json
    (CI artifact).
    """
    import json

    from benchmarks.paged_serving import run_paged_serving

    out = run_paged_serving()
    with open("BENCH_paged_serving.json", "w") as fh:
        json.dump(out, fh, indent=2)


def bench_speculative():
    """The PR-9 tentpole quantified: approx-draft self-speculation.

    The knob's draft model is FREE: eligible decode ticks draft k
    tokens at an aggressive low-power config and verify them in ONE
    service-config pass through the same executables.  The bars
    (speculative stream identical to non-speculative exact greedy,
    zero retraces across a live (k, draft-cfg) sweep, > 1 token per
    verify weight-pass, serve pJ/token below the exact baseline) are
    ENFORCED in ``benchmarks/speculative.py``: a violation raises and
    becomes the ERROR row CI greps for.  Emits BENCH_spec_decode.json
    (CI artifact).
    """
    import json

    from benchmarks.speculative import run_speculative

    out = run_speculative()
    with open("BENCH_spec_decode.json", "w") as fh:
        json.dump(out, fh, indent=2)


def bench_traffic():
    """The PR-10 tentpole quantified: traffic-aware per-class budgets.

    Serves three seeded traffic scenarios (steady Poisson, 2x overload
    spike, mixed-class) through scheduler-attached engines and scores
    each as a throughput–latency–energy Pareto point.  The bars (every
    class's measured pJ/token within 5 % of its split budget after the
    re-split loop converges, spike availability >= the exact-only arm
    at the same power cap for less energy, zero retraces across the
    whole sweep) are ENFORCED in ``benchmarks/traffic.py``: a
    violation raises and becomes the ERROR row CI greps for.  Emits
    BENCH_traffic.json (CI artifact).
    """
    import json

    from benchmarks.traffic import run_traffic

    out = run_traffic()
    with open("BENCH_traffic.json", "w") as fh:
        json.dump(out, fh, indent=2)


BENCHES = {
    "table1": bench_table1_multiplier_metrics,
    "fig5": bench_fig5_power_improvement,
    "fig6": bench_fig6_power_accuracy,
    "fig7": bench_fig7_tradeoff,
    "hw_sim": bench_hw_sim,
    "approx_mac": bench_approx_mac_kernel,
    "pallas": bench_pallas,
    "pallas_path": bench_pallas_path,
    "moe_path": bench_moe_path,
    "scheduler": bench_scheduler,
    "resilience": bench_resilience,
    "sharded_decode": bench_sharded_decode,
    "paged_serving": bench_paged_serving,
    "speculative": bench_speculative,
    "traffic": bench_traffic,
    "lm_energy": bench_lm_energy_model,
    "roofline": bench_roofline_table,
    "runtime_config": bench_runtime_config_switch,
}

# every bench that writes a BENCH_*.json artifact — `run.py all`
# regenerates the full artifact set in one command
JSON_BENCHES = ["pallas_path", "moe_path", "scheduler", "resilience",
                "sharded_decode", "paged_serving", "speculative",
                "traffic"]


def main() -> None:
    which = sys.argv[1:] or list(BENCHES)
    if which == ["all"]:
        which = JSON_BENCHES
    print("name,us_per_call,derived")
    for name in which:
        try:
            BENCHES[name]()
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
