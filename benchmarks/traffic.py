"""Traffic Pareto surface for per-class power budgets (PR 10 tentpole,
DESIGN.md §13).

Serves three deterministic traffic scenarios — steady Poisson, a 2x
overload spike, and a mixed-class stream with per-class budget splits —
through scheduler-attached engines and scores each as a
throughput–latency–energy point (the Pareto surface serving operators
actually trade along).  Everything runs on seeded traffic and a
deterministic FakeClock, so every row is replayable bit-for-bit.

Acceptance bars (ENFORCED — a violation raises, which the harness
turns into the ERROR row CI greps for):

  * per-class budget attainment: after the re-split loop converges,
    each class's measured pJ/token lands within 5 % of its split
    budget (``share_c / mix_c * B`` at the window-mean re-split
    shares), and the re-split demonstrably moved share toward the
    class that runs hot against a mis-configured even split;
  * under the 2x spike, the budgeted-scheduler + brownout arm serves
    availability >= the exact-only arm at the same power cap, for
    strictly less energy per token;
  * zero retraces across the WHOLE sweep: every engine ends with
    exactly one compiled decode and one compiled prefill executable.

``run_traffic`` returns the machine-readable scenario table;
``benchmarks/run.py`` writes it to BENCH_traffic.json (CI artifact).
"""
from __future__ import annotations

import time

import numpy as np


class FakeClock:
    """Deterministic injected time source: each read advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def _require(ok: bool, msg: str):
    if not ok:
        raise RuntimeError(f"traffic bench bar violated: {msg}")


def _trained_model():
    """Briefly-trained demo LM (same recipe as bench_scheduler: the
    budget bars need probe agreement, which needs logit margins)."""
    import jax
    import jax.numpy as jnp
    from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
    from repro.nn import transformer as T
    from repro.train import optimizer as opt_mod
    from repro.train.step import build_train_step, init_state
    cfg = T.ModelConfig(
        name="demo-lm", n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=256, scan_layers=False,
        remat=False, q_chunk=32, loss_chunks=1,
        compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=256, seq_len=48, global_batch=16, n_templates=4,
        seed=0))
    opt = opt_mod.adamw(lr=4e-3)
    train = jax.jit(build_train_step(cfg, opt))
    state = init_state(params, opt)
    t0 = time.perf_counter()
    for i in range(400):
        b = data.batch(i)
        state, metrics = train(state,
                               {k: jnp.asarray(v) for k, v in b.items()})
    train_s = time.perf_counter() - t0
    params = jax.tree.map(np.asarray, state["params"])
    return cfg, params, float(metrics["loss"]), train_s


def _latency(reqs) -> dict:
    """e2e latency stats (injected-clock seconds) over served
    requests; None when nothing finished in the window."""
    waits = sorted(r.finished_at - r.submitted_at for r in reqs
                   if r.status == "done" and r.finished_at is not None
                   and r.submitted_at is not None)
    if not waits:
        return {"mean_s": None, "p95_s": None, "served": 0}
    p95 = waits[min(len(waits) - 1, int(round(0.95 * (len(waits) - 1))))]
    return {"mean_s": float(np.mean(waits)), "p95_s": float(p95),
            "served": len(waits)}


def _zero_retraces(eng) -> bool:
    return (eng._decode._cache_size() == 1
            and eng._prefill._cache_size() == 1)


def run_traffic() -> dict:
    from repro.core.power_model import energy_per_token_pj
    from repro.serve.brownout import BrownoutController
    from repro.serve.engine import Engine
    from repro.serve.scheduler import PowerBudgetScheduler
    from repro.serve.traffic import (TrafficClass, TrafficGenerator,
                                     class_budget_shares, slo_report)

    cfg, params, loss, train_s = _trained_model()
    engines = []          # every engine in the sweep: one retrace audit

    # --- scenario 1: steady Poisson, budget-fraction Pareto sweep -----
    sched = PowerBudgetScheduler(0.0, retune_every=8, probe_every=1,
                                 seed=0)
    eng = Engine(params, cfg, max_batch=4, max_len=64,
                 scheduler=sched, clock=FakeClock(), seed=0)
    engines.append(("steady", eng))
    exact_pj = energy_per_token_pj(np.zeros(cfg.n_layers, np.int32),
                                   eng.macs_per_token)
    chat = TrafficClass("chat", prompt_len=8, max_new_tokens=12)

    def serve_window(engine, gen, t0, ticks):
        """Run `ticks` ticks of `gen`'s trace starting at tick t0;
        returns the offered requests (their stamps carry latency)."""
        offered = []
        for t in range(t0, t0 + ticks):
            for r in gen.arrivals(t):
                offered.append(r)
                engine.submit(r)
            engine.step()
        return offered

    steady_rows = []
    for frac in (1.0, 0.9, 0.8):
        budget = frac * exact_pj
        sched.set_budget(budget)
        # 0.25 req/tick * 12 decode tokens = 3 tok/tick demand against
        # 4 slots' capacity: stable queue, so the latency column means
        # something (a saturated queue just measures the window length)
        gen = TrafficGenerator((chat,), rate_per_tick=0.25, seed=21,
                               vocab_size=cfg.vocab_size)
        serve_window(eng, gen, 0, 60)                 # converge
        e0, n0 = eng.serve_mac_energy_pj_per_param, \
            eng.n_serve_tokens_charged
        m0 = eng.n_tokens_emitted
        t0 = time.perf_counter()
        offered = serve_window(eng, gen, 60, 120)     # measure
        wall = time.perf_counter() - t0
        dn = eng.n_serve_tokens_charged - n0
        measured = ((eng.serve_mac_energy_pj_per_param - e0)
                    / max(dn, 1) * eng.macs_per_token)
        _require(measured <= 1.05 * budget,
                 f"steady frac={frac}: measured {measured:.0f} pJ/tok "
                 f"blew the {budget:.0f} budget")
        row = {
            "budget_frac_of_exact": frac,
            "budget_pj_per_token": budget,
            "measured_pj_per_token": measured,
            "throughput_tok_per_tick": (eng.n_tokens_emitted - m0) / 120,
            "latency": _latency(offered),
            "allocation": sched._tensor(sched.assignment).tolist(),
        }
        steady_rows.append(row)
        print(f"traffic_steady_{frac},{wall * 1e6 / 120:.1f},"
              f"budget_pj={budget:.0f};measured_pj={measured:.0f};"
              f"tok_per_tick={row['throughput_tok_per_tick']:.2f};"
              f"p95_s={row['latency']['p95_s']}")

    # --- scenario 2: 2x overload spike, budgeted vs exact-only --------
    cap = 2.5 * exact_pj          # 2 slots at exact, all 4 degraded

    def spike_run(budgeted: bool):
        gen = TrafficGenerator(
            (TrafficClass("chat", prompt_len=6, max_new_tokens=6),),
            rate_per_tick=0.3, seed=11, vocab_size=cfg.vocab_size,
            spikes=((10, 70, 2.0),))
        sc = bo = None
        if budgeted:
            sc = PowerBudgetScheduler(0.85 * exact_pj, retune_every=8,
                                      probe_every=2, seed=0)
            bo = BrownoutController(ladder=(0, 16, 31),
                                    high_watermark=0.3,
                                    low_watermark=0.1, hold_ticks=4)
        e = Engine(params, cfg, max_batch=4, max_len=64,
                   queue_capacity=6, power_cap_pj_per_tick=cap,
                   scheduler=sc, brownout=bo, clock=FakeClock(), seed=0)
        engines.append(("spike_budgeted" if budgeted else "spike_exact",
                        e))
        offered = serve_window(e, gen, 0, 110)
        e.run(max_ticks=300)      # drain the tail
        pj = (e.serve_mac_energy_pj_per_param
              / max(e.n_serve_tokens_charged, 1) * e.macs_per_token)
        return e, bo, offered, slo_report(offered), pj

    eng_b, bo, off_b, rep_b, pj_b = spike_run(True)
    eng_x, _, off_x, rep_x, pj_x = spike_run(False)
    _require([r.rid for r in off_b] == [r.rid for r in off_x],
             "traffic replay broke: spike offered loads differ")
    avail_b = rep_b["total"]["availability"]
    avail_x = rep_x["total"]["availability"]
    _require(avail_b >= avail_x,
             f"budgeted arm must serve >= exact-only availability "
             f"under the spike ({avail_b:.3f} < {avail_x:.3f})")
    _require(pj_b < pj_x,
             f"budgeted arm must cut energy/token: {pj_b:.1f} vs "
             f"{pj_x:.1f}")
    spike_rows = []
    for tag, e, rep, off, pj in (("budgeted", eng_b, rep_b, off_b, pj_b),
                                 ("exact", eng_x, rep_x, off_x, pj_x)):
        spike_rows.append({
            "arm": tag, "offered": len(off),
            "availability": rep["total"]["availability"],
            "throughput_tok_per_tick": e.n_tokens_emitted / 110,
            "latency": _latency(off),
            "measured_pj_per_token": pj,
            "rejected": e.n_rejected,
            "brownout_escalations": bo.n_escalations if tag == "budgeted"
            else 0})
        print(f"traffic_spike_{tag},0.0,"
              f"availability={rep['total']['availability']:.3f};"
              f"rejected={e.n_rejected};pj_per_token={pj:.1f}")

    # --- scenario 3: mixed-class stream, per-class budget re-split ----
    # the split is DELIBERATELY mis-configured (even split over a 2:1
    # traffic mix): chat runs hot against its target, bulk leaves
    # budget unspent, and the retune loop must move share to the hot
    # class until every class sits on its split budget
    classes = (TrafficClass("chat", weight=2.0, prompt_len=8,
                            max_new_tokens=12, budget_share=0.5),
               TrafficClass("bulk", weight=1.0, prompt_len=8,
                            max_new_tokens=12, budget_share=0.5))
    budget = 0.85 * exact_pj
    sched_m = PowerBudgetScheduler(budget, retune_every=8,
                                   probe_every=1, seed=0)
    sched_m.set_class_budgets(class_budget_shares(classes))
    eng_m = Engine(params, cfg, max_batch=4, max_len=64,
                   scheduler=sched_m, clock=FakeClock(), seed=0)
    engines.append(("mixed", eng_m))
    gen = TrafficGenerator(classes, rate_per_tick=0.6, seed=5,
                           vocab_size=cfg.vocab_size)
    serve_window(eng_m, gen, 0, 120)                  # converge
    marks = {c: (eng_m.serve_energy_by_class.get(c, 0.0),
                 eng_m.serve_tokens_by_class.get(c, 0))
             for c in sched_m.class_shares}
    m0 = eng_m.n_tokens_emitted
    share_sum = {c: 0.0 for c in sched_m.class_shares}
    offered_m = []
    for t in range(120, 240):                         # measure
        for r in gen.arrivals(t):
            offered_m.append(r)
            eng_m.submit(r)
        eng_m.step()
        for c, s in sched_m.class_shares.items():
            share_sum[c] += s
    mean_share = {c: v / 120 for c, v in share_sum.items()}
    deltas = {c: (eng_m.serve_energy_by_class.get(c, 0.0) - e0,
                  eng_m.serve_tokens_by_class.get(c, 0) - n0)
              for c, (e0, n0) in marks.items()}
    tot_tok = sum(dn for _, dn in deltas.values())
    class_rows = {}
    for c, (de, dn) in deltas.items():
        mix = dn / tot_tok
        measured = de / dn * eng_m.macs_per_token
        target = mean_share[c] / mix * budget
        attain = measured / target
        class_rows[c] = {
            "configured_share": 0.5, "mean_split_share": mean_share[c],
            "token_mix": mix, "measured_pj_per_token": measured,
            "target_pj_per_token": target, "attainment": attain}
        _require(abs(attain - 1.0) <= 0.05,
                 f"class {c}: measured {measured:.0f} pJ/tok vs split "
                 f"budget {target:.0f} ({(attain - 1) * 100:+.1f}%)")
        print(f"traffic_class_{c},0.0,share={mean_share[c]:.3f};"
              f"mix={mix:.3f};measured_pj={measured:.0f};"
              f"target_pj={target:.0f};attain={attain * 100:.1f}%")
    _require(mean_share["chat"] > 0.55,
             f"re-split never moved share to the hot class "
             f"(chat {mean_share['chat']:.3f})")
    _require(abs(sum(sched_m.class_shares.values()) - 1.0) < 1e-9,
             "class shares must always sum to the global budget")

    # --- zero retraces across the whole sweep -------------------------
    for tag, e in engines:
        _require(_zero_retraces(e), f"{tag} engine retraced "
                 f"(decode={e._decode._cache_size()}, "
                 f"prefill={e._prefill._cache_size()})")
    print(f"traffic_zero_retraces,0.0,engines={len(engines)}"
          f";train_loss={loss:.3f};train_s={train_s:.1f}")

    return {
        "bench": "traffic",
        "model": {"n_layers": 4, "d_model": 64, "vocab": 256,
                  "train_steps": 400, "train_loss": loss},
        "exact_pj_per_token": exact_pj,
        "scenarios": {
            "steady_poisson": steady_rows,
            "spike_2x": spike_rows,
            "mixed_class": {
                "budget_pj_per_token": budget,
                "classes": class_rows,
                "final_shares": dict(sched_m.class_shares),
                "slo": slo_report(offered_m)["total"],
            },
        },
        "zero_retraces": True,
    }
