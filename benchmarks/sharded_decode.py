"""Sharded-serving benchmark body (PR 5, DESIGN.md §8).

Runs INSIDE a subprocess with ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` (jax device topology is frozen at backend init, so the
parent harness — ``benchmarks/run.py sharded_decode`` — must not force
devices in its own process).  Serves the demo LM through one Engine
single-host and once sharded on a (2, 4) data x model mesh, and scores

  * decode throughput (us/tick) and TTFT, single-host vs sharded —
    forced host devices on CPU: correctness-path timings, the ranking
    is only meaningful on real multi-device hardware;
  * token BIT-identity between the two engines (enforced: raise);
  * a live ``apply_allocation`` retune of the replicated config tensor
    mid-stream with zero retraces (enforced: raise).

Writes BENCH_sharded_decode.json (CI artifact) and prints the harness's
``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import json
import time

import numpy as np


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.dist.sharding import serve_mapping
    from repro.launch.mesh import make_serve_mesh
    from repro.nn import transformer as T
    from repro.serve.engine import Engine, Request

    n_dev = len(jax.devices())
    assert n_dev == 8, f"expected 8 forced host devices, got {n_dev}"
    cfg = T.ModelConfig(
        name="demo-lm", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=256, vocab_size=256, scan_layers=False,
        remat=False, q_chunk=32, loss_chunks=1,
        compute_dtype=jnp.float32)
    params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=8) for _ in range(8)]
    mixed = np.asarray([0, 8, 16, 31], np.int32)

    def serve(mapping):
        eng = Engine(params, cfg, max_batch=4, max_len=64,
                     mapping=mapping, param_specs=specs)
        eng.rng = jax.random.PRNGKey(0)
        eng.set_approx_cfg(mixed)
        for i, p in enumerate(prompts):      # warmup batch: compiles
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=8))
        warmed_up = eng.run()
        eng.completed = []   # run() returns the CUMULATIVE list — keep
        #                      warmup compile time out of the TTFTs
        warm = (eng._decode._cache_size(), eng._prefill._cache_size())
        for i, p in enumerate(prompts):      # measured batch
            eng.submit(Request(rid=100 + i, prompt=p, max_new_tokens=8))
        t0 = time.perf_counter()
        steps0 = eng.n_decode_steps
        done = list(eng.run())
        eng.completed = []
        dt = time.perf_counter() - t0
        us_tick = dt * 1e6 / max(eng.n_decode_steps - steps0, 1)
        ttft = float(np.median([r.first_token_at - r.submitted_at
                                for r in done]))
        # live retune of the replicated config: whole mesh, no retrace
        eng.apply_allocation({0: 31, 2: 5})
        for i, p in enumerate(prompts[:4]):
            eng.submit(Request(rid=200 + i, prompt=p, max_new_tokens=8))
        done2 = eng.run()
        now = (eng._decode._cache_size(), eng._prefill._cache_size())
        if now != warm:
            raise RuntimeError(f"sharded retune recompiled: {warm}->{now}")
        toks = [t for r in sorted(warmed_up + done + done2,
                                  key=lambda r: r.rid)
                for t in r.tokens]
        return us_tick, ttft, toks

    us0, ttft0, toks0 = serve(None)
    mesh = make_serve_mesh(dp=2, tp=4)
    us1, ttft1, toks1 = serve(serve_mapping(mesh, kv="hd"))
    if toks1 != toks0:
        raise RuntimeError("sharded decode is not bit-identical to the "
                           "single-host path")

    print(f"sharded_decode_single_host,{us0:.1f},"
          f"ttft_ms={ttft0*1e3:.0f};mode=forced_host_cpu")
    print(f"sharded_decode_dp2_tp4,{us1:.1f},"
          f"ttft_ms={ttft1*1e3:.0f};vs_single={us0/us1:.2f}x;"
          f"bit_identical=True;zero_retraces=True")

    out = {
        "bench": "sharded_decode",
        "mode": "forced_host_cpu",   # 8 forced host devices — timings
        #                              are correctness-path only
        "mesh": {"data": 2, "model": 4},
        "model": {"n_layers": 4, "d_model": 64, "vocab": 256},
        "mixed_cfg": mixed.tolist(),
        "single_host": {"us_per_tick": us0, "ttft_ms": ttft0 * 1e3},
        "sharded": {"us_per_tick": us1, "ttft_ms": ttft1 * 1e3},
        "tokens_bit_identical": True,
        "zero_retraces_across_retune": True,
    }
    with open("BENCH_sharded_decode.json", "w") as fh:
        json.dump(out, fh, indent=2)


if __name__ == "__main__":
    main()
