"""Quickstart: the paper's technique in 60 lines.

Trains the paper's 62-30-10 MLP on (procedural) MNIST, quantizes it to
signed-magnitude int8, and sweeps the 32 error-configurable MAC settings
— printing the accuracy/power trade-off the paper's Figs 6/7 report.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import select_uniform_config
from repro.core.power_model import network_improvement_pct, network_power_mw
from repro.data.synthetic_mnist import load_mnist
from repro.nn import mlp_paper as M
from repro.train.optimizer import adamw, apply_updates


def main():
    print("== data ==")
    data = load_mnist(n_train=6000, n_test=1500, seed=0)
    print(f"source={data.source}, train={data.train_x.shape}, "
          f"features=62 (paper's reduction)")

    print("== float training ==")
    params = M.init_params(jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3, weight_decay=1e-4)
    state = opt.init(params)

    def loss_fn(p, x, y):
        lp = jax.nn.log_softmax(M.apply_float(p, x))
        return -jnp.take_along_axis(lp, y[:, None], axis=1).mean()

    @jax.jit
    def step(p, s, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    rng = np.random.default_rng(0)
    for epoch in range(30):
        idx = rng.permutation(len(data.train_x))
        for i in range(0, len(idx) - 127, 128):
            b = idx[i:i + 128]
            params, state, l = step(params, state,
                                    jnp.asarray(data.train_x[b]),
                                    jnp.asarray(data.train_y[b]))
    print(f"final loss {float(l):.4f}")

    print("== quantize (signed-magnitude int8) ==")
    qm = M.QuantizedMLP.from_float(params, data.train_x[:2000])

    print("== error-config sweep (paper Figs 5-7) ==")
    print(f"{'cfg':>4} {'accuracy':>9} {'power mW':>9} {'saving':>7}")
    for cfg in (0, 1, 4, 8, 12, 16, 20, 24, 28, 31):
        acc = qm.accuracy(data.test_x, data.test_y, cfg)
        print(f"{cfg:4d} {acc*100:8.2f}% {network_power_mw(cfg):9.3f} "
              f"{network_improvement_pct(cfg):6.2f}%")

    print("== dynamic power control (1% accuracy budget) ==")
    best, accs = select_uniform_config(
        lambda c: qm.accuracy(data.test_x[:800], data.test_y[:800], c),
        budget=0.01)
    print(f"controller selects cfg {best}: "
          f"{network_power_mw(best):.2f} mW "
          f"({network_improvement_pct(best):.2f}% saved), "
          f"accuracy {accs[best]*100:.2f}% vs exact {accs[0]*100:.2f}%")


if __name__ == "__main__":
    main()
