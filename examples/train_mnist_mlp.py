"""End-to-end reproduction driver for the paper (Section IV).

Full pipeline: data -> float training (with fault-tolerant train loop +
checkpointing) -> signed-magnitude int8 quantization -> all-32-config
accuracy/power sweep -> cycle-accurate hardware simulation.  Writes
experiments/paper_mlp_results.json consumed by EXPERIMENTS.md.

  PYTHONPATH=src python examples/train_mnist_mlp.py [--epochs 40]
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.error_metrics import PAPER_TABLE_I, summary_table
from repro.core.hw_sim import simulate
from repro.core.power_model import network_improvement_pct, network_power_mw
from repro.data.synthetic_mnist import load_mnist
from repro.dist.fault_tolerance import resilient_train_loop
from repro.nn import mlp_paper as M
from repro.train.optimizer import adamw, apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--out", default="experiments/paper_mlp_results.json")
    args = ap.parse_args()

    data = load_mnist(n_train=8000, n_test=2000, seed=0)
    params = M.init_params(jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3, weight_decay=1e-4)

    def loss_fn(p, x, y):
        lp = jax.nn.log_softmax(M.apply_float(p, x))
        return -jnp.take_along_axis(lp, y[:, None], axis=1).mean()

    @jax.jit
    def train_step(state, batch):
        p, s = state["params"], state["opt"]
        l, g = jax.value_and_grad(loss_fn)(p, batch["x"], batch["y"])
        u, s = opt.update(g, s, p)
        return ({"params": apply_updates(p, u), "opt": s},
                {"loss": l})

    bs = 128
    n = len(data.train_x)
    steps_per_epoch = n // bs
    rng = np.random.default_rng(0)
    perms = [rng.permutation(n) for _ in range(args.epochs)]

    def data_iter(step):
        e = step // steps_per_epoch
        i = (step % steps_per_epoch) * bs
        idx = perms[min(e, args.epochs - 1)][i:i + bs]
        return {"x": jnp.asarray(data.train_x[idx]),
                "y": jnp.asarray(data.train_y[idx])}

    ck = Checkpointer("experiments/ckpt_mlp", keep_last_k=2)
    state = {"params": params, "opt": opt.init(params)}
    state, monitor, _ = resilient_train_loop(
        train_step=train_step, state=state, data_iter=data_iter,
        checkpointer=ck, total_steps=args.epochs * steps_per_epoch,
        checkpoint_every=200)
    params = state["params"]

    float_acc = float((np.argmax(np.asarray(M.apply_float(
        params, jnp.asarray(data.test_x))), axis=1) == data.test_y).mean())
    print(f"float accuracy: {float_acc*100:.2f}%")

    qm = M.QuantizedMLP.from_float(params, data.train_x[:2000])
    accs = {c: qm.accuracy(data.test_x, data.test_y, c) for c in range(32)}
    print(f"int8 exact (cfg 0): {accs[0]*100:.2f}%  |  "
          f"worst cfg: {min(accs.values())*100:.2f}%  |  "
          f"drop {100*(accs[0]-min(accs.values())):.2f}% (paper: 0.92%)")

    sim0 = simulate(qm, data.test_x[:50], config=0)
    sim31 = simulate(qm, data.test_x[:50], config=31)
    print(f"hw-sim power: exact {sim0.avg_power_mw:.3f} mW (paper 5.55), "
          f"cfg31 {sim31.avg_power_mw:.3f} mW (paper 4.81)")

    results = {
        "dataset": data.source,
        "float_acc": float_acc,
        "acc_per_config": {str(k): v for k, v in accs.items()},
        "acc_drop_worst": accs[0] - min(accs.values()),
        "acc_avg_approx": float(np.mean([accs[c] for c in range(1, 32)])),
        "power_mw_per_config": {str(c): network_power_mw(c)
                                for c in range(32)},
        "improvement_pct_per_config": {str(c): network_improvement_pct(c)
                                       for c in range(32)},
        "hw_sim": {"cycles_per_image": sim0.cycles / 50,
                   "power_exact_mw": sim0.avg_power_mw,
                   "power_cfg31_mw": sim31.avg_power_mw},
        "multiplier_metrics": summary_table(),
        "paper_table1": PAPER_TABLE_I,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
