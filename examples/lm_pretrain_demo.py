"""LM pre-training driver on the synthetic pipeline: the full training
substrate end-to-end (model from the arch registry at reduced scale,
AdamW + cosine schedule, microbatched train step, fault-tolerant loop
with async checkpointing, straggler monitor).

Default runs a ~8M-parameter qwen2.5-family config for 300 steps on CPU
(loss drops ~2 nats on the templated synthetic stream).  --full selects
a ~100M config (for real accelerators).

  PYTHONPATH=src python examples/lm_pretrain_demo.py [--steps 300] [--full]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config
from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
from repro.dist.fault_tolerance import resilient_train_loop
from repro.nn import transformer as T
from repro.train.optimizer import adamw
from repro.train.schedule import warmup_cosine
from repro.train.step import build_train_step, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (accelerator-scale)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    base = get_config("qwen2.5-3b")
    if args.full:
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000, tie_embeddings=True,
            scan_layers=True, remat=True, q_chunk=256, loss_chunks=4)
    else:
        cfg = dataclasses.replace(
            base, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
            head_dim=64, d_ff=1024, vocab_size=2048, tie_embeddings=True,
            scan_layers=False, remat=False, q_chunk=128, loss_chunks=2,
            compute_dtype=jnp.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch family: {cfg.name} (reduced) — {n/1e6:.1f}M params")

    sched = warmup_cosine(3e-3 if not args.full else 6e-4, 20, args.steps)
    opt = adamw(lr=sched, weight_decay=0.01, grad_clip_norm=1.0)
    step_fn = jax.jit(build_train_step(cfg, opt, num_microbatches=2))
    state = init_state(params, opt)

    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0))

    losses = []
    t0 = time.time()

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % 25 == 0:
            tps = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(sched(step)):.2e}  tok/s {tps:,.0f}")

    ck = Checkpointer("experiments/ckpt_lm_demo", keep_last_k=2)
    state, monitor, last = resilient_train_loop(
        train_step=step_fn, state=state,
        data_iter=lambda s: jax.tree.map(jnp.asarray, data.batch(s)),
        checkpointer=ck, total_steps=args.steps, checkpoint_every=100,
        on_metrics=on_metrics)

    first = float(np.mean(losses[:10]))
    final = float(np.mean(losses[-10:]))
    print(f"\nloss {first:.3f} -> {final:.3f} over {last} steps "
          f"({len(monitor.flagged)} straggler steps flagged)")
    assert final < first, "training failed to reduce loss"
    print(f"checkpoints under experiments/ckpt_lm_demo "
          f"(latest step {ck.latest_step()})")


if __name__ == "__main__":
    main()
