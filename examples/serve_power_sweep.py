"""Serving with dynamic power control: a LIVE error-config sweep through
one continuous-batching engine.

The paper's knob generalized to LM serving — and, since PR 1, exercised
the way the paper means it: the error config is a traced runtime value,
so ONE engine (one compiled prefill + one compiled decode executable)
serves every config.  The sweep below retunes the live engine between
batches with ``set_approx_cfg`` and asserts ZERO recompilations via the
jit compilation-cache counters; the report shows tokens generated, token
agreement vs the exact run, and the modeled MAC energy saving.

  PYTHONPATH=src python examples/serve_power_sweep.py

The demo exercises every serving mode: dense (XLA + fused-Pallas
backends), MoE (grouped expert kernel, per-expert configs), the online
power-budget scheduler, and — with --mesh DPxTP — the SHARDED engine
(DESIGN.md §8).  Sharding needs dp*tp visible devices; off-TPU force
host devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python examples/serve_power_sweep.py --mesh 4x2

(--mesh 4x2 keeps tp=2 dividing the demo model's 2 KV heads — the
bit-exact heads-TP regime; any DPxTP works, see DESIGN.md §8.)
"""
import argparse

import jax
import numpy as np

from repro.nn import transformer as T
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, metavar="DPxTP",
                    help="also demo the sharded engine on a (data, "
                         "model) mesh, e.g. 2x4")
    args = ap.parse_args()
    cfg = T.ModelConfig(
        name="demo-lm", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, scan_layers=False,
        remat=False, q_chunk=64, loss_chunks=1,
        compute_dtype=jax.numpy.float32)
    params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, 4 layers, GQA kv=2")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 512, size=rng.integers(6, 20))
               for _ in range(6)]

    eng = Engine(params, cfg, max_batch=3, max_len=64)

    def run_batch():
        # identical sampling-key stream every batch, so token agreement
        # isolates the error config's effect (not RNG divergence)
        eng.rng = jax.random.PRNGKey(0)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=12))
        done, eng.completed = eng.run(), []
        toks = {r.rid: r.tokens for r in done}
        return [t for rid in sorted(toks) for t in toks[rid]]

    baseline_tokens = None
    caches_after_warmup = None
    prev_energy = prev_exact = 0.0
    print(f"{'cfg':>4} {'tokens':>7} {'agree':>7} {'MAC energy':>12} "
          f"{'saving':>7}")
    for approx_cfg in (0, 1, 8, 16, 31):
        eng.set_approx_cfg(approx_cfg)      # live retune, no recompile
        flat = run_batch()
        if baseline_tokens is None:
            baseline_tokens = flat
            # jit caches are warm now: one decode + one prefill executable
            # per prompt-length shape, shared by every config from here on
            caches_after_warmup = (eng._decode._cache_size(),
                                   eng._prefill._cache_size())
        agree = float(np.mean([a == b for a, b in
                               zip(flat, baseline_tokens)]))
        rep = eng.energy_report()
        e_cfg, prev_energy = rep["modeled_mac_energy_j"] - prev_energy, \
            rep["modeled_mac_energy_j"]
        e_ex, prev_exact = rep["exact_mac_energy_j"] - prev_exact, \
            rep["exact_mac_energy_j"]
        saving = 1.0 - e_cfg / e_ex if e_ex > 0 else 0.0
        print(f"{approx_cfg:4d} {len(flat):7d} {agree*100:6.1f}% "
              f"{e_cfg*1e3:9.3f} mJ {saving*100:6.2f}%")

    now = (eng._decode._cache_size(), eng._prefill._cache_size())
    assert now == caches_after_warmup, \
        f"config sweep recompiled: {caches_after_warmup} -> {now}"
    print(f"\nzero recompiles across the sweep: decode/prefill executables "
          f"stayed at {now}")

    # mixed per-request configs in ONE decode pool (conservative min-join),
    # then a per-layer allocation as a DynamicPowerController would emit
    eng.set_approx_cfg(0)
    for i, p in enumerate(prompts[:3]):
        eng.submit(Request(rid=100 + i, prompt=p, max_new_tokens=8,
                           approx_cfg=(0, 8, 31)[i]))
    done, eng.completed = eng.run(), []
    print(f"mixed per-request configs: {len(done)} requests served")
    eng.apply_allocation({"layer_0": 0, "layer_1": 8, "layer_2": 16,
                          "layer_3": 31})
    for i, p in enumerate(prompts[:3]):
        eng.submit(Request(rid=200 + i, prompt=p, max_new_tokens=8))
    done, eng.completed = eng.run(), []
    assert (eng._decode._cache_size(),
            eng._prefill._cache_size()) == caches_after_warmup
    print(f"per-layer allocation {eng.approx_cfg.tolist()} served "
          f"{len(done)} requests — still no recompiles")

    # ---- the fused Pallas backend (PR 2) --------------------------------
    # ModelConfig.mac_backend="pallas" routes every GEMM through the
    # fused approx-MAC kernel (in-kernel activation quantization + f32
    # rescale, per-N-block config vectors); the engine pre-quantizes the
    # weights into QTensors ONCE at init.  cfg_groups=2 widens the knob
    # to per-layer-per-neuron-group matrices.  Off-TPU the kernel runs
    # in interpret mode (mac_interpret) — slow but bit-identical, so we
    # demo on a short batch.  Pick block shapes for YOUR GEMMs with:
    #   from repro.kernels.approx_mac.ops import autotune_block_shapes
    #   best = autotune_block_shapes(m, k, n, config=8)[0]  # fastest-first
    #   cfg = dataclasses.replace(cfg, mac_blocks=(best["bm"], best["bn"],
    #                                              best["bk"]))
    # (benchmarks/run.py pallas_path sweeps this into
    #  BENCH_pallas_path.json.)
    import dataclasses
    cfg_p = dataclasses.replace(cfg, mac_backend="pallas",
                                mac_interpret=True)
    eng_p = Engine(params, cfg_p, max_batch=3, max_len=64, cfg_groups=2)
    eng_p.rng = jax.random.PRNGKey(0)
    # outer neuron group of every layer at cfg 31, inner exact
    eng_p.set_approx_cfg(np.stack([np.zeros(4, np.int32),
                                   np.full(4, 31, np.int32)], axis=1))
    for i, p in enumerate(prompts[:3]):
        eng_p.submit(Request(rid=300 + i, prompt=p, max_new_tokens=4))
    done, eng_p.completed = eng_p.run(), []
    rep = eng_p.energy_report()
    print(f"\npallas backend (fused kernel, per-layer-per-block configs "
          f"{eng_p.approx_cfg.tolist()}): {len(done)} requests, "
          f"saving {rep['saving_frac']*100:.2f}%")
    # ---- the grouped MoE expert kernel (PR 3) ---------------------------
    # On a MoE model the expert FFN is ONE grouped pallas_call (the
    # expert loop lives in the kernel grid, DESIGN.md §4) and
    # cfg_experts widens the knob with an EXPERT axis: (n_layers,
    # n_experts, cfg_groups) config tensors, every expert at its own
    # error config, retuned live with zero recompiles.
    cfg_m = T.ModelConfig(
        name="demo-moe", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=512, n_experts=4, top_k=2,
        scan_layers=False, remat=False, q_chunk=64, loss_chunks=1,
        compute_dtype=jax.numpy.float32, mac_backend="pallas",
        mac_interpret=True)
    params_m, _ = T.init_lm(jax.random.PRNGKey(1), cfg_m)
    eng_m = Engine(params_m, cfg_m, max_batch=2, max_len=64, cfg_experts=4)
    eng_m.rng = jax.random.PRNGKey(0)
    # expert 0 exact, experts 1-3 increasingly aggressive, both layers
    eng_m.set_approx_cfg(np.broadcast_to(
        np.asarray([0, 8, 16, 31], np.int32)[None, :, None], (2, 4, 1)))
    for i, p in enumerate(prompts[:2]):
        eng_m.submit(Request(rid=400 + i, prompt=p, max_new_tokens=4))
    done, eng_m.completed = eng_m.run(), []
    warm = (eng_m._decode._cache_size(), eng_m._prefill._cache_size())
    # single-expert retune, as a controller allocation would emit
    eng_m.apply_allocation({(0, 1): 31, (1, 3): 8})
    for i, p in enumerate(prompts[:2]):
        eng_m.submit(Request(rid=410 + i, prompt=p, max_new_tokens=4))
    done, eng_m.completed = eng_m.run(), []
    assert (eng_m._decode._cache_size(),
            eng_m._prefill._cache_size()) == warm
    print(f"\ngrouped MoE engine (per-expert configs "
          f"{eng_m.approx_cfg[..., 0].tolist()}): {len(done)} requests — "
          f"per-expert retune, still no recompiles")

    # ---- the online power-budget scheduler (PR 4) -----------------------
    # Everything above retunes the engine BY HAND.  Engine(scheduler=...)
    # closes the loop: the scheduler consumes a joules/token budget,
    # shadow-probes decode steps at the exact config (same compiled
    # executable) to MEASURE token agreement, and retunes the pool every
    # few ticks with the same greedy core as the offline
    # DynamicPowerController — self-driving dynamic power control
    # (DESIGN.md §7; benchmarks/run.py scheduler quantifies convergence
    # on a trained model).
    from repro.core.power_model import energy_per_token_pj
    from repro.serve.scheduler import PowerBudgetScheduler
    sched = PowerBudgetScheduler(0.0, retune_every=4, probe_every=2)
    eng_s = Engine(params, cfg, max_batch=3, max_len=64, scheduler=sched)
    eng_s.rng = jax.random.PRNGKey(0)
    exact_pj = energy_per_token_pj(np.zeros(cfg.n_layers, np.int32),
                                   eng_s.macs_per_token)
    sched.set_budget(0.85 * exact_pj)   # 15% below exact-mode energy
    warm = None
    for round_ in range(6):
        for i, p in enumerate(prompts):
            eng_s.submit(Request(rid=500 + 10 * round_ + i, prompt=p,
                                 max_new_tokens=8))
        eng_s.run()
        if warm is None:
            warm = (eng_s._decode._cache_size(),
                    eng_s._prefill._cache_size())
    rep = sched.report()
    assert (eng_s._decode._cache_size(),
            eng_s._prefill._cache_size()) == warm
    print(f"\nbudget scheduler: target {sched.budget_pj_per_token/1e3:.1f}"
          f" nJ/token -> measured {rep['measured_pj_per_token']/1e3:.1f}"
          f" nJ/token, allocation {rep['assignment']}, "
          f"{rep['probes']} probes ({rep['agreement']*100:.0f}% agree, "
          f"{rep['backoffs']} backoffs), {rep['retunes']} retunes — "
          f"probes and retunes recompiled nothing")
    # ---- chaos-hardened serving (PR 7) ----------------------------------
    # The same engine under injected faults (DESIGN.md §10): a seeded
    # FaultInjector corrupts logits and fails a decode step mid-run; the
    # NaN/Inf guard rolls the tick back (cache uncommitted) and
    # quarantines the offending config one notch toward exact, the
    # retry path re-decodes after a capped backoff, and a
    # BrownoutController sheds joules/token — not requests — under
    # queue pressure.  Chaos compiles NOTHING new: the injector only
    # touches executable OUTPUTS, so the zero-recompile invariant of
    # every section above holds under fault load too.
    from repro.serve.brownout import BrownoutController
    from repro.serve.faults import FaultEvent, FaultInjector
    inj = FaultInjector([FaultEvent(tick=2, kind="nan_logits"),
                         FaultEvent(tick=5, kind="step_fail")], seed=0)
    eng_r = Engine(params, cfg, max_batch=3, max_len=64,
                   queue_capacity=8, fault_injector=inj,
                   brownout=BrownoutController(ladder=(0, 16, 31),
                                               high_watermark=0.5,
                                               hold_ticks=2),
                   retry_base_s=1e-3)
    eng_r.rng = jax.random.PRNGKey(0)
    for i, p in enumerate(prompts):
        eng_r.submit(Request(rid=600 + i, prompt=p, max_new_tokens=8,
                             ttft_slo_s=30.0, e2e_slo_s=30.0))
    done, eng_r.completed = eng_r.run(), []
    rr = eng_r.resilience_report()
    assert all(r.status == "done" for r in done), rr
    assert (eng_r._decode._cache_size(),
            eng_r._prefill._cache_size()) == caches_after_warmup
    print(f"\nchaos run: {len(done)} requests served through "
          f"{sum(inj.counts.values())} injected faults "
          f"({rr['nan_events']} NaN rollbacks, {rr['retries']} retries, "
          f"{rr['quarantined']} quarantines, "
          f"{eng_r.brownout.n_escalations} brownout escalations) — "
          f"every request finished, nothing recompiled")

    # ---- approx-draft speculative decoding (PR 9) -----------------------
    # The knob's last trick: an aggressive low-power config IS a free
    # draft model (DESIGN.md §12).  Engine(spec=SpecConfig(...)) makes
    # eligible greedy ticks draft k tokens at draft_cfg and verify all
    # of them in ONE service-config pass — every emitted token is the
    # VERIFIER's own argmax, so the stream matches plain greedy by
    # construction, and a live (k, draft-cfg) retarget via set_spec
    # compiles nothing (k is a host loop count, draft_cfg traced data;
    # benchmarks/run.py speculative enforces the identity/energy bars
    # on a trained model).
    from repro.serve.speculative import SpecConfig
    eng_v = Engine(params, cfg, max_batch=3, max_len=64,
                   spec=SpecConfig(draft_cfg=8, k=3, max_k=5))
    eng_v.rng = jax.random.PRNGKey(0)
    warm = None
    for k, dcfg in ((3, 8), (2, 16), (5, 31)):
        eng_v.set_spec(SpecConfig(draft_cfg=dcfg, k=k, max_k=5))
        for i, p in enumerate(prompts[:3]):
            eng_v.submit(Request(rid=700 + 10 * k + i, prompt=p,
                                 max_new_tokens=8))
        done, eng_v.completed = eng_v.run(), []
        if warm is None:
            warm = (eng_v._decode._cache_size(),
                    eng_v._verify._cache_size())
    assert (eng_v._decode._cache_size(),
            eng_v._verify._cache_size()) == warm
    tv = (eng_v.n_spec_emitted / eng_v.n_verify_steps
          if eng_v.n_verify_steps else 0.0)
    print(f"\nspeculative decoding: {eng_v.n_spec_ticks} spec ticks "
          f"across a (k, draft-cfg) sweep, "
          f"{eng_v.n_spec_emitted}/{eng_v.n_draft_tokens} "
          f"emitted/drafted ({tv:.2f} tokens per verify pass) — "
          f"draft retargets recompiled nothing")

    # ---- per-class power budgets (PR 10) --------------------------------
    # One global budget, split across traffic classes (DESIGN.md §13):
    # each TrafficClass declares a budget_share, the scheduler turns the
    # shares into per-class pJ/token TARGETS scaled by the class's live
    # token mix, the engine attributes every serve-pass joule to the
    # class that spent it, and each retune re-splits the shares from
    # measured usage — unspent budget flows to the hot class.  The class
    # layer is pure attribution + adaptation on the host: the planner
    # still drives ONE pool config, so nothing recompiles.
    from repro.serve.traffic import (TrafficClass, TrafficGenerator,
                                     class_budget_shares)
    classes = (TrafficClass("chat", prompt_len=8, max_new_tokens=8,
                            weight=2.0, budget_share=0.5),
               TrafficClass("bulk", prompt_len=12, max_new_tokens=8,
                            weight=1.0, budget_share=0.5))
    gen = TrafficGenerator(classes, rate_per_tick=0.6, seed=0,
                           vocab_size=cfg.vocab_size)
    # retune_every=8 keeps both classes present in (almost) every
    # usage window — a window one class sits out re-splits toward the
    # other, so tiny windows make the split chase arrival noise
    sched_c = PowerBudgetScheduler(0.0, retune_every=8, probe_every=2)
    sched_c.set_class_budgets(class_budget_shares(classes))
    eng_c = Engine(params, cfg, max_batch=4, max_len=64,
                   scheduler=sched_c, prefill_pad=16)
    eng_c.rng = jax.random.PRNGKey(0)
    sched_c.set_budget(0.85 * exact_pj)
    share_sum, n_meas = {c.name: 0.0 for c in classes}, 0
    for t in range(120):
        for r in gen.arrivals(t):
            eng_c.submit(r)
        eng_c.step()
        if t >= 40:                     # past the first retunes
            n_meas += 1
            for name, s in sched_c.class_shares.items():
                share_sum[name] += s
    # report the TIME-MEAN split: with 4 batch slots a single retune
    # window often sees one class only, so the instantaneous share
    # oscillates around the mix — the mean is the closed-loop signal
    # (benchmarks/run.py traffic measures the same way)
    mean_share = {c: v / n_meas for c, v in share_sum.items()}
    eng_c.run()                         # drain the tail
    print("\nper-class budgets (even 0.5/0.5 split over a 2:1 arrival "
          "mix — watch the re-split repair it):")
    for name in sorted(eng_c.serve_tokens_by_class):
        de = eng_c.serve_energy_by_class[name]
        dn = eng_c.serve_tokens_by_class[name]
        pj_tok = de / max(dn, 1) * eng_c.macs_per_token
        print(f"  {name:>5}: {dn:4d} tokens, "
              f"{pj_tok / 1e3:7.1f} nJ/token, "
              f"share {class_budget_shares(classes)[name]:.2f} -> "
              f"{mean_share.get(name, 0.0):.3f} (mean)")
    # prefill_pad folds both class prompt shapes into one executable
    assert (eng_c._decode._cache_size(),
            eng_c._prefill._cache_size()) == (1, 1)
    print("  class re-splits retuned the split, recompiled nothing")

    # ---- the sharded engine (PR 5) --------------------------------------
    # Engine(mapping=...) serves the SAME model TP-sharded over a
    # (data, model) mesh (DESIGN.md §8): params placed by their logical
    # specs, KV cache sharded over heads, config tensors REPLICATED —
    # so the live retunes above reach every shard with zero recompiles,
    # and the sharded token stream is bit-identical to single-host.
    if args.mesh:
        from repro.dist.sharding import serve_mapping
        from repro.launch.mesh import make_serve_mesh
        dp, tp = (int(x) for x in args.mesh.lower().split("x"))
        mapping = serve_mapping(make_serve_mesh(dp=dp, tp=tp), kv="hd")
        mixed = np.asarray([0, 8, 16, 31], np.int32)

        def fresh_batch(mapping):
            # fresh engines on both sides: a reused engine's cache rows
            # beyond a new slot's prompt hold the PREVIOUS batch's K/V
            # (not zeros), so used-vs-fresh token streams differ — the
            # comparison must isolate sharding, nothing else
            e = Engine(params, cfg, max_batch=3, max_len=64,
                       mapping=mapping, param_specs=specs)
            e.rng = jax.random.PRNGKey(0)
            e.set_approx_cfg(mixed)
            for i, p in enumerate(prompts):
                e.submit(Request(rid=i, prompt=p, max_new_tokens=12))
            toks = {r.rid: r.tokens for r in e.run()}
            e.completed = []
            return e, [t for rid in sorted(toks) for t in toks[rid]]

        _, ref = fresh_batch(None)
        eng_d, flat = fresh_batch(mapping)
        warm = (eng_d._decode._cache_size(), eng_d._prefill._cache_size())
        eng_d.apply_allocation({0: 31, 2: 5})   # retunes the whole mesh
        for i, p in enumerate(prompts[:3]):
            eng_d.submit(Request(rid=50 + i, prompt=p, max_new_tokens=8))
        done, eng_d.completed = eng_d.run(), []
        assert (eng_d._decode._cache_size(),
                eng_d._prefill._cache_size()) == warm
        agree = float(np.mean([a == b for a, b in zip(flat, ref)]))
        if cfg.n_kv_heads % tp == 0:
            # heads TP: attention whole per head -> bit-exact decode
            assert flat == ref, "sharded decode must be bit-identical"
            note = "bit-identical to single-host"
        else:
            # kv heads don't divide tp, so head_dim takes the model
            # axis: the float attention contraction reassociates across
            # shards — numerically equivalent, and this RANDOM-INIT
            # model's near-uniform logits flip argmax on 1e-7 noise, so
            # raw token agreement is not meaningful here (DESIGN.md §8;
            # pick tp dividing n_kv_heads, e.g. --mesh 4x2, for the
            # bit-exact regime)
            note = (f"numerically equivalent ({agree*100:.0f}% raw "
                    f"token agreement: kv_heads={cfg.n_kv_heads} % "
                    f"tp={tp} != 0 shards head_dim)")
        print(f"\nsharded engine (({dp}, {tp}) mesh, per-layer configs "
              f"{mixed.tolist()}): {len(flat)} tokens, {note} — "
              f"replicated-config retune recompiled nothing")
    else:
        print("\n(pass --mesh 4x2 with 8 visible devices to demo the "
              "sharded engine)")

    print("\n(agreement = generated-token match vs the exact engine; "
          "energy = calibrated per-MAC model, DESIGN.md §2)")


if __name__ == "__main__":
    main()
