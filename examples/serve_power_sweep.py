"""Serving with dynamic power control: batched requests through the
continuous-batching engine at several MAC error configurations.

The paper's knob generalized to LM serving: each engine instance runs
all GEMMs at one error config; the report shows tokens generated, token
agreement vs the exact engine, and the modeled MAC energy saving.

  PYTHONPATH=src python examples/serve_power_sweep.py
"""
import jax
import numpy as np

from repro.nn import transformer as T
from repro.serve.engine import Engine, Request


def main():
    cfg = T.ModelConfig(
        name="demo-lm", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, scan_layers=False,
        remat=False, q_chunk=64, loss_chunks=1,
        compute_dtype=jax.numpy.float32)
    params, _ = T.init_lm(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, 4 layers, GQA kv=2")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 512, size=rng.integers(6, 20))
               for _ in range(6)]

    baseline_tokens = None
    print(f"{'cfg':>4} {'tokens':>7} {'agree':>7} {'MAC energy':>12} "
          f"{'saving':>7}")
    for approx_cfg in (0, 1, 8, 16, 31):
        eng = Engine(params, cfg, max_batch=3, max_len=64,
                     approx_cfg=approx_cfg)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=12))
        done = eng.run()
        toks = {r.rid: r.tokens for r in done}
        flat = [t for rid in sorted(toks) for t in toks[rid]]
        if baseline_tokens is None:
            baseline_tokens = flat
        agree = float(np.mean([a == b for a, b in
                               zip(flat, baseline_tokens)]))
        rep = eng.energy_report()
        print(f"{approx_cfg:4d} {len(flat):7d} {agree*100:6.1f}% "
              f"{rep['modeled_mac_energy_j']*1e3:9.3f} mJ "
              f"{rep['saving_frac']*100:6.2f}%")
    print("\n(agreement = generated-token match vs the exact engine; "
          "energy = calibrated per-MAC model, DESIGN.md §2)")


if __name__ == "__main__":
    main()
