"""Pallas API-drift shims shared by the kernel modules."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
