"""Pallas TPU kernel: error-configurable int8 MAC matmul.

The paper's MAC-array knob, adapted to the MXU (DESIGN.md §2): operand
magnitudes are LSB-truncated (with optional round-to-nearest and an
operand-magnitude gate) *inside the kernel*, then fed to exact int8
dot_generals accumulating in an int32 VMEM scratch tile.  The truncation
is a handful of VPU integer ops per element on tiles already resident in
VMEM — the approximation costs no extra HBM traffic.

Runtime reconfigurability (the paper's actual contribution): the
per-call (depth_a, depth_b, gate, rtn) parameters arrive as a (4,)
int32 *scalar-prefetch* operand in SMEM, not as closure constants, so
ONE compiled kernel serves all 32 error configurations — switching the
power mode between calls retraces and recompiles nothing.

Tiling: grid (M/bm, N/bn, K/bk), A tile (bm, bk) and B tile (bk, bn) in
VMEM, int32 accumulator scratch (bm, bn).  bm = bn = 128 and bk = 256
keep the MXU dims 128-aligned and the working set
(128*256 + 256*128 int8 + 128*128 int32) = 128 KiB well inside VMEM;
ops.py lets benchmarks sweep block shapes.

The contraction (k) grid dimension is marked "arbitrary" so the
accumulator carries across k-steps on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.approx_multiplier import OPERAND_PARAM_TABLE
from repro.core.quantization import truncate_operand_lsb
from repro.kernels.compat import CompilerParams as _CompilerParams


def _truncate(v, depth, gate, rtn):
    """Elementwise int8->int32 magnitude truncation (VPU ops only).

    depth/gate/rtn are traced int32 scalars read from SMEM, so this is
    exactly the traced branch of core.quantization.truncate_operand_lsb
    — ONE definition of the bit-level semantics shared by the XLA path
    and the kernel (pure jnp integer ops, pallas-traceable)."""
    return truncate_operand_lsb(v, depth, gate, rtn).astype(jnp.int32)


def _kernel(cfg_ref, a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _truncate(a_ref[...], cfg_ref[0], cfg_ref[2], cfg_ref[3])
    b = _truncate(b_ref[...], cfg_ref[1], cfg_ref[2], cfg_ref[3])
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def config_operand(config) -> jax.Array:
    """(4,) int32 scalar-prefetch operand for a static or traced config."""
    if isinstance(config, jax.Array):
        return jnp.asarray(OPERAND_PARAM_TABLE)[
            jnp.asarray(config, jnp.int32)]
    return jnp.asarray(OPERAND_PARAM_TABLE[int(config)])


def approx_mac_matmul(a, b, config=0, *, bm: int = 128,
                      bn: int = 128, bk: int = 256,
                      interpret: bool = False):
    """a: (M, K) int8, b: (K, N) int8 -> (M, N) int32 under `config`.

    `config` may be a Python int or a traced int32 scalar — either way
    the compiled kernel is config-independent (params ride in SMEM).
    Shapes must be pre-padded to tile multiples (ops.py handles padding).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    k_steps = k // bk
    kernel = lambda *refs: _kernel(*refs, k_steps=k_steps)
    common = dict(
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    if hasattr(pltpu, "PrefetchScalarGridSpec"):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m // bm, n // bn, k_steps),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, ks, cfg: (i, ks)),
                pl.BlockSpec((bk, bn), lambda i, j, ks, cfg: (ks, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, ks, cfg: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        )
        return pl.pallas_call(
            kernel, grid_spec=grid_spec, **common,
        )(config_operand(config), a, b)
    # newer jax drops PrefetchScalarGridSpec along with TPUCompilerParams:
    # pass the (4,) config as a plain SMEM-resident input instead (same
    # kernel signature; loses only the prefetch hint)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((bk, bn), lambda i, j, ks: (ks, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ks: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        **common,
    )(config_operand(config), a, b)
