"""Pallas TPU kernel: error-configurable int8 MAC matmul.

The paper's MAC-array knob, adapted to the MXU (DESIGN.md §2): operand
magnitudes are LSB-truncated (with optional round-to-nearest and an
operand-magnitude gate) *inside the kernel*, then fed to exact int8
dot_generals accumulating in an int32 VMEM scratch tile.  The truncation
is a handful of VPU integer ops per element on tiles already resident in
VMEM — the approximation costs no extra HBM traffic.

Tiling: grid (M/bm, N/bn, K/bk), A tile (bm, bk) and B tile (bk, bn) in
VMEM, int32 accumulator scratch (bm, bn).  bm = bn = 128 and bk = 256
keep the MXU dims 128-aligned and the working set
(128*256 + 256*128 int8 + 128*128 int32) = 128 KiB well inside VMEM;
ops.py lets benchmarks sweep block shapes.

The contraction (k) grid dimension is marked "arbitrary" so the
accumulator carries across k-steps on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.approx_multiplier import config_params


def _truncate(v, depth: int, gate: int, rtn: bool):
    """Elementwise int8->int32 magnitude truncation (VPU ops only)."""
    v = v.astype(jnp.int32)
    if depth <= 0:
        return v
    mag = jnp.abs(v)
    sign = jnp.sign(v)
    low_mask = (1 << depth) - 1
    if rtn:
        tmag = jnp.minimum((mag + (1 << (depth - 1))) & ~low_mask, 127)
    else:
        tmag = mag & ~low_mask
    if gate > 0:
        tmag = jnp.where(mag >= gate, tmag, mag)
    return sign * tmag


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, depth_a, depth_b, gate, rtn,
            k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _truncate(a_ref[...], depth_a, gate, rtn)
    b = _truncate(b_ref[...], depth_b, gate, rtn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def approx_mac_matmul(a, b, config: int = 0, *, bm: int = 128,
                      bn: int = 128, bk: int = 256,
                      interpret: bool = False):
    """a: (M, K) int8, b: (K, N) int8 -> (M, N) int32 under `config`.

    Shapes must be pre-padded to tile multiples (ops.py handles padding).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    if config == 0:
        depth_a = depth_b = gate = 0
        rtn = False
    else:
        mode, t, gate = config_params(config)
        rtn = mode in (1, 2)
        depth_a = t // 2
        depth_b = t - t // 2
    k_steps = k // bk
    kernel = functools.partial(_kernel, depth_a=depth_a, depth_b=depth_b,
                               gate=gate, rtn=rtn, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((bk, bn), lambda i, j, ks: (ks, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ks: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
