"""Pallas TPU kernel: error-configurable int8 MAC matmul.

The paper's MAC-array knob, adapted to the MXU (DESIGN.md §2): operand
magnitudes are LSB-truncated (with optional round-to-nearest and an
operand-magnitude gate) *inside the kernel*, then fed to exact int8
dot_generals accumulating in an int32 VMEM scratch tile.  The truncation
is a handful of VPU integer ops per element on tiles already resident in
VMEM — the approximation costs no extra HBM traffic.

Runtime reconfigurability (the paper's actual contribution): the
per-call (depth_a, depth_b, gate, rtn) parameters arrive as a
**per-N-column-block (n_blocks, 4)** int32 *scalar-prefetch* operand in
SMEM indexed by ``program_id(1)``, not as closure constants.  Two
consequences:

  * ONE compiled kernel serves all 32 error configurations — switching
    the power mode between calls retraces and recompiles nothing;
  * different output-column blocks of ONE GEMM can run at different
    error configs — the hardware's per-MAC (per-neuron) granularity,
    still inside a single compiled executable (DESIGN.md §3).

Three kernel variants share the truncation body:

  * ``approx_mac_matmul``      — int8 x int8 -> int32 (quantized inputs)
  * ``approx_mac_fused_matmul``— f32 x int8 -> f32: dynamic activation
    quantization (divide by a prefetched abs-max scale, round, clip) and
    the f32 rescale epilogue run INSIDE the kernel, so a float-in /
    float-out approx dense is one pallas_call — no int8 activation or
    int32 accumulator tensor ever round-trips through HBM.
  * ``approx_mac_grouped_matmul`` — the fused variant with a leading
    EXPERT grid axis (DESIGN.md §4): one pallas_call computes E
    independent GEMMs against a stacked (E, K, N) weight bank — the MoE
    expert loop folded into the kernel grid, no per-expert dispatch.
    Per-expert valid-row counts ride as scalar-prefetch metadata so
    empty / ragged expert slices skip their MXU work, and the config
    operand widens to (E, n_blocks, 4) — the error knob becomes
    per-EXPERT (x per-neuron-block) inside one compiled kernel.

Tiling: grid (M/bm, N/bn, K/bk), A tile (bm, bk) and B tile (bk, bn) in
VMEM, int32 accumulator scratch (bm, bn).  bm = bn = 128 and bk = 256
keep the MXU dims 128-aligned and the working set
(128*256 + 256*128 int8 + 128*128 int32) = 128 KiB well inside VMEM;
ops.py lets benchmarks sweep block shapes (``autotune_block_shapes``).

The contraction (k) grid dimension is marked "arbitrary" so the
accumulator carries across k-steps on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.approx_matmul import operand_param_table
from repro.core.approx_multiplier import OPERAND_PARAM_TABLE
from repro.core.quantization import QMAX, truncate_operand_lsb
from repro.kernels.compat import CompilerParams as _CompilerParams


def _truncate(v, depth, gate, rtn):
    """Elementwise int8->int32 magnitude truncation (VPU ops only).

    depth/gate/rtn are traced int32 scalars read from SMEM, so this is
    exactly the traced branch of core.quantization.truncate_operand_lsb
    — ONE definition of the bit-level semantics shared by the XLA path
    and the kernel (pure jnp integer ops, pallas-traceable)."""
    return truncate_operand_lsb(v, depth, gate, rtn).astype(jnp.int32)


def _block_cfg(cfg_ref):
    """This N-block's (depth_a, depth_b, gate, rtn) from the per-tile
    (n_blocks, 4) SMEM config vector — the per-neuron knob."""
    j = pl.program_id(1)
    return cfg_ref[j, 0], cfg_ref[j, 1], cfg_ref[j, 2], cfg_ref[j, 3]


def _kernel(cfg_ref, a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    depth_a, depth_b, gate, rtn = _block_cfg(cfg_ref)
    a = _truncate(a_ref[...], depth_a, gate, rtn)
    b = _truncate(b_ref[...], depth_b, gate, rtn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def _fused_kernel(cfg_ref, xscale_ref, x_ref, b_ref, scale_ref, o_ref,
                  acc_ref, *, k_steps):
    """Float-in/float-out variant: quantize the f32 activation tile with
    the prefetched per-tensor scale, truncate, int8 MAC, and rescale to
    f32 in the epilogue — all on VMEM-resident tiles.

    The quantize/rescale arithmetic mirrors core.quantization.quantize
    and core.approx_matmul.approx_dense op-for-op: scale_ref carries the
    COMBINED x_scale * w_scale row (rounded once by the wrapper), so the
    epilogue is a SINGLE f32 multiply with no association freedom — XLA
    cannot regroup it differently across paths, keeping the fused path
    bit-identical to the unfused XLA operand path."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_scale = xscale_ref[0]
    depth_a, depth_b, gate, rtn = _block_cfg(cfg_ref)
    x_q = jnp.clip(jnp.round(x_ref[...] / x_scale), -QMAX, QMAX
                   ).astype(jnp.int8)
    a = _truncate(x_q, depth_a, gate, rtn)
    b = _truncate(b_ref[...], depth_b, gate, rtn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale_ref[...]


def config_operand(config, n_blocks: int = 1) -> jax.Array:
    """(n_blocks, 4) int32 scalar-prefetch operand.

    `config` may be a Python int or a traced int32 scalar (one config
    for every block), or an exactly-(n_blocks,) vector of config
    indices (per-block configs).  Shorter "neuron group" vectors are a
    wrapper-level concept: ops._expand_group_vector maps them onto the
    block grid using the LOGICAL output width (with conservative
    lowest-MRED collapse on straddling blocks) before the kernel call.
    Rows are gathered from the device-resident OPERAND_PARAM_TABLE
    (uploaded once per process, not re-embedded per trace).
    """
    if isinstance(config, (tuple, list)):
        config = jnp.asarray(config, jnp.int32)
    if isinstance(config, jax.Array):
        cfg = jnp.asarray(config, jnp.int32)
        if cfg.ndim == 0:
            return jnp.broadcast_to(operand_param_table()[cfg],
                                    (n_blocks, 4))
        assert cfg.shape == (n_blocks,), (cfg.shape, n_blocks)
        return operand_param_table()[cfg]
    return jnp.broadcast_to(jnp.asarray(OPERAND_PARAM_TABLE[int(config)]),
                            (n_blocks, 4))


def _grid_call(kernel, n_prefetch, grid, in_specs, out_shape, scratch,
               interpret):
    """pallas_call through PrefetchScalarGridSpec when available, else
    plain SMEM inputs (same kernel signature; loses only the prefetch
    hint).  in_specs are the non-scalar specs with index maps taking one
    argument per grid dimension (the contraction dim is last/innermost)
    — prefetch args are appended automatically."""
    ng = len(grid)
    common = dict(
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",) * (ng - 1) + ("arbitrary",)),
        interpret=interpret,
    )
    bspecs, ospec = in_specs

    def with_prefetch(spec):
        index_map = spec.index_map
        return pl.BlockSpec(
            spec.block_shape,
            lambda *a, _m=index_map: _m(*a[:ng]))

    if hasattr(pltpu, "PrefetchScalarGridSpec"):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_prefetch,
            grid=grid,
            in_specs=[with_prefetch(s) for s in bspecs],
            out_specs=with_prefetch(ospec),
            scratch_shapes=scratch,
        )
        return pl.pallas_call(kernel, grid_spec=grid_spec, **common)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] * n_prefetch
        + list(bspecs),
        out_specs=ospec,
        scratch_shapes=scratch,
        **common,
    )


def approx_mac_matmul(a, b, config=0, *, bm: int = 128,
                      bn: int = 128, bk: int = 256,
                      interpret: bool = False):
    """a: (M, K) int8, b: (K, N) int8 -> (M, N) int32 under `config`.

    `config` may be a Python int, a traced int32 scalar, or a per-block
    config vector (see config_operand) — either way the compiled kernel
    is config-independent (params ride in SMEM).  Shapes must be
    pre-padded to tile multiples (ops.py handles padding).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    k_steps = k // bk
    kernel = lambda *refs: _kernel(*refs, k_steps=k_steps)
    call = _grid_call(
        kernel, 1, (m // bm, n // bn, k_steps),
        ([
            pl.BlockSpec((bm, bk), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((bk, bn), lambda i, j, ks: (ks, j)),
        ], pl.BlockSpec((bm, bn), lambda i, j, ks: (i, j))),
        jax.ShapeDtypeStruct((m, n), jnp.int32),
        [pltpu.VMEM((bm, bn), jnp.int32)],
        interpret,
    )
    return call(config_operand(config, n // bn), a, b)


def approx_mac_fused_matmul(x, w_q, scale_row, x_scale, config=0, *,
                            bm: int = 128, bn: int = 128, bk: int = 256,
                            interpret: bool = False):
    """Fused float-in/float-out approx GEMM: ONE pallas_call.

    x: (M, K) f32 activations (pre-padded); w_q: (K, N) int8;
    scale_row: (1, N) f32 COMBINED dequant scales — x_scale * w_scale
    per column, rounded once by the caller so the kernel epilogue is a
    single association-free multiply; x_scale: (1,) f32 per-tensor
    activation scale (abs-max/127, computed by the caller's single
    reduction pass, used for the in-kernel quantize); config: as in
    approx_mac_matmul.  Returns (M, N) f32 = dequantized approximate
    product — the int8 activations and the int32 accumulator exist only
    in VMEM.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and scale_row.shape == (1, n), \
        (x.shape, w_q.shape, scale_row.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    k_steps = k // bk
    kernel = lambda *refs: _fused_kernel(*refs, k_steps=k_steps)
    call = _grid_call(
        kernel, 2, (m // bm, n // bn, k_steps),
        ([
            pl.BlockSpec((bm, bk), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((bk, bn), lambda i, j, ks: (ks, j)),
            pl.BlockSpec((1, bn), lambda i, j, ks: (0, j)),
        ], pl.BlockSpec((bm, bn), lambda i, j, ks: (i, j))),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        [pltpu.VMEM((bm, bn), jnp.int32)],
        interpret,
    )
    return call(config_operand(config, n // bn),
                jnp.asarray(x_scale, jnp.float32).reshape(1),
                x.astype(jnp.float32), w_q, scale_row)


# ---------------------------------------------------------------------------
# grouped (MoE expert-bank) variant
# ---------------------------------------------------------------------------

def _grouped_kernel(cfg_ref, rows_ref, xscale_ref, x_ref, b_ref, scale_ref,
                    o_ref, acc_ref, *, k_steps, bm):
    """One (expert, m-block, n-block, k-step) grid cell of the grouped
    fused GEMM.  cfg_ref: (E, n_blocks, 4) SMEM — expert e's n-block j
    runs its own (depth_a, depth_b, gate, rtn); rows_ref: (E,) SMEM
    valid-row counts — an m-block with no valid row skips the MXU work
    entirely (its accumulator stays zero, so the epilogue writes zeros:
    exactly what computing the zero-masked rows would produce).
    scale_ref carries the COMBINED x_scale * w_scale rows (one rounding
    in the wrapper, one association-free epilogue multiply here — see
    _fused_kernel)."""
    e, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(rows_ref[e] > i * bm)
    def _mac():
        x_scale = xscale_ref[0]
        depth_a, depth_b = cfg_ref[e, j, 0], cfg_ref[e, j, 1]
        gate, rtn = cfg_ref[e, j, 2], cfg_ref[e, j, 3]
        x_q = jnp.clip(jnp.round(x_ref[0] / x_scale), -QMAX, QMAX
                       ).astype(jnp.int8)
        a = _truncate(x_q, depth_a, gate, rtn)
        b = _truncate(b_ref[0], depth_b, gate, rtn)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(jnp.float32) * scale_ref[...]


def grouped_config_operand(config, n_experts: int,
                           n_blocks: int = 1) -> jax.Array:
    """(E, n_blocks, 4) int32 scalar-prefetch operand for the grouped
    kernel.  `config` may be a Python int / traced scalar (one config
    for every expert and block), an (E,) per-expert vector, or an
    (E, n_blocks) per-expert-per-block matrix.  Group vectors shorter
    than n_blocks are a wrapper-level concept (ops expands them row-wise
    with the same conservative collapse as the dense path)."""
    if isinstance(config, (tuple, list)):
        config = jnp.asarray(config, jnp.int32)
    if isinstance(config, jax.Array):
        cfg = jnp.asarray(config, jnp.int32)
        if cfg.ndim == 0:
            cfg = jnp.broadcast_to(cfg, (n_experts, n_blocks))
        elif cfg.ndim == 1:
            assert cfg.shape == (n_experts,), (cfg.shape, n_experts)
            cfg = jnp.broadcast_to(cfg[:, None], (n_experts, n_blocks))
        else:
            assert cfg.shape == (n_experts, n_blocks), \
                (cfg.shape, n_experts, n_blocks)
        return operand_param_table()[cfg]
    return jnp.broadcast_to(jnp.asarray(OPERAND_PARAM_TABLE[int(config)]),
                            (n_experts, n_blocks, 4))


def approx_mac_grouped_matmul(x, w_q, scale_rows, x_scale, group_rows,
                              config=0, *, bm: int = 128, bn: int = 128,
                              bk: int = 256, interpret: bool = False):
    """Grouped fused approx GEMM over an expert bank: ONE pallas_call.

    x: (E, M, K) f32 per-expert activation slices (pre-padded; rows at
    index >= group_rows[e] must be zero — ops masks them); w_q:
    (E, K, N) int8 stacked weight bank; scale_rows: (E, N) f32 COMBINED
    dequant scales (x_scale * per-expert per-column w_scale, rounded
    once by the caller); x_scale: (1,) f32 shared per-tensor activation
    scale (for the in-kernel quantize); group_rows: (E,) int32 valid-row
    counts (ragged/empty experts skip their m-blocks); config: see
    grouped_config_operand.  Returns (E, M, N) f32 — E dequantized
    approximate products from one kernel launch, each expert (and each
    of its N-blocks) at its own error config.  Grid (E, M/bm, N/bn,
    K/bk); the expert axis is just the outermost parallel grid
    dimension, so folding the expert loop into the kernel costs no extra
    HBM traffic and no per-expert dispatch."""
    e, m, k = x.shape
    e2, k2, n = w_q.shape
    assert e == e2 and k == k2 and scale_rows.shape == (e, n), \
        (x.shape, w_q.shape, scale_rows.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    k_steps = k // bk
    kernel = lambda *refs: _grouped_kernel(*refs, k_steps=k_steps, bm=bm)
    call = _grid_call(
        kernel, 3, (e, m // bm, n // bn, k_steps),
        ([
            pl.BlockSpec((1, bm, bk), lambda g, i, j, ks: (g, i, ks)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, ks: (g, ks, j)),
            pl.BlockSpec((1, bn), lambda g, i, j, ks: (g, j)),
        ], pl.BlockSpec((1, bm, bn), lambda g, i, j, ks: (g, i, j))),
        jax.ShapeDtypeStruct((e, m, n), jnp.float32),
        [pltpu.VMEM((bm, bn), jnp.int32)],
        interpret,
    )
    return call(grouped_config_operand(config, e, n // bn),
                jnp.asarray(group_rows, jnp.int32).reshape(e),
                jnp.asarray(x_scale, jnp.float32).reshape(1),
                x.astype(jnp.float32), w_q, scale_rows)
