"""Pallas TPU kernel: error-configurable int8 MAC matmul.

The paper's MAC-array knob, adapted to the MXU (DESIGN.md §2): operand
magnitudes are LSB-truncated (with optional round-to-nearest and an
operand-magnitude gate) *inside the kernel*, then fed to exact int8
dot_generals accumulating in an int32 VMEM scratch tile.  The truncation
is a handful of VPU integer ops per element on tiles already resident in
VMEM — the approximation costs no extra HBM traffic.

Runtime reconfigurability (the paper's actual contribution): the
per-call (depth_a, depth_b, gate, rtn) parameters arrive as a
**per-N-column-block (n_blocks, 4)** int32 *scalar-prefetch* operand in
SMEM indexed by ``program_id(1)``, not as closure constants.  Two
consequences:

  * ONE compiled kernel serves all 32 error configurations — switching
    the power mode between calls retraces and recompiles nothing;
  * different output-column blocks of ONE GEMM can run at different
    error configs — the hardware's per-MAC (per-neuron) granularity,
    still inside a single compiled executable (DESIGN.md §3).

Two kernel variants share the truncation body:

  * ``approx_mac_matmul``      — int8 x int8 -> int32 (quantized inputs)
  * ``approx_mac_fused_matmul``— f32 x int8 -> f32: dynamic activation
    quantization (divide by a prefetched abs-max scale, round, clip) and
    the f32 rescale epilogue run INSIDE the kernel, so a float-in /
    float-out approx dense is one pallas_call — no int8 activation or
    int32 accumulator tensor ever round-trips through HBM.

Tiling: grid (M/bm, N/bn, K/bk), A tile (bm, bk) and B tile (bk, bn) in
VMEM, int32 accumulator scratch (bm, bn).  bm = bn = 128 and bk = 256
keep the MXU dims 128-aligned and the working set
(128*256 + 256*128 int8 + 128*128 int32) = 128 KiB well inside VMEM;
ops.py lets benchmarks sweep block shapes (``autotune_block_shapes``).

The contraction (k) grid dimension is marked "arbitrary" so the
accumulator carries across k-steps on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.approx_matmul import operand_param_table
from repro.core.approx_multiplier import OPERAND_PARAM_TABLE
from repro.core.quantization import QMAX, truncate_operand_lsb
from repro.kernels.compat import CompilerParams as _CompilerParams


def _truncate(v, depth, gate, rtn):
    """Elementwise int8->int32 magnitude truncation (VPU ops only).

    depth/gate/rtn are traced int32 scalars read from SMEM, so this is
    exactly the traced branch of core.quantization.truncate_operand_lsb
    — ONE definition of the bit-level semantics shared by the XLA path
    and the kernel (pure jnp integer ops, pallas-traceable)."""
    return truncate_operand_lsb(v, depth, gate, rtn).astype(jnp.int32)


def _block_cfg(cfg_ref):
    """This N-block's (depth_a, depth_b, gate, rtn) from the per-tile
    (n_blocks, 4) SMEM config vector — the per-neuron knob."""
    j = pl.program_id(1)
    return cfg_ref[j, 0], cfg_ref[j, 1], cfg_ref[j, 2], cfg_ref[j, 3]


def _kernel(cfg_ref, a_ref, b_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    depth_a, depth_b, gate, rtn = _block_cfg(cfg_ref)
    a = _truncate(a_ref[...], depth_a, gate, rtn)
    b = _truncate(b_ref[...], depth_b, gate, rtn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def _fused_kernel(cfg_ref, xscale_ref, x_ref, b_ref, wscale_ref, o_ref,
                  acc_ref, *, k_steps):
    """Float-in/float-out variant: quantize the f32 activation tile with
    the prefetched per-tensor scale, truncate, int8 MAC, and rescale to
    f32 in the epilogue — all on VMEM-resident tiles.

    The quantize/rescale arithmetic mirrors core.quantization.quantize
    and core.approx_matmul.approx_dense op-for-op (same round/clip/cast
    and the same f32 multiply order), so the fused path is bit-identical
    to the unfused XLA operand path."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_scale = xscale_ref[0]
    depth_a, depth_b, gate, rtn = _block_cfg(cfg_ref)
    x_q = jnp.clip(jnp.round(x_ref[...] / x_scale), -QMAX, QMAX
                   ).astype(jnp.int8)
    a = _truncate(x_q, depth_a, gate, rtn)
    b = _truncate(b_ref[...], depth_b, gate, rtn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * x_scale
                      * wscale_ref[...])


def config_operand(config, n_blocks: int = 1) -> jax.Array:
    """(n_blocks, 4) int32 scalar-prefetch operand.

    `config` may be a Python int or a traced int32 scalar (one config
    for every block), or an exactly-(n_blocks,) vector of config
    indices (per-block configs).  Shorter "neuron group" vectors are a
    wrapper-level concept: ops._expand_group_vector maps them onto the
    block grid using the LOGICAL output width (with conservative
    lowest-MRED collapse on straddling blocks) before the kernel call.
    Rows are gathered from the device-resident OPERAND_PARAM_TABLE
    (uploaded once per process, not re-embedded per trace).
    """
    if isinstance(config, (tuple, list)):
        config = jnp.asarray(config, jnp.int32)
    if isinstance(config, jax.Array):
        cfg = jnp.asarray(config, jnp.int32)
        if cfg.ndim == 0:
            return jnp.broadcast_to(operand_param_table()[cfg],
                                    (n_blocks, 4))
        assert cfg.shape == (n_blocks,), (cfg.shape, n_blocks)
        return operand_param_table()[cfg]
    return jnp.broadcast_to(jnp.asarray(OPERAND_PARAM_TABLE[int(config)]),
                            (n_blocks, 4))


def _grid_call(kernel, n_prefetch, grid, in_specs, out_shape, scratch,
               interpret):
    """pallas_call through PrefetchScalarGridSpec when available, else
    plain SMEM inputs (same kernel signature; loses only the prefetch
    hint).  in_specs are the non-scalar specs with index maps taking
    (i, j, ks) — prefetch args are appended automatically."""
    common = dict(
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    bspecs, ospec = in_specs

    def with_prefetch(spec):
        index_map = spec.index_map
        return pl.BlockSpec(
            spec.block_shape,
            lambda i, j, ks, *_, _m=index_map: _m(i, j, ks))

    if hasattr(pltpu, "PrefetchScalarGridSpec"):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_prefetch,
            grid=grid,
            in_specs=[with_prefetch(s) for s in bspecs],
            out_specs=with_prefetch(ospec),
            scratch_shapes=scratch,
        )
        return pl.pallas_call(kernel, grid_spec=grid_spec, **common)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] * n_prefetch
        + list(bspecs),
        out_specs=ospec,
        scratch_shapes=scratch,
        **common,
    )


def approx_mac_matmul(a, b, config=0, *, bm: int = 128,
                      bn: int = 128, bk: int = 256,
                      interpret: bool = False):
    """a: (M, K) int8, b: (K, N) int8 -> (M, N) int32 under `config`.

    `config` may be a Python int, a traced int32 scalar, or a per-block
    config vector (see config_operand) — either way the compiled kernel
    is config-independent (params ride in SMEM).  Shapes must be
    pre-padded to tile multiples (ops.py handles padding).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    k_steps = k // bk
    kernel = lambda *refs: _kernel(*refs, k_steps=k_steps)
    call = _grid_call(
        kernel, 1, (m // bm, n // bn, k_steps),
        ([
            pl.BlockSpec((bm, bk), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((bk, bn), lambda i, j, ks: (ks, j)),
        ], pl.BlockSpec((bm, bn), lambda i, j, ks: (i, j))),
        jax.ShapeDtypeStruct((m, n), jnp.int32),
        [pltpu.VMEM((bm, bn), jnp.int32)],
        interpret,
    )
    return call(config_operand(config, n // bn), a, b)


def approx_mac_fused_matmul(x, w_q, w_scale_row, x_scale, config=0, *,
                            bm: int = 128, bn: int = 128, bk: int = 256,
                            interpret: bool = False):
    """Fused float-in/float-out approx GEMM: ONE pallas_call.

    x: (M, K) f32 activations (pre-padded); w_q: (K, N) int8;
    w_scale_row: (1, N) f32 per-column weight scales (broadcast a
    per-tensor scale before calling); x_scale: (1,) f32 per-tensor
    activation scale (abs-max/127, computed by the caller's single
    reduction pass); config: as in approx_mac_matmul.  Returns (M, N)
    f32 = dequantized approximate product — the int8 activations and the
    int32 accumulator exist only in VMEM.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and w_scale_row.shape == (1, n), \
        (x.shape, w_q.shape, w_scale_row.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    k_steps = k // bk
    kernel = lambda *refs: _fused_kernel(*refs, k_steps=k_steps)
    call = _grid_call(
        kernel, 2, (m // bm, n // bn, k_steps),
        ([
            pl.BlockSpec((bm, bk), lambda i, j, ks: (i, ks)),
            pl.BlockSpec((bk, bn), lambda i, j, ks: (ks, j)),
            pl.BlockSpec((1, bn), lambda i, j, ks: (0, j)),
        ], pl.BlockSpec((bm, bn), lambda i, j, ks: (i, j))),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        [pltpu.VMEM((bm, bn), jnp.int32)],
        interpret,
    )
    return call(config_operand(config, n // bn),
                jnp.asarray(x_scale, jnp.float32).reshape(1),
                x.astype(jnp.float32), w_q, w_scale_row)
