"""Pure-jnp oracle for the approx-MAC kernel.

Delegates to repro.core.approx_matmul.approx_matmul_operand — the
TPU-adaptation semantics (operand truncation, depth split ceil-on-B,
gate, round-to-nearest for ROUND/COMP modes) are defined exactly once in
core and reused here, so the kernel is tested against the same function
the model layers use.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.approx_matmul import approx_matmul_operand


def approx_mac_matmul_ref(a, b, config: int = 0):
    """a: (M, K) int8, b: (K, N) int8 -> (M, N) int32."""
    return approx_matmul_operand(a, b, config,
                                 preferred_element_type=jnp.int32)
