"""Pure-jnp oracles for the approx-MAC kernels.

Delegates to repro.core.approx_matmul — the TPU-adaptation semantics
(operand truncation, depth split ceil-on-B, gate, round-to-nearest for
ROUND/COMP modes) are defined exactly once in core and reused here, so
the kernels are tested against the same functions the model layers use.

``approx_mac_grouped_ref`` is the blocked grouped reference for the
expert-bank kernel (DESIGN.md §4): a plain Python loop of per-expert
blocked operand matmuls on the SHARED per-tensor activation scale, with
per-expert per-column weight scales and ragged valid-row masking — the
semantics the single-pallas_call grouped kernel must reproduce bit-for-
bit, composed only from core ops (none of the kernel's own plumbing).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.approx_matmul import (approx_matmul_operand,
                                      approx_matmul_operand_blocked)
from repro.core.quantization import quantize


def approx_mac_matmul_ref(a, b, config: int = 0):
    """a: (M, K) int8, b: (K, N) int8 -> (M, N) int32."""
    return approx_matmul_operand(a, b, config,
                                 preferred_element_type=jnp.int32)


def approx_mac_grouped_ref(x, w_q, w_scale, cfg_blocks, group_rows=None,
                           block_n: int = 128):
    """Blocked grouped reference: (E, M, K) f32 x (E, K, N) int8 bank.

    cfg_blocks: (E, n_blocks) config indices — expert e's output columns
    [i*block_n, (i+1)*block_n) run under cfg_blocks[e][i] (pass
    n_blocks == 1 rows for uniform per-expert configs).  group_rows:
    optional (E,) valid-row counts; rows past the count are zeroed and
    excluded from the shared activation scale.  Returns (E, M, N) f32.
    """
    e, m, _ = x.shape
    x = x.astype(jnp.float32)
    if group_rows is not None:
        valid = jnp.arange(m)[None, :, None] \
            < jnp.asarray(group_rows)[:, None, None]
        x = jnp.where(valid, x, 0.0)
    x_qt = quantize(x)                       # ONE shared per-tensor scale
    w_scale = jnp.asarray(w_scale, jnp.float32)
    outs = []
    for i in range(e):
        n = w_q[i].shape[-1]
        cfg_row = cfg_blocks[i]
        if len(cfg_row) == 1:
            acc = approx_matmul_operand(x_qt.values[i], w_q[i], cfg_row[0])
        else:
            acc = approx_matmul_operand_blocked(x_qt.values[i], w_q[i],
                                                cfg_row, block_n)
        # combined scale rounded once — the shared rescale convention
        # (core.approx_matmul.approx_dense)
        outs.append(acc.astype(jnp.float32)
                    * (x_qt.scale * w_scale[i][None, :]))
    return jnp.stack(outs)
