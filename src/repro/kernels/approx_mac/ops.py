"""Jit-ready wrapper around the approx-MAC Pallas kernel.

Handles padding to tile multiples, batching (leading dims flattened into
M), dtype checks, and the interpret switch (CPU validation).  The f32
scale handling (dynamic activation quantization) mirrors
core.approx_matmul.approx_dense so models can switch `use_pallas` on
without numeric drift.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .approx_mac import approx_mac_matmul


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _approx_mac_jit(a, b, config, *, bm, bn, bk, interpret):
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    lead = a.shape[:-2]
    m, k = a.shape[-2:]
    n = b.shape[-1]
    a2 = a.reshape((-1, k)) if lead else a
    m_flat = a2.shape[0]
    a2 = _pad_to(_pad_to(a2, bm, 0), bk, 1)
    b2 = _pad_to(_pad_to(b, bk, 0), bn, 1)
    out = approx_mac_matmul(a2, b2, config, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    out = out[:m_flat, :n]
    return out.reshape(lead + (m, n)) if lead else out


def approx_mac(a, b, config=0, *, bm: int = 128, bn: int = 128,
               bk: int = 256, interpret: bool = False):
    """a: (..., M, K) int8; b: (K, N) int8 -> (..., M, N) int32.

    `config` is a TRACED int32 argument of the jitted wrapper (it was a
    static argname before PR 1): sweeping all 32 error configs reuses one
    compiled executable per shape — the runtime power knob.
    """
    return _approx_mac_jit(a, b, jnp.asarray(config, jnp.int32),
                           bm=bm, bn=bn, bk=bk, interpret=interpret)


def approx_dense_pallas(x, w_q, w_scale, config: int = 0, *,
                        interpret: bool = False,
                        compute_dtype=jnp.bfloat16):
    """Float-facing layer op on the kernel path: dynamic per-tensor int8
    activation quantization -> kernel -> f32 rescale."""
    from repro.core.quantization import quantize
    x_qt = quantize(x.astype(jnp.float32))
    acc = approx_mac(x_qt.values, w_q, config, interpret=interpret)
    return (acc.astype(jnp.float32) * x_qt.scale * w_scale
            ).astype(compute_dtype)
