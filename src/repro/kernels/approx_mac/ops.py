"""Jit-ready wrappers around the approx-MAC Pallas kernels.

Handles padding to tile multiples, batching (leading dims flattened into
M), dtype checks, and the interpret switch (CPU validation).

``approx_dense_pallas`` is the float-facing layer op on the kernel path.
With ``fused=True`` (the default, the production path) the dynamic int8
activation quantization and the f32 rescale epilogue run INSIDE the
kernel (one pallas_call; the only extra HBM traffic beyond reading x/w
and writing y is one abs-max reduction over x producing a scalar).  With
``fused=False`` it reproduces the PR-1 three-pass pipeline (quantize ->
kernel -> rescale, two extra HBM round-trips) — kept for the
fused-vs-unfused A/B in benchmarks.

Both accept per-N-column-block config vectors (the per-neuron knob); see
``approx_mac.config_operand`` for the accepted config forms.
``approx_dense_grouped_pallas`` is the grouped-expert twin (DESIGN.md
§4): E GEMMs against a stacked (E, K, N) QTensor bank in ONE
pallas_call, per-expert(-per-block) configs and ragged/empty expert
slices included.  ``autotune_block_shapes`` sweeps (bm, bn, bk)
candidates for a GEMM shape and returns the measured ranking
(BENCH_pallas_path.json).
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantization import (QMAX, QTensor, compute_scale,
                                     expand_left)

from .approx_mac import (approx_mac_fused_matmul, approx_mac_grouped_matmul,
                         approx_mac_matmul)


def default_interpret() -> bool:
    """True when the Pallas kernels must run in interpret mode (no TPU)."""
    return jax.default_backend() != "tpu"


_MRED_RANK_DEV: list = []
_ERROR_RANK_DEV: list = []


def _mred_table_dev():
    """core.error_metrics.mred_table as a device constant (one upload
    per process) — the error ranking for conservative group collapse."""
    from repro.core.approx_matmul import device_constant
    from repro.core.error_metrics import mred_table
    return device_constant(_MRED_RANK_DEV, mred_table)


def _error_rank_dev():
    """Per-config integer error rank (power_model.error_rank — THE
    shared (measured MRED, config index) total order) as a device
    constant.  A total order — unlike the raw MRED table it has no
    ties, so argmin over gathered ranks is deterministic and breaks
    MRED ties toward the lower config index, exactly like the engine
    pool join."""
    from repro.core.approx_matmul import device_constant

    def build():
        from repro.core.power_model import error_rank
        return error_rank().astype("int32")

    return device_constant(_ERROR_RANK_DEV, build)


def collapse_expert_cfg(config):
    """(E, g) per-expert-per-group config -> (g,) per-group vector for a
    GEMM with no expert axis (attention/MLP denses of a MoE model whose
    engine config carries an expert dimension): per group, the
    lowest-measured-MRED config across the experts — the same
    never-exceed-requested-error rule as the engine's pool join and the
    straddling-block collapse.  Traced-gather only: zero retraces."""
    cfg = jnp.asarray(config, jnp.int32)
    assert cfg.ndim == 2, cfg.shape
    idx = jnp.argmin(_error_rank_dev()[cfg], axis=0)
    return jnp.take_along_axis(cfg, idx[None, :], axis=0)[0]


def _expand_group_vector(config, n_logical: int, bn: int, n_blocks: int):
    """Map a (g,) neuron-group config vector onto the kernel's
    (n_blocks,) N-block grid using the LOGICAL output width.

    Neuron group j owns logical columns [j*n/g, (j+1)*n/g).  A kernel
    block whose bn columns fall inside one group takes that group's
    config; a block that straddles a group boundary — or a GEMM too
    narrow to resolve all groups — runs the lowest-measured-MRED config
    among the groups it covers (conservative collapse, the same
    never-exceed-requested-error rule as the engine's pool join).
    Static block spans + traced gathers: zero retraces across sweeps.
    """
    g = config.shape[0]
    if g == n_blocks and n_logical % bn == 0:
        # group spans == block spans exactly: per-block vector as-is
        return config
    rank = _mred_table_dev()
    rows = []
    for i in range(n_blocks):
        lo = min(i * bn, n_logical - 1) * g // n_logical
        hi = min((i + 1) * bn - 1, n_logical - 1) * g // n_logical
        cand = config[lo:hi + 1]
        rows.append(cand[jnp.argmin(rank[cand])])
    return jnp.stack(rows)


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _approx_mac_jit(a, b, config, *, bm, bn, bk, interpret):
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    lead = a.shape[:-2]
    m, k = a.shape[-2:]
    n = b.shape[-1]
    a2 = a.reshape((-1, k)) if lead else a
    m_flat = a2.shape[0]
    a2 = _pad_to(_pad_to(a2, bm, 0), bk, 1)
    b2 = _pad_to(_pad_to(b, bk, 0), bn, 1)
    if config.ndim == 1:
        config = _expand_group_vector(config, n, bn, b2.shape[1] // bn)
    out = approx_mac_matmul(a2, b2, config, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    out = out[:m_flat, :n]
    return out.reshape(lead + (m, n)) if lead else out


def approx_mac(a, b, config=0, *, bm: int = 128, bn: int = 128,
               bk: int = 256, interpret: bool = False):
    """a: (..., M, K) int8; b: (K, N) int8 -> (..., M, N) int32.

    `config` is a TRACED int32 argument of the jitted wrapper: sweeping
    all 32 error configs — uniform scalars or per-block vectors of a
    fixed length — reuses one compiled executable per shape.  A (g,)
    vector assigns neuron group j to logical columns [j*N/g, (j+1)*N/g)
    at bn-column block resolution; blocks straddling a group boundary
    (or GEMMs too narrow to resolve all groups) collapse to the
    lowest-measured-MRED config among their groups
    (_expand_group_vector).
    """
    return _approx_mac_jit(a, b, jnp.asarray(config, jnp.int32),
                           bm=bm, bn=bn, bk=bk, interpret=interpret)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _approx_dense_fused_jit(x, w_q, w_scale, config, x_scale, *, bm, bn,
                            bk, interpret):
    assert w_q.dtype == jnp.int8
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w_q.shape[-1]
    x2 = x.astype(jnp.float32).reshape((-1, k))
    m_flat = x2.shape[0]
    # COMBINED dequant scale, rounded once here: the kernel epilogue is
    # then a single multiply with no association freedom (XLA regroups
    # (acc*xs)*ws chains; the single-product form is bit-stable)
    w_row = x_scale * jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(1, -1), (1, n))
    x2 = _pad_to(_pad_to(x2, bm, 0), bk, 1)
    w2 = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    w_row = _pad_to(w_row, bn, 1)
    if config.ndim == 1:
        config = _expand_group_vector(config, n, bn, w2.shape[1] // bn)
    out = approx_mac_fused_matmul(x2, w2, w_row, x_scale, config,
                                  bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m_flat, :n].reshape(lead + (n,))


def approx_dense_pallas(x, w_q, w_scale=None, config=0, *,
                        fused: bool = True,
                        bm: int = 128, bn: int = 128, bk: int = 256,
                        interpret: bool = False,
                        compute_dtype=jnp.bfloat16):
    """Float-facing layer op on the kernel path.

    x: (..., K) float activations; w_q: (K, N) int8 (or a QTensor, in
    which case w_scale is taken from it); w_scale: f32 scalar or (N,)
    per-channel vector.  Returns (..., N) `compute_dtype`, bit-identical
    (interpret mode) to core.approx_matmul.approx_dense at every config,
    including per-block config vectors.
    """
    if isinstance(w_q, QTensor):
        assert w_scale is None
        w_q, w_scale = w_q.values, w_q.scale
    config = jnp.asarray(config, jnp.int32)
    if fused:
        # the per-tensor dynamic activation scale (the ONE pre-pass any
        # dynamic quantization needs) is computed HERE, in the caller's
        # compilation context, not inside the inner jit: XLA strength-
        # reduces the constant /127 division differently in compiled
        # programs vs eager dispatch (reciprocal multiply, 1-ulp off),
        # so the scale must come from the same context as any reference
        # path it is compared against
        x_scale = compute_scale(x.astype(jnp.float32))
        y = _approx_dense_fused_jit(x, w_q, w_scale, config, x_scale,
                                    bm=bm, bn=bn, bk=bk,
                                    interpret=interpret)
        return y.astype(compute_dtype)
    # unfused (PR-1) pipeline: quantize -> int kernel -> rescale, with
    # the int8 activations and int32 accumulator round-tripping HBM
    from repro.core.quantization import quantize
    x_qt = quantize(x.astype(jnp.float32))
    acc = approx_mac(x_qt.values, w_q, config, bm=bm, bn=bn, bk=bk,
                     interpret=interpret)
    w_scale = jnp.asarray(w_scale, jnp.float32)
    return (acc.astype(jnp.float32)
            * expand_left(x_qt.scale * w_scale, acc.ndim)
            ).astype(compute_dtype)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _approx_grouped_fused_jit(x, w_q, w_scale, config, group_rows,
                              x_scale, *, bm, bn, bk, interpret):
    assert w_q.dtype == jnp.int8
    e, m, k = x.shape
    n = w_q.shape[-1]
    # auto-shrink blocks to the hardware-granularity-rounded dims: a
    # per-expert slice smaller than the tile would otherwise pad every
    # expert's quantize + MAC up to full (bm, bk) tiles — pure waste, on
    # TPU (DMA + MXU occupancy) and in interpret mode alike.  Results
    # are tiling-invariant, and bn can only shrink when the GEMM has a
    # single N-block, so neuron-group semantics are unchanged.
    bm = min(bm, -(-m // 8) * 8)
    bk = min(bk, -(-k // 128) * 128)
    bn = min(bn, -(-n // 128) * 128)
    x2 = _pad_to(_pad_to(x.astype(jnp.float32), bm, 1), bk, 2)
    w2 = _pad_to(_pad_to(w_q, bk, 1), bn, 2)
    # combined dequant scale, rounded once (see _approx_dense_fused_jit)
    ws = _pad_to(x_scale * jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(
            (e, -1) if jnp.ndim(w_scale) >= 1 else (1, 1)), (e, n)), bn, 1)
    n_blocks = w2.shape[2] // bn
    if config.ndim == 2:
        # per-expert neuron-GROUP vectors: expand each expert's row onto
        # the block grid with the same conservative lowest-MRED collapse
        # as the dense path (logical width n, not the padded width;
        # _expand_group_vector's fast path keeps exact per-block rows)
        config = jax.vmap(
            lambda c: _expand_group_vector(c, n, bn, n_blocks))(config)
    out = approx_mac_grouped_matmul(x2, w2, ws, x_scale, group_rows,
                                    config, bm=bm, bn=bn, bk=bk,
                                    interpret=interpret)
    return out[:, :m, :n]


def approx_dense_grouped_pallas(x, w_q, w_scale=None, config=0,
                                group_rows=None, *,
                                bm: int = 128, bn: int = 128, bk: int = 256,
                                interpret: bool = False,
                                compute_dtype=jnp.bfloat16):
    """Grouped-expert float-facing op: E approx GEMMs, ONE pallas_call.

    x: (E, M, K) float per-expert activation slices; w_q: stacked
    (E, K, N) int8 bank (or a bank QTensor with (E, N) per-expert
    per-column scales — see transformer.quantize_lm_params); config: a
    scalar, an (E,) per-expert vector, or an (E, g) per-expert
    neuron-group matrix (g == N-blocks for exact per-block control);
    group_rows: optional (E,) int32 valid-row counts — rows at index >=
    group_rows[e] are treated as absent (zeroed in the output, excluded
    from the shared activation scale), and m-blocks past the count skip
    their MXU work in-kernel.  Returns (E, M, N) `compute_dtype`,
    bit-identical (interpret mode) to per-expert approx_dense /
    approx_dense_pallas calls on the shared per-tensor activation scale.

    `config` and `group_rows` are traced arguments of one jitted
    wrapper: sweeping per-expert configs or raggedness retraces nothing.
    """
    if isinstance(w_q, QTensor):
        assert w_scale is None
        w_q, w_scale = w_q.values, w_q.scale
    e, m, _ = x.shape
    config = jnp.asarray(config, jnp.int32)
    x = x.astype(jnp.float32)
    if group_rows is None:
        rows = jnp.full((e,), m, jnp.int32)
    else:
        # zero rows beyond each expert's valid count BEFORE the shared
        # abs-max so ragged callers get exactly the ref semantics
        # (invalid rows contribute nothing, not even to the scale)
        rows = jnp.asarray(group_rows, jnp.int32)
        valid = jnp.arange(m)[None, :, None] < rows[:, None, None]
        x = jnp.where(valid, x, 0.0)
    # shared per-tensor activation scale, computed in the CALLER's
    # compilation context (identical to quantize()-ing the whole
    # dispatch buffer where the comparison path does it — see the note
    # in approx_dense_pallas on XLA's constant-division rewrite)
    x_scale = compute_scale(x)
    y = _approx_grouped_fused_jit(x, w_q, w_scale, config, rows, x_scale,
                                  bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y.astype(compute_dtype)


DEFAULT_BLOCK_CANDIDATES = (
    (128, 128, 256),   # default: MXU-aligned, 128 KiB working set
    (128, 128, 128),
    (256, 128, 256),
    (128, 256, 256),
    (256, 256, 256),
    (512, 128, 512),
)


def autotune_block_shapes(m: int, k: int, n: int, *, config=8,
                          candidates=None, fused: bool = True,
                          interpret: bool | None = None,
                          iters: int = 5, seed: int = 0):
    """Measure the fused approx-dense over (bm, bn, bk) candidates for a
    GEMM shape; returns a list of {"bm","bn","bk","us"} dicts sorted
    fastest-first (entry 0 is the pick).

    On TPU this is the real autotune; in interpret mode (CPU CI) the
    ranking is not meaningful for TPU but exercises the whole sweep
    machinery and feeds BENCH_pallas_path.json.
    """
    import numpy as np
    interpret = default_interpret() if interpret is None else interpret
    candidates = list(candidates or DEFAULT_BLOCK_CANDIDATES)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w_q = jnp.asarray(rng.integers(-QMAX, QMAX + 1, (k, n)), jnp.int8)
    w_scale = jnp.asarray(rng.random(n) * 0.02 + 1e-3, jnp.float32)
    results = []
    for bm, bn, bk in candidates:
        def run():
            return approx_dense_pallas(x, w_q, w_scale, config,
                                       fused=fused, bm=bm, bn=bn, bk=bk,
                                       interpret=interpret)
        try:
            jax.block_until_ready(run())                    # compile
            times = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(run())
                times.append(time.perf_counter() - t0)
            results.append({"bm": bm, "bn": bn, "bk": bk,
                            "us": float(np.median(times) * 1e6)})
        except Exception as e:   # a candidate may exceed VMEM on TPU
            results.append({"bm": bm, "bn": bn, "bk": bk,
                            "error": f"{type(e).__name__}: {e}"})
    ok = [r for r in results if "us" in r]
    ok.sort(key=lambda r: r["us"])
    return ok + [r for r in results if "us" not in r]
