"""Pallas TPU kernel: paged gather-attention for single-token decode.

Decode attention against a paged KV cache (DESIGN.md §11): K/V live in a
(num_blocks, block_size, KV, hd) pool and each batch row reads its keys
through a (pages,) slice of the block table.  The table and per-row
cache lengths arrive as *scalar-prefetched* operands — the K/V BlockSpec
index maps dereference ``bt_ref`` to pick the physical block for each
(row, page) grid step, so the kernel streams exactly the pages a row
owns and never materialises the gathered (B, P*bs, KV, hd) view the XLA
path builds.

Grid: (batch*heads, pages) with the page dimension innermost
("arbitrary") so the online-softmax m/l/acc carries live across pages.
Pages past ``ceil(len/bs)`` still iterate but are fully masked —
block-skipping via a per-row page count is the same documented perf
follow-up as flash_attention's masked KV blocks.  GQA indexes the KV
head as q_head // group in the index maps, like flash_attention.

Environments whose pallas build lacks ``PrefetchScalarGridSpec`` (the
index maps *need* the table ref, so approx_mac's plain-SMEM fallback
cannot express the gather) fall back to the XLA reference — numerically
identical masking, one gathered dot instead of a page stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams
from repro.nn.attention import decode_attention

NEG_INF = -1.0e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, logit_cap, bs, pages, h):
    bh = pl.program_id(0)
    pi = pl.program_id(1)
    b = bh // h

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (1, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bs, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap > 0.0:
        s = jnp.tanh(s / logit_cap) * logit_cap
    key_pos = pi * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    mask = key_pos < len_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # fully-masked page: s == m_new == NEG_INF would give exp(0) = 1 —
    # force masked probabilities to exactly zero.
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pi == pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _pad_hd(x, mult: int = 128):
    pad = (-x.shape[-1]) % mult
    if pad == 0:
        return x
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, width)


def paged_attention_reference(q, k_pool, v_pool, tables, cache_len, *,
                              logit_cap: float = 0.0,
                              scale: float | None = None):
    """XLA reference: gather the table view, run stock decode attention.

    q: (B, 1, H, hd); pools: (NB, bs, KV, hd); tables: (B, P) int32;
    cache_len: (B,) int32 valid keys per row (current token included).
    """
    b = q.shape[0]
    kv, hd = k_pool.shape[2], k_pool.shape[3]
    kc = jnp.reshape(k_pool[tables], (b, -1, kv, hd))
    vc = jnp.reshape(v_pool[tables], (b, -1, kv, hd))
    return decode_attention(q, kc, vc, cache_len, window=0,
                            logit_cap=logit_cap, scale=scale)


def paged_decode_attention(q, k_pool, v_pool, tables, cache_len, *,
                           logit_cap: float = 0.0,
                           scale: float | None = None,
                           interpret: bool = False):
    """Same contract as ``paged_attention_reference``, via the kernel."""
    if not hasattr(pltpu, "PrefetchScalarGridSpec"):
        return paged_attention_reference(q, k_pool, v_pool, tables,
                                         cache_len, logit_cap=logit_cap,
                                         scale=scale)
    b, sq, h, hd = q.shape
    assert sq == 1, "paged decode kernel is single-token"
    kv = k_pool.shape[2]
    assert h % kv == 0
    group = h // kv
    bs = k_pool.shape[1]
    pages = tables.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    qp = _pad_hd(q[:, 0])                                 # (B, H, hd')
    kp = _pad_hd(k_pool)
    vp = _pad_hd(v_pool)
    hdp = qp.shape[-1]
    kernel = functools.partial(_kernel, scale=scale, logit_cap=logit_cap,
                               bs=bs, pages=pages, h=h)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * h, pages),
        in_specs=[
            pl.BlockSpec((1, 1, hdp),
                         lambda bh, pi, bt, sl: (bh // h, bh % h, 0)),
            pl.BlockSpec((1, bs, 1, hdp),
                         lambda bh, pi, bt, sl:
                         (bt[bh // h, pi], 0, (bh % h) // group, 0)),
            pl.BlockSpec((1, bs, 1, hdp),
                         lambda bh, pi, bt, sl:
                         (bt[bh // h, pi], 0, (bh % h) // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hdp),
                               lambda bh, pi, bt, sl: (bh // h, bh % h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hdp), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hdp), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(cache_len, jnp.int32),
      qp, kp, vp)
    return out[..., :hd][:, None]                         # (B, 1, H, hd)
