"""Jit wrapper for the flash-attention kernel: layout adaptation
(B,S,H,hd model layout <-> B,H,S,hd kernel layout), head-dim padding to
128 (h2o-danube hd=120), sequence padding to block multiples.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


def _pad_axis(x, mult, axis):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap",
                                   "scale", "bq", "bk", "interpret"))
def flash_attn(q, k, v, *, causal: bool = True, window: int = 0,
               logit_cap: float = 0.0, scale: float | None = None,
               bq: int = 256, bk: int = 256, interpret: bool = False):
    """Model layout: q (B, Sq, H, hd); k,v (B, Skv, KV, hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale   # scale on TRUE hd
    bq_eff = min(bq, max(sq, 8))
    bk_eff = min(bk, max(skv, 8))
    qt = _pad_axis(_pad_axis(q.transpose(0, 2, 1, 3), 128, 3), bq_eff, 2)
    kt = _pad_axis(_pad_axis(k.transpose(0, 2, 1, 3), 128, 3), bk_eff, 2)
    vt = _pad_axis(_pad_axis(v.transpose(0, 2, 1, 3), 128, 3), bk_eff, 2)
    # padded kv rows are masked inside the kernel via true_skv
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          logit_cap=logit_cap, scale=scale,
                          bq=bq_eff, bk=bk_eff, interpret=interpret,
                          true_sq=sq, true_skv=skv)
    out = out[:, :, :sq, :hd].transpose(0, 2, 1, 3)
    return out.astype(q.dtype)
