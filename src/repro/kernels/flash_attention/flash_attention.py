"""Pallas TPU kernel: flash attention with logit softcap + sliding window.

Covers the attention variants the assigned archs need (gemma2 local+
global with softcap, danube SWA, plain GQA): online-softmax over KV
blocks with m/l/acc carries in VMEM scratch, fp32 accumulation.

Grid: (batch*q_heads, Sq/bq, Skv/bk) with the KV dimension innermost
("arbitrary") so the carries live across kv steps.  GQA is handled by
indexing the KV head = q_head // group_size in the BlockSpec index maps
(no materialized head repetition).  Causal/window masks are applied
per-block; fully-masked blocks still iterate but contribute zeros — the
block-skipping refinement (shrinking the kv grid per q block) is a
documented perf follow-up, not a correctness issue.

head_dim is padded to a multiple of 128 by ops.py (danube hd=120).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, logit_cap, causal, window, bq, bk, kv_steps, sq, skv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap > 0.0:
        s = jnp.tanh(s / logit_cap) * logit_cap
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (skv - sq)                                  # align decode offsets
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < skv          # true (pre-padding) kv length
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # fully-masked block: s == m_new == NEG_INF would give exp(0)=1 —
    # force masked probabilities to exactly zero.
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, scale: float | None = None,
                    bq: int = 256, bk: int = 256, interpret: bool = False,
                    true_sq: int | None = None, true_skv: int | None = None):
    """q: (B, H, Sq, hd); k,v: (B, KV, Skv, hd) -> (B, H, Sq, hd).

    hd must be a multiple of 128 and Sq/Skv multiples of bq/bk (ops.py
    pads; true_sq/true_skv are the pre-padding lengths for masking).
    GQA via H = g * KV."""
    b, h, sq, hd = q.shape
    _, kv, skv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    scale = hd ** -0.5 if scale is None else scale
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    kv_steps = skv // bk
    kernel = functools.partial(
        _kernel, scale=scale, logit_cap=logit_cap, causal=causal,
        window=window, bq=bq, bk=bk, kv_steps=kv_steps,
        sq=true_sq or sq, skv=true_skv or skv)
    return pl.pallas_call(
        kernel,
        grid=(b * h, sq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda bh, qi, ki: (bh // h, bh % h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bh, qi, ki: (bh // h, (bh % h) // group,
                                             ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bh, qi, ki: (bh // h, (bh % h) // group,
                                             ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bh, qi, ki: (bh // h, bh % h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
