"""Attention: GQA/MQA/MHA with chunked (memory-bounded) softmax, sliding
windows, logit soft-capping, decay biases (for mLSTM), and decode paths.

Key implementation choice for 32k+ sequences on 16 GB chips: never
materialize the full (S, S) score matrix.  ``chunked_attention`` loops
over query chunks with ``jax.lax.map``; each chunk attends to either the
full key range (global) or a dynamically-sliced window (local), so peak
memory is O(S * q_chunk) [global] or O(w * q_chunk) [local] per head.
On TPU the Pallas flash kernel (kernels/flash_attention) replaces this
XLA path when `use_pallas` is set; both are validated against
``ref_attention``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .layers import softcap as _softcap

NEG_INF = -2.0e38


def _repeat_kv(k, n_rep: int):
    """(B, S, kv, hd) -> (B, S, kv*n_rep, hd) by head repetition."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                            ).reshape(b, s, kv * n_rep, hd)


# ---------------------------------------------------------------------------
# reference (oracle) attention — small shapes only
# ---------------------------------------------------------------------------

def ref_attention(q, k, v, *, causal: bool = True, window: int = 0,
                  logit_cap: float = 0.0, scale: float | None = None,
                  bias=None):
    """q: (B, Sq, H, hd); k,v: (B, Skv, KV, hd).  Returns (B, Sq, H, hd).

    Supports GQA (H multiple of KV), causal masking with `q_offset`
    implied by Skv - Sq (decode-friendly), sliding window, softcap."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    scale = scale if scale is not None else hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_cap > 0:
        scores = _softcap(scores, logit_cap)
    if bias is not None:
        scores = scores + bias
    skv = k.shape[1]
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked attention (memory-bounded XLA path)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      logit_cap: float = 0.0, scale: float | None = None,
                      q_chunk: int = 1024, decay=None, unroll: bool = False):
    """Memory-bounded attention; same semantics as ref_attention.

    decay: optional dict(log_fcum=(B,S,H), log_i=(B,S,H)) adding the
    mLSTM decay bias b_ij = log_fcum_i - log_fcum_j + log_i_j and using
    the mLSTM max(|den|, exp(-m)) normalizer instead of softmax's sum.
    """
    b, s_orig, h, hd = q.shape
    kv_heads = k.shape[2]
    n_rep = h // kv_heads
    scale = scale if scale is not None else hd ** -0.5
    q_chunk = min(q_chunk, s_orig)
    # pad queries to a chunk multiple; padded rows are sliced off at the
    # end.  decay has a q side (log_fcum_i) and a k side (log_fcum_j,
    # log_i_j): only the q side follows the query padding.
    s = ((s_orig + q_chunk - 1) // q_chunk) * q_chunk
    decay_q = decay
    if s != s_orig:
        q = jnp.pad(q, ((0, 0), (0, s - s_orig), (0, 0), (0, 0)))
        if decay is not None:
            decay_q = {kk: jnp.pad(vv, ((0, 0), (0, s - s_orig), (0, 0)))
                       for kk, vv in decay.items()}
    s_kv = k.shape[1]
    n_chunks = s // q_chunk

    use_window = window > 0 and window < s
    if use_window:
        # keys for chunk c live in [c*qc - (window-1), c*qc + qc): pad K/V
        # on the left so every chunk slices a fixed-size [window+qc] range,
        # and on the right by the query padding so the dynamic_slice for
        # the last (padded) chunk never clamps and misaligns positions.
        pad = window
        rpad = s - s_orig
        k_pad = jnp.pad(k, ((0, 0), (pad, rpad), (0, 0), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (pad, rpad), (0, 0), (0, 0)))

    def one_chunk(c):
        q_c = jax.lax.dynamic_slice_in_dim(q, c * q_chunk, q_chunk, axis=1)
        q_idx = c * q_chunk + jnp.arange(q_chunk)
        if use_window:
            k_c = jax.lax.dynamic_slice_in_dim(k_pad, c * q_chunk,
                                               window + q_chunk, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v_pad, c * q_chunk,
                                               window + q_chunk, axis=1)
            k_idx = c * q_chunk - window + jnp.arange(window + q_chunk)
        else:
            k_c, v_c = k, v
            k_idx = jnp.arange(s_kv)
        k_r = _repeat_kv(k_c, n_rep)
        v_r = _repeat_kv(v_c, n_rep)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_c.astype(jnp.float32),
                            k_r.astype(jnp.float32)) * scale
        if logit_cap > 0:
            scores = _softcap(scores, logit_cap)
        # non-causal unwindowed unpadded chunks mask nothing: skip the
        # where() to save a full read+write of the score tensor (the
        # whisper-encoder memory-term iteration, EXPERIMENTS §Perf 8)
        if not causal and window == 0 and s == s_orig:
            m = jnp.max(scores, axis=-1, keepdims=True)
            e = jnp.exp(scores - m)
            den = jnp.sum(e, axis=-1, keepdims=True)
            out = jnp.einsum("bhqk,bkhd->bqhd", e / den,
                             v_r.astype(jnp.float32))
            return out.astype(q.dtype)
        mask = jnp.ones((q_chunk, k_idx.shape[0]), bool)
        mask &= (k_idx[None, :] >= 0) & (k_idx[None, :] < s_kv)
        if causal:
            mask &= k_idx[None, :] <= q_idx[:, None]
        if window > 0:
            mask &= k_idx[None, :] > q_idx[:, None] - window
        if decay is not None:
            # mLSTM parallel form (xLSTM eq. 24-27): the q.k dot product
            # multiplies OUTSIDE the exponential decay gate.
            #   D~_ij = logsig_fcum_i - logsig_fcum_j + log_i_j  (j <= i)
            #   m_i   = max_j D~_ij;  D'_ij = exp(D~_ij - m_i)
            #   C     = (Q K^T / sqrt(d)) * D'
            #   n_i   = max(|sum_j C_ij|, exp(-m_i));  H = C/n @ V
            lf, li = decay["log_fcum"], decay["log_i"]        # (B,S_kv,H)
            lf_q = jax.lax.dynamic_slice_in_dim(
                decay_q["log_fcum"], c * q_chunk, q_chunk, 1)
            if use_window:
                lf_pad = jnp.pad(lf, ((0, 0), (pad, rpad), (0, 0)))
                li_pad = jnp.pad(li, ((0, 0), (pad, rpad), (0, 0)))
                lf_k = jax.lax.dynamic_slice_in_dim(lf_pad, c * q_chunk,
                                                    window + q_chunk, 1)
                li_k = jax.lax.dynamic_slice_in_dim(li_pad, c * q_chunk,
                                                    window + q_chunk, 1)
            else:
                lf_k, li_k = lf, li
            dmat = (lf_q[:, :, None, :].transpose(0, 3, 1, 2)
                    - lf_k[:, None, :, :].transpose(0, 3, 1, 2)
                    + li_k[:, None, :, :].transpose(0, 3, 1, 2))
            dmat = jnp.where(mask[None, None], dmat, NEG_INF)
            m = jnp.max(dmat, axis=-1, keepdims=True)
            m = jnp.maximum(m, -30.0)                        # numeric floor
            cmat = scores * jnp.exp(dmat - m)
            den = jnp.maximum(jnp.abs(jnp.sum(cmat, axis=-1, keepdims=True)),
                              jnp.exp(-m))
            out = jnp.einsum("bhqk,bkhd->bqhd", cmat / den,
                             v_r.astype(jnp.float32))
            return out.astype(q.dtype)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        m = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        den = jnp.sum(e, axis=-1, keepdims=True)
        out = jnp.einsum("bhqk,bkhd->bqhd", e / den, v_r.astype(jnp.float32))
        return out.astype(q.dtype)

    if unroll:
        # python-unrolled chunk loop: used by the dry-run cost probes so
        # XLA's HloCostAnalysis (which counts while bodies once) sees
        # every chunk; numerically identical to the lax.map path.
        out = jnp.stack([one_chunk(jnp.asarray(c)) for c in range(n_chunks)])
    else:
        out = jax.lax.map(one_chunk, jnp.arange(n_chunks))  # (C,B,qc,H,hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return out[:, :s_orig]


# ---------------------------------------------------------------------------
# decode attention (single query position against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     logit_cap: float = 0.0, scale: float | None = None):
    """q: (B, 1, H, hd); caches: (B, S_max, KV, hd); cache_len: scalar or
    (B,) — number of valid cache positions (new token already written).
    Window semantics match chunked_attention (last `window` positions)."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    s_max = k_cache.shape[1]
    k_r = _repeat_kv(k_cache, h // kv)
    v_r = _repeat_kv(v_cache, h // kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k_r.astype(jnp.float32)) * scale    # (B,H,1,S)
    if logit_cap > 0:
        scores = _softcap(scores, logit_cap)
    pos = jnp.arange(s_max)[None, :]
    limit = jnp.asarray(cache_len).reshape(-1, 1)
    valid = pos < limit
    if window > 0:
        valid &= pos >= limit - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v_r.astype(jnp.float32))
    return out.astype(q.dtype)
