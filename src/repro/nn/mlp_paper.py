"""The paper's MLP: 62 -> 30 (hidden, ReLU) -> 10, signed-magnitude 8-bit.

Float training graph + quantized/approximate inference graph.  The
quantized graph follows the paper's datapath semantics exactly:

  per neuron:  acc21 = sum_k approx_mult(x_k, w_k)      (21-bit signed acc)
               acc   = acc21 + bias_aligned
               relu  = max(acc, 0)
               out8  = saturate(acc >> shift)            (clip to [0,127])

Bias alignment: the paper stores 8-bit biases; inside the MAC result
domain the bias must be scaled by (s_x * s_w / s_b)^-1 ... we keep the
standard integer-pipeline choice: bias is quantized directly in the
accumulator scale (s_x*s_w), i.e. b_int = round(b / (s_x*s_w)), which a
real controller would precompute.  `shift` per layer realigns the 21-bit
accumulator to the next layer's 8-bit input domain and is chosen at
quantization time from calibration data (the paper's "saturation
section"; exact shift values are not given in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx_matmul import approx_matmul_lut, approx_matmul_operand
from repro.core.quantization import QMAX, expand_left, quantize_np

N_INPUT, N_HIDDEN, N_OUTPUT = 62, 30, 10


# ---------------------------------------------------------------------------
# float model (training)
# ---------------------------------------------------------------------------

def init_params(rng, n_in: int = N_INPUT, n_hidden: int = N_HIDDEN,
                n_out: int = N_OUTPUT):
    k1, k2 = jax.random.split(rng)
    s1 = np.sqrt(2.0 / n_in)
    s2 = np.sqrt(2.0 / n_hidden)
    return {
        "hidden": {"w": jax.random.normal(k1, (n_in, n_hidden)) * s1,
                   "b": jnp.zeros((n_hidden,))},
        "out": {"w": jax.random.normal(k2, (n_hidden, n_out)) * s2,
                "b": jnp.zeros((n_out,))},
    }


def apply_float(params, x):
    h = jax.nn.relu(x @ params["hidden"]["w"]
                    + expand_left(params["hidden"]["b"], x.ndim))
    return h @ params["out"]["w"] + expand_left(params["out"]["b"], h.ndim)


# ---------------------------------------------------------------------------
# quantized model (paper datapath semantics)
# ---------------------------------------------------------------------------

@dataclass
class QuantizedMLP:
    """Frozen integer parameters + scales, built from trained float params."""
    w1: np.ndarray          # (62, 30) int8
    b1: np.ndarray          # (30,)    int32, accumulator domain
    w2: np.ndarray          # (30, 10) int8
    b2: np.ndarray          # (10,)    int32
    x_scale: float          # input quant scale (images pre-scaled to [0,1])
    s1: float               # w1 scale
    shift1: int             # hidden-layer saturation shift
    h_scale: float          # effective scale of the 8-bit hidden activations
    s2: float               # w2 scale
    meta: dict = field(default_factory=dict)

    @staticmethod
    def from_float(params, calib_x: np.ndarray) -> "QuantizedMLP":
        """Quantize a trained float model; pick saturation shifts from
        calibration activations so the int pipeline tracks the float one."""
        w1f = np.asarray(params["hidden"]["w"], np.float32)
        b1f = np.asarray(params["hidden"]["b"], np.float32)
        w2f = np.asarray(params["out"]["w"], np.float32)
        b2f = np.asarray(params["out"]["b"], np.float32)

        x_scale = float(np.abs(calib_x).max() / QMAX) or 1.0 / QMAX
        w1, s1 = quantize_np(w1f)
        s1 = float(s1)
        acc_scale1 = x_scale * s1
        b1 = np.round(b1f / acc_scale1).astype(np.int32)

        # float hidden activations on calibration data -> choose shift so
        # the 8-bit saturated output covers the observed range.
        xq = np.clip(np.round(calib_x / x_scale), -QMAX, QMAX).astype(np.int32)
        acc = xq @ w1.astype(np.int32) + b1
        acc = np.maximum(acc, 0)
        amax = max(float(acc.max()), 1.0)
        shift1 = max(int(np.ceil(np.log2(amax / QMAX))), 0)
        h_scale = acc_scale1 * (1 << shift1)

        w2, s2 = quantize_np(w2f)
        s2 = float(s2)
        b2 = np.round(b2f / (h_scale * s2)).astype(np.int32)
        return QuantizedMLP(w1=w1, b1=b1, w2=w2, b2=b2, x_scale=x_scale,
                            s1=s1, shift1=shift1, h_scale=h_scale, s2=s2)

    # -- inference ---------------------------------------------------------
    def quantize_input(self, x: np.ndarray) -> np.ndarray:
        return np.clip(np.round(np.asarray(x) / self.x_scale),
                       -QMAX, QMAX).astype(np.int8)

    @staticmethod
    def _layer_configs(config):
        """Normalize `config` to per-layer (hidden, out) configs.

        Accepts a single int (both layers), a length-2 sequence/array of
        per-layer configs, or traced int32 scalars — the runtime knob
        extends down to the paper's own 62-30-10 network."""
        if isinstance(config, (tuple, list)):
            c1, c2 = config
            return c1, c2
        if isinstance(config, (np.ndarray, jax.Array)) \
                and getattr(config, "ndim", 0) == 1:
            return config[0], config[1]
        return config, config

    def apply(self, x_q, config=0, method: str = "lut",
              interpret: bool | None = None):
        """Integer forward pass under error config `config` (jax arrays).

        x_q: (B, 62) int8.  Returns (B, 10) int32 logits (accumulator
        domain of the output layer — argmax semantics identical to the
        hardware's maximum-value circuit).  method: "lut" (bit-exact
        ASIC oracle), "operand" (TPU-native XLA adaptation), or
        "pallas" (the approx-MAC kernel — same operand semantics, run
        through the fused serving kernel; `interpret` defaults to auto:
        interpret mode off-TPU)."""
        if method == "pallas":
            from repro.kernels.approx_mac.ops import (approx_mac,
                                                      default_interpret)
            itp = default_interpret() if interpret is None else interpret
            mm = lambda a, b, c: approx_mac(a, b, c, interpret=itp)
        else:
            mm = (approx_matmul_lut if method == "lut"
                  else approx_matmul_operand)
        c1, c2 = self._layer_configs(config)
        x_q = jnp.asarray(x_q)
        acc1 = mm(x_q, jnp.asarray(self.w1), c1) \
            + expand_left(jnp.asarray(self.b1), x_q.ndim)
        acc1 = jnp.maximum(acc1, 0)                       # ReLU (21-bit domain)
        h = jnp.clip(acc1 >> self.shift1, 0, QMAX).astype(jnp.int8)  # saturate
        acc2 = mm(h, jnp.asarray(self.w2), c2) \
            + expand_left(jnp.asarray(self.b2), h.ndim)
        return acc2

    def predict(self, x: np.ndarray, config=0, method: str = "lut"):
        logits = self.apply(self.quantize_input(x), config, method)
        return np.asarray(jnp.argmax(logits, axis=-1))

    def accuracy(self, x: np.ndarray, y: np.ndarray, config=0,
                 method: str = "lut") -> float:
        return float((self.predict(x, config, method) == np.asarray(y)).mean())

    # accumulator-width check (paper: 21-bit MAC output register)
    def max_abs_accumulator(self, x: np.ndarray, config: int = 0) -> int:
        x_q = self.quantize_input(x)
        acc1 = approx_matmul_lut(jnp.asarray(x_q), jnp.asarray(self.w1), config)
        return int(jnp.max(jnp.abs(acc1)))
