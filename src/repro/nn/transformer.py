"""Composable LM assembly covering all 10 assigned architectures.

One ``ModelConfig`` describes dense / MoE / VLM / enc-dec / SSM / hybrid
variants through a layer `pattern` (cycled over the depth):

  "global"     full causal attention          (all dense/MoE archs)
  "local"      sliding-window causal attention (gemma2, danube3, griffin)
  "recurrent"  Griffin RG-LRU block            (recurrentgemma)
  "mlstm"      xLSTM matrix-memory block
  "slstm"      xLSTM scalar-memory block

Parameters are plain nested dicts.  Layers are grouped by one pattern
period and scanned with ``jax.lax.scan`` (config.scan_layers) so the HLO
stays small at 132 B scale; every init function also returns a parallel
*logical sharding spec* tree (tuples of logical axis names per dim) that
``dist/sharding.py`` maps onto the mesh (TP on "model", FSDP on "data").

Three lowerable entry points per architecture:
  * ``forward``        — full-sequence activations (training / prefill)
  * ``prefill``        — forward + KV/state cache construction
  * ``decode_step``    — one token against the cache

The paper's error-config knob threads through every GEMM via
``approx_cfg`` (0 = exact float path).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import QTensor, expand_left, quantize
from .attention import chunked_attention, decode_attention
from .layers import ACT, dense, dense_init, embed_init, layernorm, rmsnorm, softcap
from .moe import moe_ffn
from .recurrent import (mlstm_block_init, mlstm_parallel, mlstm_step,
                        recurrent_block, recurrent_block_init,
                        slstm_block_init, slstm_scan, slstm_step)

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    pattern: tuple[str, ...] = ("global",)
    window: int = 0                      # sliding window for "local"
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    mlp: str = "swiglu"                  # swiglu | geglu | gelu | none
    act: str = "silu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    query_scale: float | None = None     # None -> head_dim**-0.5
    norm: str = "rms"                    # rms | ln
    post_norm: bool = False              # gemma2 extra post-norms
    embed_scale: bool = False            # gemma multiplies embed by sqrt(d)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    renormalize: bool = True
    moe_groups: int = 1                  # dispatch groups (align with DP shards)
    moe_seq_chunks: int = 1              # sequential MoE sub-chunks (prefill)
    moe_ep: bool = False                 # expert-parallel (E over "model")
                                         # instead of TP on d_ff
    moe_grouped: bool = True             # pallas backend: ONE grouped
                                         # kernel over all experts (False =
                                         # per-expert lax.map A/B path)
    # enc-dec (whisper)
    encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_frame_dim: int = 0               # stub frontend embedding dim == d_model
    max_positions: int = 8192            # learned pos-emb table (ln norm archs)
    # VLM
    vision_prefix_len: int = 0
    # recurrent
    lru_width: int = 0
    mlstm_proj_factor: float = 2.0
    # approx-MAC execution backend for every dense GEMM (DESIGN.md §3):
    # "xla" = operand-truncation ops compiled by XLA; "pallas" = the
    # fused approx-MAC kernel (quantize + truncate + int8 MAC + rescale
    # in one pallas_call, per-N-block config vectors supported).
    # mac_interpret runs the kernel in interpret mode (CPU tests/CI).
    # mac_blocks = the kernel's (bm, bn, bk) tile shape — feed it the
    # winner of kernels.approx_mac.ops.autotune_block_shapes on TPU.
    mac_backend: str = "xla"
    mac_interpret: bool = False
    mac_blocks: tuple[int, int, int] = (128, 128, 256)
    # runtime/execution
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing"        # nothing | dots
    q_chunk: int = 1024
    compute_dtype: Any = jnp.bfloat16
    kv_quant: bool = False               # int8 KV cache
    kv_onehot_write: bool = False        # shard-local cache write (decode
                                         # with a sequence-sharded cache)
    loss_chunks: int = 8                 # chunked vocab CE
    unroll_chunks: bool = False          # dry-run cost-probe mode
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    def remainder_layers(self) -> int:
        return self.n_layers % len(self.pattern)

    def layer_kinds(self) -> list[str]:
        return [self.pattern[i % len(self.pattern)]
                for i in range(self.n_layers)]

    def smoke(self, **over) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        def down(v, lo, q=1):
            return max(lo, int(v) // q)
        base = dict(
            n_layers=max(2 * len(self.pattern), 2),
            d_model=64, n_heads=2,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=32, d_ff=128 if self.d_ff else 0, vocab_size=128,
            window=min(self.window, 16) if self.window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_enc_layers=2 if self.encoder_decoder else 0,
            vision_prefix_len=4 if self.vision_prefix_len else 0,
            lru_width=64 if self.lru_width else 0,
            moe_groups=1, scan_layers=False, remat=False,
            q_chunk=8, loss_chunks=2, max_positions=128,
            compute_dtype=jnp.float32,
        )
        base.update(over)
        return dataclasses.replace(self, **base)


# ---------------------------------------------------------------------------
# per-block init (+ logical sharding specs)
# ---------------------------------------------------------------------------
# logical axes: "fsdp" (zero-3 over data), "tp" (tensor-parallel over
# model), "tp?" (tp if divisible at mapping time else replicated),
# "vocab" (== tp), None (replicated)

def _norm_init(cfg):
    if cfg.norm == "rms":
        return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}, \
               {"scale": (None,)}
    return ({"scale": jnp.ones((cfg.d_model,), jnp.float32),
             "bias": jnp.zeros((cfg.d_model,), jnp.float32)},
            {"scale": (None,), "bias": (None,)})


def _apply_norm(p, x, cfg):
    if cfg.norm == "rms":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def _attn_init(rng, cfg, cross: bool = False):
    ks = jax.random.split(rng, 5)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    std = 1.0 / np.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, h, hd)) * std).astype(jnp.float32),
        "wk": (jax.random.normal(ks[1], (d, kv, hd)) * std).astype(jnp.float32),
        "wv": (jax.random.normal(ks[2], (d, kv, hd)) * std).astype(jnp.float32),
        "wo": (jax.random.normal(ks[3], (h, hd, d)) * std / np.sqrt(cfg.n_layers)
               ).astype(jnp.float32),
    }
    s = {
        "wq": ("fsdp", "tp?", None), "wk": ("fsdp", "tp?", None),
        "wv": ("fsdp", "tp?", None), "wo": ("tp?", None, "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
        s["bq"] = ("tp?", None)
        s["bk"] = ("tp?", None)
        s["bv"] = ("tp?", None)
    return p, s


def _mlp_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.n_experts > 0:
        e = cfg.n_experts
        std_in, std_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
        p = {"router": (jax.random.normal(ks[0], (d, e)) * std_in
                        ).astype(jnp.float32),
             "w_gate": (jax.random.normal(ks[1], (e, d, f)) * std_in
                        ).astype(jnp.float32),
             "w_up": (jax.random.normal(ks[1], (e, d, f)) * std_in
                      ).astype(jnp.float32),
             "w_down": (jax.random.normal(ks[2], (e, f, d)) * std_out
                        ).astype(jnp.float32)}
        if cfg.moe_ep and e % 8 == 0:
            s = {"router": (None, None),
                 "w_gate": ("expert", "fsdp", None),
                 "w_up": ("expert", "fsdp", None),
                 "w_down": ("expert", None, "fsdp")}
        else:
            s = {"router": (None, None),
                 "w_gate": (None, "fsdp", "tp"), "w_up": (None, "fsdp", "tp"),
                 "w_down": (None, "tp", "fsdp")}
        return p, s
    if cfg.mlp == "none" or f == 0:
        return {}, {}
    p = {"w_up": dense_init(ks[0], d, f),
         "w_down": dense_init(ks[1], f, d, scale=1.0 / np.sqrt(f))}
    s = {"w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp")}
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], d, f)
        s["w_gate"] = ("fsdp", "tp")
    return p, s


def _block_init(rng, cfg, kind: str):
    """One layer's params+specs for pattern element `kind`."""
    ks = jax.random.split(rng, 6)
    p, s = {}, {}
    n1, sn1 = _norm_init(cfg)
    p["norm1"], s["norm1"] = n1, sn1
    if kind in ("global", "local"):
        p["attn"], s["attn"] = _attn_init(ks[0], cfg)
        if cfg.encoder_decoder:   # decoder blocks get cross-attn
            p["norm_x"], s["norm_x"] = _norm_init(cfg)
            p["xattn"], s["xattn"] = _attn_init(ks[1], cfg, cross=True)
        n2, sn2 = _norm_init(cfg)
        p["norm2"], s["norm2"] = n2, sn2
        p["mlp"], s["mlp"] = _mlp_init(ks[2], cfg)
        if cfg.post_norm:
            p["post1"], s["post1"] = _norm_init(cfg)
            p["post2"], s["post2"] = _norm_init(cfg)
    elif kind == "recurrent":
        p["rec"] = recurrent_block_init(ks[0], cfg.d_model, cfg.lru_width)
        s["rec"] = {"w_in_rec": ("fsdp", "tp"), "w_in_gate": ("fsdp", "tp"),
                    "conv_w": (None, "tp"), "conv_b": ("tp",),
                    "lru": {"lam": ("tp",), "w_a": (None, "tp"),
                            "b_a": ("tp",), "w_x": (None, "tp"),
                            "b_x": ("tp",)},
                    "w_out": ("tp", "fsdp")}
        n2, sn2 = _norm_init(cfg)
        p["norm2"], s["norm2"] = n2, sn2
        p["mlp"], s["mlp"] = _mlp_init(ks[2], cfg)
    elif kind == "mlstm":
        p["cell"] = mlstm_block_init(ks[0], cfg.d_model, cfg.n_heads,
                                     cfg.mlstm_proj_factor)
        s["cell"] = {k: ("fsdp", "tp?") for k in
                     ("w_up", "w_gate", "w_q", "w_k", "w_v", "w_if")}
        s["cell"]["w_down"] = ("tp?", "fsdp")
        s["cell"]["b_if"] = (None,)
        s["cell"]["ln_scale"] = ("tp?",)
    elif kind == "slstm":
        p["cell"] = slstm_block_init(ks[0], cfg.d_model, cfg.n_heads)
        s["cell"] = {"w": ("fsdp", "tp?"), "r": (None, None, None),
                     "b": (None,), "ln_scale": (None,),
                     "w_up": ("fsdp", "tp?"), "w_gate": ("fsdp", "tp?"),
                     "w_down": ("tp?", "fsdp")}
    else:
        raise ValueError(kind)
    return p, s


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_specs(spec, n):
    """Prepend the scan ("layers") axis to every spec tuple."""
    return jax.tree.map(lambda t: (None,) + tuple(t), spec,
                        is_leaf=lambda t: isinstance(t, tuple))


def init_lm(rng, cfg: ModelConfig):
    """Returns (params, logical_specs)."""
    ks = jax.random.split(rng, 8)
    params: Params = {}
    specs: Params = {}
    params["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model)
    specs["embed"] = ("vocab", "fsdp")
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size)
        specs["lm_head"] = ("fsdp", "vocab")

    def make_blocks(rng, n_layers, pattern, dec=False):
        kinds = [pattern[i % len(pattern)] for i in range(n_layers)]
        npat = len(pattern)
        n_groups, rem = n_layers // npat, n_layers % npat
        rngs = jax.random.split(rng, n_layers)
        bp, bs = {}, {}
        if n_groups:
            groups = []
            gspec = None
            for g in range(n_groups):
                gp = {}
                for j in range(npat):
                    li = g * npat + j
                    p, sp = _block_init(rngs[li], cfg, kinds[li])
                    gp[f"b{j}"] = p
                    if g == 0:
                        gspec = gspec or {}
                        gspec[f"b{j}"] = sp
                groups.append(gp)
            bp["scan"] = _stack(groups)
            bs["scan"] = _stack_specs(gspec, n_groups)
        for r in range(rem):
            li = n_groups * npat + r
            p, sp = _block_init(rngs[li], cfg, kinds[li])
            bp[f"rest{r}"] = p
            bs[f"rest{r}"] = sp
        return bp, bs

    params["blocks"], specs["blocks"] = make_blocks(ks[2], cfg.n_layers,
                                                    cfg.pattern)
    fn, fs = _norm_init(cfg)
    params["final_norm"], specs["final_norm"] = fn, fs

    if cfg.encoder_decoder:
        # encoder: non-causal global attention blocks (no cross-attn)
        enc_cfg = dataclasses.replace(cfg, encoder_decoder=False)
        ep, es = {}, {}
        kinds = ["global"] * cfg.n_enc_layers
        rngs = jax.random.split(ks[3], cfg.n_enc_layers)
        groups = [dict(b0=_block_init(rngs[g], enc_cfg, "global")[0])
                  for g in range(cfg.n_enc_layers)]
        gspec = {"b0": _block_init(rngs[0], enc_cfg, "global")[1]}
        ep["scan"] = _stack(groups)
        es["scan"] = _stack_specs(gspec, cfg.n_enc_layers)
        params["encoder"], specs["encoder"] = ep, es
        en, esn = _norm_init(cfg)
        params["enc_norm"], specs["enc_norm"] = en, esn
        params["enc_pos"] = (jax.random.normal(ks[4], (cfg.max_positions,
                                                       cfg.d_model)) * 0.02
                             ).astype(jnp.float32)
        specs["enc_pos"] = (None, "fsdp")
    if cfg.norm == "ln":   # whisper-style learned positions for the decoder
        params["dec_pos"] = (jax.random.normal(ks[5], (cfg.max_positions,
                                                       cfg.d_model)) * 0.02
                             ).astype(jnp.float32)
        specs["dec_pos"] = (None, "fsdp")
    return params, specs


# ---------------------------------------------------------------------------
# one-time weight quantization (serving)
# ---------------------------------------------------------------------------

def _vmapped_quantize(a, base_ndim: int):
    """Per-channel quantize of the trailing `base_ndim` dims, vmapped
    over any leading (scan-stacked layer) dims.

    CONTRACT: for stacked inputs the result is a *container* QTensor —
    values (L, ..., C) with scale (L, C) — whose aux `axis` refers to
    the UNSTACKED per-layer layout (axis = base_ndim - 1); lax.scan /
    per-layer slicing / QTensor.take reduce each leaf back to the
    per-layer shape, and QTensor.dequantize/reshape understand the
    stacked layout directly (scale.ndim - 1 leading dims are stacked)."""
    f = lambda w: quantize(w, axis=w.ndim - 1)
    for _ in range(a.ndim - base_ndim):
        f = jax.vmap(f)
    return f(a)


# the MLP/MoE-bank weight names quantize_lm_params converts — shared
# with the spec transform so the two cannot drift key-by-key
_QUANT_MLP_KEYS = ("w_up", "w_gate", "w_down")


def _map_quantized_nodes(tree, conv_attn, conv_mlp):
    """The ONE walk over the GEMM-weight nodes the serving path
    quantizes: 'attn'/'xattn' subtrees through `conv_attn`, 'mlp'
    subtrees through `conv_mlp`, every other node untouched, rooted at
    the 'blocks'/'encoder' subtrees.  Both ``quantize_lm_params`` (leaf
    converter: float array -> QTensor) and ``quantize_lm_specs`` (leaf
    converter: spec tuple -> QTensor spec node) run THIS walk, so the
    params tree and its placement-spec tree cannot structurally drift —
    a converted node in one is a converted node in the other."""
    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {}
        for k, v in node.items():
            if k in ("attn", "xattn"):
                out[k] = conv_attn(v)
            elif k == "mlp":
                out[k] = conv_mlp(v)
            else:
                out[k] = walk(v)
        return out

    new = dict(tree)
    for key in ("blocks", "encoder"):
        if key in tree:
            new[key] = walk(tree[key])
    return new


def _qtensor_spec(values_spec):
    """Logical-spec node for one QTensor leaf produced by
    ``_vmapped_quantize``: the node's keys are the CHILD INDICES of
    ``QTensor.tree_flatten`` — 0 = ``values`` (keeps `values_spec`),
    1 = ``scale`` (the stacked leading axes plus the out-channel dim:
    scale shape is ``values.shape[:-2] + (values.shape[-1],)``).
    ``dist.sharding.Mapping.shardings`` walks pytree paths by key, and a
    registered pytree node's children are addressed by flattened index,
    so an int-keyed dict is exactly the addressable spec node."""
    values_spec = tuple(values_spec)
    return {0: values_spec, 1: values_spec[:-2] + (values_spec[-1],)}


def quantize_lm_specs(specs, cfg: ModelConfig):
    """Transform an ``init_lm`` logical-spec tree to match the params
    tree ``quantize_lm_params`` produces, so quantized serving params
    remain PLACEABLE by logical specs (multi-host sharded serving,
    DESIGN.md §8).

    The spec transform mirrors the param transform exactly:

      * attention ``wq``/``wk``/``wv`` collapse (d, H, hd) into the 2D
        GEMM layout (d, H*hd) — the merged output dim inherits the head
        dim's axis (``"tp?"``: H*hd is divisible by the TP size whenever
        H is, and the divisibility re-check at mapping time drops it
        safely when not);
      * ``wo`` collapses (H, hd, d) into (H*hd, d) the same way;
      * MLP / MoE-bank mats keep their layout (the expert axis is just a
        leading stacked dim), so their values spec is unchanged;
      * every quantized leaf becomes a ``{values, scale}`` QTensor node
        (``_qtensor_spec``): the per-output-channel scale is sharded
        like the output dim it scales.

    Leaves ``quantize_lm_params`` leaves float (embed, lm_head, norms,
    router, recurrent cells, biases) pass through untouched."""
    def merge(a, b):
        return a if a is not None else b

    def conv_attn(s):
        out = dict(s)
        for key in ("wq", "wk", "wv"):
            if key in s:
                t = tuple(s[key])
                out[key] = _qtensor_spec(t[:-2] + (merge(t[-2], t[-1]),))
        if "wo" in s:
            t = tuple(s["wo"])
            out["wo"] = _qtensor_spec(t[:-3] + (merge(t[-3], t[-2]),
                                                t[-1]))
        return out

    def conv_mlp(s):
        if not s:
            return s
        out = dict(s)
        for key in _QUANT_MLP_KEYS:
            if key in s:
                out[key] = _qtensor_spec(s[key])
        return out

    return _map_quantized_nodes(specs, conv_attn, conv_mlp)


def quantize_lm_params(params, cfg: ModelConfig):
    """Pre-quantize every GEMM weight that flows through ``dense`` into
    a QTensor ONCE — the serving engine calls this at init so no decode
    step re-runs weight abs-max/round/cast inside the traced graph
    (previously every dense call re-quantized its float weight).

    Attention projections are stored in their 2D GEMM layout
    ((d, H*hd) / (H*hd, d)) with per-output-channel scales — exactly the
    arrays the per-call ``quantize(w, axis=1)`` produced, so numerics are
    unchanged.  Dense-MLP mats quantize per-channel in place.  MoE expert
    mats quantize into stacked (E, in, out) QTensor BANKS with (E, out)
    per-expert per-output-channel scales — the layout the grouped expert
    kernel consumes directly (DESIGN.md §4) and bit-identical to
    ``moe.quantize_expert_bank`` applied per trace, so pre-quantizing
    kills the per-call expert requantize without changing a bit.  The
    router and recurrent cells keep per-call quantization.  Returns a
    new params tree; embed/lm_head/norms stay float.
    """
    def conv_attn(d):
        out = dict(d)
        for key in ("wq", "wk", "wv"):
            if key in d:
                a = d[key]
                lead = a.ndim - 3
                a2 = a.reshape(a.shape[:lead + 1] + (-1,))
                out[key] = _vmapped_quantize(a2, 2)
        if "wo" in d:
            a = d["wo"]
            lead = a.ndim - 3
            a2 = a.reshape(a.shape[:lead] + (-1, a.shape[-1]))
            out["wo"] = _vmapped_quantize(a2, 2)
        return out

    def conv_mlp(d):
        if not d:
            return d
        out = dict(d)
        for key in _QUANT_MLP_KEYS:
            if key in d:
                # expert tensors (E, in, out) vmap into stacked banks
                # with (E, out) scales; dense mats quantize in place —
                # same code path, the expert axis is just one more
                # leading dim
                out[key] = _vmapped_quantize(d[key], 2)
        return out

    return _map_quantized_nodes(params, conv_attn, conv_mlp)


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------

def _dense_kw(cfg) -> dict:
    """dense() kwargs for the model's MAC backend (empty = XLA default)."""
    if cfg is None or cfg.mac_backend == "xla":
        return {}
    return {"backend": cfg.mac_backend, "interpret": cfg.mac_interpret,
            "block_shapes": tuple(cfg.mac_blocks)}


def _proj(x, w, approx_cfg=0, bias=None, cfg=None, heads=None):
    """x: (B,S,d) @ w: (d,H,hd) -> (B,S,H,hd) through the dense knob.

    w is a float (d,H,hd) array, or a pre-quantized QTensor stored in
    its 2D GEMM layout (d, H*hd) (quantize_lm_params) — then `heads`
    supplies H for the output reshape."""
    if isinstance(w, QTensor):
        assert heads is not None, "QTensor projections need heads="
        h, hd = heads, w.values.shape[-1] // heads
        y = dense(x, w, approx_cfg=approx_cfg, **_dense_kw(cfg))
    else:
        d, h, hd = w.shape
        y = dense(x, w.reshape(d, h * hd), approx_cfg=approx_cfg,
                  **_dense_kw(cfg))
    y = y.reshape(x.shape[:-1] + (h, hd))
    if bias is not None:
        y = y + expand_left(bias.astype(y.dtype), y.ndim)
    return y


def _attn_out(y, wo, approx_cfg=0, cfg=None):
    if isinstance(wo, QTensor):
        hhd = wo.values.shape[0]
        return dense(y.reshape(y.shape[:-2] + (hhd,)), wo,
                     approx_cfg=approx_cfg, **_dense_kw(cfg))
    h, hd, d = wo.shape
    return dense(y.reshape(y.shape[:-2] + (h * hd,)), wo.reshape(h * hd, d),
                 approx_cfg=approx_cfg, **_dense_kw(cfg))


def _mlp_apply(p, x, cfg, approx_cfg=0):
    if cfg.n_experts > 0:
        b, s, d = x.shape
        # decode (single position): dropless — a dropped token would halt
        # generation quality; the buffer is tiny at s==1 anyway.
        cf = float(cfg.n_experts) if s == 1 else cfg.capacity_factor
        groups = cfg.moe_groups if (b * s) % cfg.moe_groups == 0 else 1
        y, _ = moe_ffn(x.reshape(b * s, d), p, n_experts=cfg.n_experts,
                       top_k=cfg.top_k, capacity_factor=cf,
                       n_groups=groups, act=cfg.act,
                       renormalize=cfg.renormalize, approx_cfg=approx_cfg,
                       seq_chunks=cfg.moe_seq_chunks if s > 1 else 1,
                       unroll_chunks=cfg.unroll_chunks, ep=cfg.moe_ep,
                       backend=cfg.mac_backend, interpret=cfg.mac_interpret,
                       grouped=cfg.moe_grouped)
        return y.reshape(b, s, d)
    if not p:
        return x
    kw = _dense_kw(cfg)
    act = ACT["gelu" if cfg.mlp == "geglu" else cfg.act] \
        if cfg.mlp in ("swiglu", "geglu") else ACT[cfg.act]
    if "w_gate" in p:
        h = act(dense(x, p["w_gate"], approx_cfg=approx_cfg, **kw)) \
            * dense(x, p["w_up"], approx_cfg=approx_cfg, **kw)
    else:
        h = act(dense(x, p["w_up"], approx_cfg=approx_cfg, **kw))
    return dense(h, p["w_down"], approx_cfg=approx_cfg, **kw)


def _attention_block(p, x, cfg, kind, *, positions, approx_cfg=0,
                     causal=True, enc_out=None):
    from .layers import apply_rope
    res = x
    h = _apply_norm(p["norm1"], x, cfg)
    q = _proj(h, p["attn"]["wq"], approx_cfg, p["attn"].get("bq"), cfg,
              cfg.n_heads)
    k = _proj(h, p["attn"]["wk"], approx_cfg, p["attn"].get("bk"), cfg,
              cfg.n_kv_heads)
    v = _proj(h, p["attn"]["wv"], approx_cfg, p["attn"].get("bv"), cfg,
              cfg.n_kv_heads)
    if cfg.norm == "rms":                      # rope archs
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.window if kind == "local" else 0
    attn = chunked_attention(q, k, v, causal=causal, window=window,
                             logit_cap=cfg.attn_softcap,
                             scale=cfg.query_scale, q_chunk=cfg.q_chunk,
                             unroll=cfg.unroll_chunks)
    y = _attn_out(attn, p["attn"]["wo"], approx_cfg, cfg)
    if cfg.post_norm:
        y = _apply_norm(p["post1"], y, cfg)
    x = res + y
    if enc_out is not None and "xattn" in p:
        res = x
        h = _apply_norm(p["norm_x"], x, cfg)
        q = _proj(h, p["xattn"]["wq"], approx_cfg, cfg=cfg,
                  heads=cfg.n_heads)
        k = _proj(enc_out, p["xattn"]["wk"], approx_cfg, cfg=cfg,
                  heads=cfg.n_kv_heads)
        v = _proj(enc_out, p["xattn"]["wv"], approx_cfg, cfg=cfg,
                  heads=cfg.n_kv_heads)
        attn = chunked_attention(q, k, v, causal=False,
                                 q_chunk=cfg.q_chunk,
                                 unroll=cfg.unroll_chunks)
        x = res + _attn_out(attn, p["xattn"]["wo"], approx_cfg, cfg)
    res = x
    h = _apply_norm(p["norm2"], x, cfg)
    y = _mlp_apply(p["mlp"], h, cfg, approx_cfg)
    if cfg.post_norm:
        y = _apply_norm(p["post2"], y, cfg)
    return res + y


def _apply_block(p, kind, x, cfg, *, positions, approx_cfg=0, causal=True,
                 enc_out=None):
    if kind in ("global", "local"):
        return _attention_block(p, x, cfg, kind, positions=positions,
                                approx_cfg=approx_cfg, causal=causal,
                                enc_out=enc_out)
    if kind == "recurrent":
        res = x
        h = _apply_norm(p["norm1"], x, cfg)
        y, _ = recurrent_block(p["rec"], h, approx_cfg=approx_cfg,
                               dense_kw=_dense_kw(cfg))
        x = res + y
        res = x
        h = _apply_norm(p["norm2"], x, cfg)
        return res + _mlp_apply(p["mlp"], h, cfg, approx_cfg)
    if kind == "mlstm":
        res = x
        h = _apply_norm(p["norm1"], x, cfg)
        return res + mlstm_parallel(p["cell"], h, cfg.n_heads,
                                    approx_cfg=approx_cfg,
                                    q_chunk=cfg.q_chunk,
                                    unroll=cfg.unroll_chunks,
                                    dense_kw=_dense_kw(cfg))
    if kind == "slstm":
        res = x
        h = _apply_norm(p["norm1"], x, cfg)
        y, _ = slstm_scan(p["cell"], h, cfg.n_heads, approx_cfg=approx_cfg,
                          dense_kw=_dense_kw(cfg))
        return res + y
    raise ValueError(kind)


def is_per_layer_cfg(approx_cfg) -> bool:
    """True when approx_cfg is a (n_layers,) per-layer config vector, a
    (n_layers, n_groups) per-layer-per-N-block config matrix, or a
    (n_layers, n_experts, n_groups) per-layer-per-EXPERT config tensor
    (0-d arrays are uniform scalar configs, not vectors)."""
    if isinstance(approx_cfg, (jax.Array, np.ndarray)):
        return approx_cfg.ndim in (1, 2, 3)
    return isinstance(approx_cfg, (list, tuple))


def split_layer_cfgs(approx_cfg, n_scan: int, npat: int):
    """(scan_part (n_groups, npat, ...), rest_part) of a per-layer
    vector/matrix; trailing per-N-block dims ride along unchanged."""
    acfg = jnp.asarray(approx_cfg, jnp.int32)
    scan_part = (acfg[:n_scan].reshape((-1, npat) + acfg.shape[1:])
                 if n_scan else None)
    rest_part = acfg[n_scan:]
    return scan_part, rest_part


def _layer_cfg_plan(blocks, approx_cfg, npat: int):
    """The ONE place the layer->config layout is mapped onto a blocks
    tree: returns (n_groups, acfg_scan, acfg_rest).  acfg parts are None
    for a uniform (scalar) approx_cfg; callers then select per layer
    with `approx_cfg if ac is None else ac[j]` (scan) / `acfg_rest[r]`
    (rest layers).  Shared by _run_blocks, prefill, and decode_step so
    the three paths cannot drift."""
    n_groups = (jax.tree.leaves(blocks["scan"])[0].shape[0]
                if "scan" in blocks else 0)
    if is_per_layer_cfg(approx_cfg):
        acfg_scan, acfg_rest = split_layer_cfgs(approx_cfg,
                                                n_groups * npat, npat)
    else:
        acfg_scan = acfg_rest = None
    return n_groups, acfg_scan, acfg_rest


def _run_blocks(blocks, x, cfg, *, positions, approx_cfg=0, causal=True,
                enc_out=None, pattern=None):
    pattern = pattern or cfg.pattern
    npat = len(pattern)

    from repro.dist.sharding import lsc

    # approx_cfg is a Python int (static), a traced int32 scalar (uniform
    # runtime config), or a (n_layers,) vector (per-layer runtime
    # configs, e.g. a DynamicPowerController allocation).  The vector's
    # scanned prefix rides through lax.scan alongside the layer params.
    n_groups, acfg_scan, acfg_rest = _layer_cfg_plan(blocks, approx_cfg,
                                                     npat)

    def group_body(x, gp, ac):
        for j, kind in enumerate(pattern):
            x = lsc(x, "batch", None, None)
            x = _apply_block(gp[f"b{j}"], kind, x, cfg, positions=positions,
                             approx_cfg=approx_cfg if ac is None else ac[j],
                             causal=causal, enc_out=enc_out)
        return x

    if "scan" in blocks:
        body = group_body
        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(group_body, policy=policy)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(
                lambda c, t: (body(c, t[0], t[1]), None),
                x, (blocks["scan"], acfg_scan))
        else:
            for g in range(n_groups):
                gp = jax.tree.map(lambda a: a[g], blocks["scan"])
                x = body(x, gp,
                         acfg_scan[g] if acfg_scan is not None else None)
    r = 0
    while f"rest{r}" in blocks:
        # rest layers follow n_groups*npat scanned layers, so their kind
        # index reduces to r % npat
        x = _apply_block(blocks[f"rest{r}"], pattern[r % npat], x, cfg,
                         positions=positions,
                         approx_cfg=(approx_cfg if acfg_rest is None
                                     else acfg_rest[r]),
                         causal=causal, enc_out=enc_out)
        r += 1
    return x


# ---------------------------------------------------------------------------
# model-level entry points
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def encode(params, cfg, enc_embeds):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    from repro.dist.sharding import lsc
    enc_embeds = lsc(enc_embeds, "batch", None, None)
    x = enc_embeds.astype(cfg.compute_dtype)
    s = x.shape[1]
    x = x + params["enc_pos"][:s][None].astype(x.dtype)
    positions = jnp.arange(s)[None]
    x = _run_blocks(params["encoder"], x, cfg, positions=positions,
                    causal=False, pattern=("global",))
    return _apply_norm(params["enc_norm"], x, cfg)


def forward(params, cfg: ModelConfig, tokens, *, vision_embeds=None,
            enc_embeds=None, approx_cfg=0):
    """Full-sequence hidden states (B, S_total, d).

    approx_cfg: Python int (static), traced int32 scalar (uniform
    runtime config), a (n_layers,) per-layer config vector, or —
    pallas backend — a (n_layers, n_groups) / (n_layers, n_experts,
    n_groups) matrix (per-layer slices with an expert axis reach MoE
    experts individually; dense GEMMs collapse the expert axis to the
    lowest-measured-MRED config, see layers.dense)."""
    from repro.dist.sharding import lsc
    tokens = lsc(tokens, "batch", None)
    x = embed_tokens(params, cfg, tokens)
    x = lsc(x, "batch", None, None)
    if cfg.vision_prefix_len and vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    if cfg.norm == "ln":   # learned positions (whisper decoder)
        x = x + params["dec_pos"][:x.shape[1]][None].astype(x.dtype)
    enc_out = None
    if cfg.encoder_decoder and enc_embeds is not None:
        enc_out = encode(params, cfg, enc_embeds)
    positions = jnp.arange(x.shape[1])[None]
    x = _run_blocks(params["blocks"], x, cfg, positions=positions,
                    approx_cfg=approx_cfg, causal=True, enc_out=enc_out)
    return _apply_norm(params["final_norm"], x, cfg)


def logits_for(params, cfg, hidden):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.dot(hidden, w.astype(hidden.dtype))
    if cfg.final_softcap > 0:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits


def lm_loss(params, cfg: ModelConfig, batch, *, approx_cfg=0):
    """Chunked-vocab cross entropy.  batch: tokens/labels (+ stubs).
    labels == -1 are masked (vision prefix positions etc.)."""
    hidden = forward(params, cfg, batch["tokens"],
                     vision_embeds=batch.get("vision_embeds"),
                     enc_embeds=batch.get("enc_embeds"),
                     approx_cfg=approx_cfg)
    labels = batch["labels"]
    if cfg.vision_prefix_len and batch.get("vision_embeds") is not None:
        pad = jnp.full(labels.shape[:1] + (cfg.vision_prefix_len,), -1,
                       labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    b, s, d = hidden.shape
    n_chunks = cfg.loss_chunks if s % cfg.loss_chunks == 0 else 1
    hs = hidden.reshape(b, n_chunks, s // n_chunks, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, s // n_chunks).swapaxes(0, 1)

    def chunk_loss(args):
        h, l = args
        logits = logits_for(params, cfg, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(l, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (l >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    losses, counts = jax.lax.map(chunk_loss, (hs, ls))
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               enc_len: int = 0):
    """Cache pytree (+ logical specs) for decode.  Attention layers get
    (B, S, KV, hd) K/V buffers (ring-buffered to `window` for local
    layers); recurrent kinds get their O(1) states."""
    kinds = cfg.layer_kinds()
    npat = len(cfg.pattern)
    n_groups, rem = cfg.n_layers // npat, cfg.n_layers % npat
    kv_dt = jnp.int8 if cfg.kv_quant else cfg.compute_dtype

    def layer_cache(kind):
        if kind in ("global", "local"):
            s = min(cfg.window, max_len) if kind == "local" else max_len
            c = {"k": jnp.zeros((batch_size, s, cfg.n_kv_heads, cfg.head_dim),
                                kv_dt),
                 "v": jnp.zeros((batch_size, s, cfg.n_kv_heads, cfg.head_dim),
                                kv_dt)}
            sp = {"k": ("batch", "kv_seq", "tp?", "kv_hd"),
                  "v": ("batch", "kv_seq", "tp?", "kv_hd")}
            if cfg.kv_quant:
                c["k_s"] = jnp.zeros((batch_size, s, cfg.n_kv_heads),
                                     jnp.float32)
                c["v_s"] = jnp.zeros((batch_size, s, cfg.n_kv_heads),
                                     jnp.float32)
                sp["k_s"] = ("batch", "kv_seq", "tp?")
                sp["v_s"] = ("batch", "kv_seq", "tp?")
            if cfg.encoder_decoder:
                c["xk"] = jnp.zeros((batch_size, enc_len, cfg.n_kv_heads,
                                     cfg.head_dim), cfg.compute_dtype)
                c["xv"] = jnp.zeros_like(c["xk"])
                sp["xk"] = ("batch", None, "tp?", None)
                sp["xv"] = ("batch", None, "tp?", None)
            return c, sp
        if kind == "recurrent":
            kw = 4  # conv width
            c = {"h": jnp.zeros((batch_size, cfg.lru_width), jnp.float32),
                 "conv": jnp.zeros((batch_size, kw - 1, cfg.lru_width),
                                   jnp.float32)}
            sp = {"h": ("batch", "tp"), "conv": ("batch", None, "tp")}
            return c, sp
        if kind == "mlstm":
            d_inner = int(cfg.d_model * cfg.mlstm_proj_factor)
            hd = d_inner // cfg.n_heads
            c = {"C": jnp.zeros((batch_size, cfg.n_heads, hd, hd), jnp.float32),
                 "n": jnp.zeros((batch_size, cfg.n_heads, hd), jnp.float32),
                 "m": jnp.full((batch_size, cfg.n_heads), -30.0, jnp.float32)}
            sp = {"C": ("batch", "tp?", None, None),
                  "n": ("batch", "tp?", None), "m": ("batch", "tp?")}
            return c, sp
        if kind == "slstm":
            z = jnp.zeros((batch_size, cfg.d_model), jnp.float32)
            c = {"h": z, "c": z, "n": z, "m": z - 30.0}
            sp = {k: ("batch", None) for k in "hcnm"}
            return c, sp
        raise ValueError(kind)

    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    cspec: Params = {"pos": ()}
    if n_groups:
        gc, gs = {}, {}
        for j in range(npat):
            c, sp = layer_cache(cfg.pattern[j])
            gc[f"b{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(), c)
            gs[f"b{j}"] = jax.tree.map(
                lambda t: (None,) + tuple(t), sp,
                is_leaf=lambda t: isinstance(t, tuple))
        cache["scan"], cspec["scan"] = gc, gs
    for r in range(rem):
        c, sp = layer_cache(cfg.pattern[r % npat])
        cache[f"rest{r}"], cspec[f"rest{r}"] = c, sp
    return cache, cspec


def _kv_write(cache_layer, kind, k_new, v_new, pos, cfg, window):
    """Write K/V at `pos` (ring-buffered for local).

    kv_onehot_write (single-token writes only): express the update as a
    one-hot masked blend instead of dynamic-update-slice.  On a cache
    whose sequence dim is sharded, DUS at a traced index forces GSPMD to
    all-gather the cache every step; the blend stays shard-local at the
    cost of re-writing the cache (decode is cache-bandwidth-bound anyway
    — §Perf iteration 1)."""
    s_buf = cache_layer["k"].shape[1]
    idx = pos % s_buf
    if cfg.kv_onehot_write and k_new.shape[1] == 1:
        oh = (jnp.arange(s_buf) == idx)[None, :, None, None]

        def blend(buf, val):
            val = val.astype(jnp.float32) if buf.dtype == jnp.int8 else val
            out = jnp.where(oh, val.astype(jnp.float32),
                            buf.astype(jnp.float32))
            return out.astype(buf.dtype)

        cache_layer = dict(cache_layer)
        if cfg.kv_quant:
            def q8(x):
                sc = jnp.max(jnp.abs(x), axis=-1) / 127.0 + 1e-9
                qv = jnp.clip(jnp.round(x / sc[..., None]), -127, 127
                              ).astype(jnp.int8)
                return qv, sc
            kq, ks = q8(k_new.astype(jnp.float32))
            vq, vs = q8(v_new.astype(jnp.float32))
            oh3 = oh[..., 0]
            cache_layer["k"] = jnp.where(oh, kq, cache_layer["k"])
            cache_layer["v"] = jnp.where(oh, vq, cache_layer["v"])
            cache_layer["k_s"] = jnp.where(oh3, ks, cache_layer["k_s"])
            cache_layer["v_s"] = jnp.where(oh3, vs, cache_layer["v_s"])
            return cache_layer
        cache_layer["k"] = blend(cache_layer["k"], k_new)
        cache_layer["v"] = blend(cache_layer["v"], v_new)
        return cache_layer
    if cfg.kv_quant:
        def q8(x):
            s = jnp.max(jnp.abs(x), axis=-1) / 127.0 + 1e-9   # (B,1,KV)
            q = jnp.clip(jnp.round(x / s[..., None]), -127, 127
                         ).astype(jnp.int8)
            return q, s
        kq, ks = q8(k_new.astype(jnp.float32))
        vq, vs = q8(v_new.astype(jnp.float32))
        cache_layer = dict(cache_layer)
        cache_layer["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["k"], kq, idx, axis=1)
        cache_layer["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["v"], vq, idx, axis=1)
        cache_layer["k_s"] = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["k_s"], ks, idx, axis=1)
        cache_layer["v_s"] = jax.lax.dynamic_update_slice_in_dim(
            cache_layer["v_s"], vs, idx, axis=1)
        return cache_layer
    cache_layer = dict(cache_layer)
    cache_layer["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["k"], k_new.astype(cache_layer["k"].dtype), idx, axis=1)
    cache_layer["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache_layer["v"], v_new.astype(cache_layer["v"].dtype), idx, axis=1)
    return cache_layer


def _kv_read(cache_layer, cfg):
    if cfg.kv_quant:
        k = (cache_layer["k"].astype(jnp.float32)
             * cache_layer["k_s"][..., None]).astype(cfg.compute_dtype)
        v = (cache_layer["v"].astype(jnp.float32)
             * cache_layer["v_s"][..., None]).astype(cfg.compute_dtype)
        return k, v
    return cache_layer["k"], cache_layer["v"]


def _decode_block(p, kind, x_t, cl, cfg, pos, *, approx_cfg=0):
    """One layer, one token. x_t: (B,1,d). Returns (x_t, new_cache_layer)."""
    from .layers import apply_rope
    if kind in ("global", "local"):
        res = x_t
        h = _apply_norm(p["norm1"], x_t, cfg)
        q = _proj(h, p["attn"]["wq"], approx_cfg, p["attn"].get("bq"), cfg,
                  cfg.n_heads)
        k = _proj(h, p["attn"]["wk"], approx_cfg, p["attn"].get("bk"), cfg,
                  cfg.n_kv_heads)
        v = _proj(h, p["attn"]["wv"], approx_cfg, p["attn"].get("bv"), cfg,
                  cfg.n_kv_heads)
        if cfg.norm == "rms":
            posv = pos[None, None] if pos.ndim == 0 else pos[:, None]
            q = apply_rope(q, posv, cfg.rope_theta)
            k = apply_rope(k, posv, cfg.rope_theta)
        window = cfg.window if kind == "local" else 0
        if cfg.kv_onehot_write:
            # seq-sharded cache mode: replicate q over the model axis so
            # the score einsum's only sharded free dim is the cache seq —
            # otherwise GSPMD all-gathers the (GB-scale) cache instead of
            # the (KB-scale) query (§Perf iteration 1, second attempt).
            from repro.dist.sharding import lsc
            q = lsc(q, "batch", None, None, None)
        cl = _kv_write(cl, kind, k, v, pos, cfg, window)
        kc, vc = _kv_read(cl, cfg)
        s_buf = kc.shape[1]
        cache_len = jnp.minimum(pos + 1, s_buf)
        attn = decode_attention(q, kc, vc, cache_len,
                                window=0 if kind == "local" else 0,
                                logit_cap=cfg.attn_softcap,
                                scale=cfg.query_scale)
        if cfg.kv_onehot_write:
            # block backward propagation of wo's head-sharding into the
            # score tensors (it would re-gather the seq-sharded cache)
            from repro.dist.sharding import lsc
            attn = lsc(attn, "batch", None, None, None)
        y = _attn_out(attn, p["attn"]["wo"], approx_cfg, cfg)
        if cfg.post_norm:
            y = _apply_norm(p["post1"], y, cfg)
        x_t = res + y
        if cfg.encoder_decoder and "xattn" in p:
            res = x_t
            h = _apply_norm(p["norm_x"], x_t, cfg)
            q = _proj(h, p["xattn"]["wq"], approx_cfg, cfg=cfg,
                      heads=cfg.n_heads)
            attn = decode_attention(q, cl["xk"], cl["xv"],
                                    cl["xk"].shape[1])
            x_t = res + _attn_out(attn, p["xattn"]["wo"], approx_cfg, cfg)
        res = x_t
        h = _apply_norm(p["norm2"], x_t, cfg)
        y = _mlp_apply(p["mlp"], h, cfg, approx_cfg)
        if cfg.post_norm:
            y = _apply_norm(p["post2"], y, cfg)
        return res + y, cl
    if kind == "recurrent":
        res = x_t
        h = _apply_norm(p["norm1"], x_t, cfg)
        y, new_state = recurrent_block(p["rec"], h, approx_cfg=approx_cfg,
                                       state=cl, decode=True,
                                       dense_kw=_dense_kw(cfg))
        x_t = res + y
        res = x_t
        h = _apply_norm(p["norm2"], x_t, cfg)
        return res + _mlp_apply(p["mlp"], h, cfg, approx_cfg), new_state
    if kind == "mlstm":
        res = x_t
        h = _apply_norm(p["norm1"], x_t, cfg)
        y, new_state = mlstm_step(p["cell"], h, cl, cfg.n_heads,
                                  approx_cfg=approx_cfg,
                                  dense_kw=_dense_kw(cfg))
        return res + y, new_state
    if kind == "slstm":
        res = x_t
        h = _apply_norm(p["norm1"], x_t, cfg)
        y, new_state = slstm_step(p["cell"], h, cl, cfg.n_heads,
                                  approx_cfg=approx_cfg,
                                  dense_kw=_dense_kw(cfg))
        return res + y, new_state
    raise ValueError(kind)


def decode_step(params, cfg: ModelConfig, cache, token, *,
                approx_cfg=0):
    """token: (B, 1) int32 -> (logits (B, V), new_cache).

    approx_cfg: Python int, traced int32 scalar, or per-layer
    (n_layers,) vector — see _run_blocks."""
    from repro.dist.sharding import lsc
    token = lsc(token, "batch", None)
    x = embed_tokens(params, cfg, token)
    x = lsc(x, "batch", None, None)
    if cfg.norm == "ln":
        x = x + params["dec_pos"][cache["pos"]][None, None].astype(x.dtype)
    pos = cache["pos"]
    new_cache: Params = {"pos": pos + 1}

    npat = len(cfg.pattern)
    n_groups, acfg_scan, acfg_rest = _layer_cfg_plan(params["blocks"],
                                                     approx_cfg, npat)

    if "scan" in params["blocks"]:
        def scan_fn(x, gp_cl_ac):
            gp, cl, ac = gp_cl_ac
            ncl = {}
            for j, kind in enumerate(cfg.pattern):
                x = lsc(x, "batch", None, None)
                x, c = _decode_block(
                    gp[f"b{j}"], kind, x, cl[f"b{j}"], cfg, pos,
                    approx_cfg=approx_cfg if ac is None else ac[j])
                ncl[f"b{j}"] = c
            return x, ncl
        if cfg.scan_layers:
            x, new_scan = jax.lax.scan(scan_fn, x, (params["blocks"]["scan"],
                                                    cache["scan"],
                                                    acfg_scan))
        else:
            outs = []
            for g in range(n_groups):
                gp_cl = jax.tree.map(lambda a: a[g],
                                     (params["blocks"]["scan"],
                                      cache["scan"]))
                ac = acfg_scan[g] if acfg_scan is not None else None
                x, ncl = scan_fn(x, gp_cl + (ac,))
                outs.append(ncl)
            new_scan = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache["scan"] = new_scan
    r = 0
    while f"rest{r}" in params["blocks"]:
        kind = cfg.pattern[r % len(cfg.pattern)]
        x, c = _decode_block(params["blocks"][f"rest{r}"], kind, x,
                             cache[f"rest{r}"], cfg, pos,
                             approx_cfg=(approx_cfg if acfg_rest is None
                                         else acfg_rest[r]))
        new_cache[f"rest{r}"] = c
        r += 1
    x = _apply_norm(params["final_norm"], x, cfg)
    logits = logits_for(params, cfg, x[:, 0])
    return logits, new_cache


# ---------------------------------------------------------------------------
# serving: speculative verify (DESIGN.md §12)
# ---------------------------------------------------------------------------

def verify_gate(cfg: ModelConfig):
    """Speculative draft/verify covers the same model family as the
    paged cache: all-'global' attention, float KV, decoder-only."""
    if any(k != "global" for k in cfg.layer_kinds()):
        raise ValueError("speculative verify needs an all-'global' "
                         "pattern")
    if cfg.kv_quant or cfg.kv_onehot_write:
        raise ValueError("speculative verify is float-KV only (no "
                         "kv_quant / kv_onehot_write)")
    if cfg.encoder_decoder or cfg.vision_prefix_len:
        raise ValueError("speculative verify does not cover "
                         "encoder-decoder or vision-prefix models")


def _verify_block(p, x, cl, cfg, positions, *, approx_cfg=0):
    """One all-'global' layer over a W-token verify window against the
    dense cache.  x: (B,W,d); cl: the layer's (B,S,KV,hd) K/V buffers;
    positions: (W,) traced absolute entries of the window tokens.  The
    window's K/V scatter into entries positions[w] (rows past the
    buffer end drop — scatter, not dynamic-update-slice, so a clipped
    tail can never shift the whole window), then every window position
    attends causally over the full updated buffer."""
    from .attention import NEG_INF, _repeat_kv
    from .layers import apply_rope
    res = x
    h = _apply_norm(p["norm1"], x, cfg)
    q = _proj(h, p["attn"]["wq"], approx_cfg, p["attn"].get("bq"), cfg,
              cfg.n_heads)
    k = _proj(h, p["attn"]["wk"], approx_cfg, p["attn"].get("bk"), cfg,
              cfg.n_kv_heads)
    v = _proj(h, p["attn"]["wv"], approx_cfg, p["attn"].get("bv"), cfg,
              cfg.n_kv_heads)
    if cfg.norm == "rms":
        q = apply_rope(q, positions[None], cfg.rope_theta)
        k = apply_rope(k, positions[None], cfg.rope_theta)
    cl = dict(cl)
    cl["k"] = cl["k"].at[:, positions].set(k.astype(cl["k"].dtype))
    cl["v"] = cl["v"].at[:, positions].set(v.astype(cl["v"].dtype))
    kc, vc = cl["k"], cl["v"]
    k_r = _repeat_kv(kc, cfg.n_heads // cfg.n_kv_heads)
    v_r = _repeat_kv(vc, cfg.n_heads // cfg.n_kv_heads)
    scale = (cfg.query_scale if cfg.query_scale is not None
             else cfg.head_dim ** -0.5)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k_r.astype(jnp.float32)) * scale
    if cfg.attn_softcap > 0:
        scores = softcap(scores, cfg.attn_softcap)
    key_pos = jnp.arange(kc.shape[1])
    valid = key_pos[None, :] <= positions[:, None]        # (W, S) causal
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("bhqk,bkhd->bqhd", w,
                      v_r.astype(jnp.float32)).astype(q.dtype)
    y = _attn_out(attn, p["attn"]["wo"], approx_cfg, cfg)
    if cfg.post_norm:
        y = _apply_norm(p["post1"], y, cfg)
    x = res + y
    res = x
    h = _apply_norm(p["norm2"], x, cfg)
    y = _mlp_apply(p["mlp"], h, cfg, approx_cfg)
    if cfg.post_norm:
        y = _apply_norm(p["post2"], y, cfg)
    return res + y, cl


def decode_verify(params, cfg: ModelConfig, cache, tokens, pos, *,
                  approx_cfg=0):
    """Score a W-token window in ONE pass against the dense cache — the
    speculative-decoding verify step (DESIGN.md §12).

    tokens: (B, W) int32 — row b holds ``[pending_input, draft_1 ..
    draft_k]`` right-padded to the STATIC window W (= max_k + 1; the
    live draft depth k only changes how many rows the host reads, so
    every (k, draft-config) pair shares this one executable — the
    zero-retrace invariant).  pos: traced int32 scalar, the absolute
    cache entry of tokens[:, 0] (the dense pool position).  The
    window's K/V are computed at THIS call's config and overwrite
    whatever the draft steps left at entries pos..pos+W-1; row w of the
    returned (B, W, V) logits scores position pos+w.  Rows past the
    valid count depend only on pad tokens: their logits are ignored
    and their K/V writes land past the committed length, masked by the
    pool position and rewritten before any read."""
    verify_gate(cfg)
    W = tokens.shape[1]
    positions = pos + jnp.arange(W)
    x = embed_tokens(params, cfg, tokens)
    if cfg.norm == "ln":
        x = x + jnp.take(params["dec_pos"], positions, axis=0
                         )[None].astype(x.dtype)
    new_cache: Params = {"pos": jnp.asarray(pos) + W}
    npat = len(cfg.pattern)
    n_groups, acfg_scan, acfg_rest = _layer_cfg_plan(params["blocks"],
                                                     approx_cfg, npat)
    if "scan" in params["blocks"]:
        def scan_fn(x, gp_cl_ac):
            gp, cl, ac = gp_cl_ac
            ncl = {}
            for j in range(npat):
                x, c = _verify_block(
                    gp[f"b{j}"], x, cl[f"b{j}"], cfg, positions,
                    approx_cfg=approx_cfg if ac is None else ac[j])
                ncl[f"b{j}"] = c
            return x, ncl
        if cfg.scan_layers:
            x, new_scan = jax.lax.scan(scan_fn, x, (params["blocks"]["scan"],
                                                    cache["scan"],
                                                    acfg_scan))
        else:
            outs = []
            for g in range(n_groups):
                gp_cl = jax.tree.map(lambda a: a[g],
                                     (params["blocks"]["scan"],
                                      cache["scan"]))
                ac = acfg_scan[g] if acfg_scan is not None else None
                x, ncl = scan_fn(x, gp_cl + (ac,))
                outs.append(ncl)
            new_scan = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache["scan"] = new_scan
    r = 0
    while f"rest{r}" in params["blocks"]:
        x, c = _verify_block(params["blocks"][f"rest{r}"], x,
                             cache[f"rest{r}"], cfg, positions,
                             approx_cfg=(approx_cfg if acfg_rest is None
                                         else acfg_rest[r]))
        new_cache[f"rest{r}"] = c
        r += 1
    x = _apply_norm(params["final_norm"], x, cfg)
    logits = logits_for(params, cfg, x)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, tokens, *, vision_embeds=None,
            enc_embeds=None, max_len: int | None = None,
            approx_cfg=0, true_len=None):
    """Sequence prefill: returns (last-token logits, populated cache).

    Implementation: full forward for activations; K/V recomputed per
    layer into the cache via a per-layer pass (keeps code simple and
    XLA CSEs the shared projections).

    ``true_len`` (traced int32 scalar) marks the real prompt length
    inside right-padded ``tokens`` so ONE compiled executable serves
    every prompt length up to the pad boundary (the engine pads to
    ``prefill_pad``): K/V writes beyond ``true_len`` are zeroed and the
    returned logits come from position ``true_len - 1``.  Causality
    makes every position < true_len blind to the pad tokens, so the
    result is bit-identical to an unpadded prefill of length true_len.
    Attention-only patterns (recurrent states would scan the pads) and
    float KV caches only (int8 would stamp nonzero scales on pads)."""
    if true_len is not None:
        if not all(k in ("global", "local") for k in cfg.layer_kinds()):
            raise ValueError("true_len= needs an attention-only pattern")
        if cfg.kv_quant or cfg.vision_prefix_len or cfg.encoder_decoder:
            raise ValueError("true_len= is incompatible with kv_quant / "
                             "vision prefixes / encoder-decoder")
    b, s = tokens.shape[0], tokens.shape[1]
    if cfg.vision_prefix_len and vision_embeds is not None:
        s = s + cfg.vision_prefix_len
    max_len = max_len or s
    enc_len = enc_embeds.shape[1] if enc_embeds is not None else 0
    cache, cache_spec = init_cache(cfg, b, max_len, enc_len)
    from repro.dist.sharding import lsc, lsc_tree
    cache = lsc_tree(cache, cache_spec)
    tokens = lsc(tokens, "batch", None)
    x = embed_tokens(params, cfg, tokens)
    x = lsc(x, "batch", None, None)
    if cfg.vision_prefix_len and vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    if cfg.norm == "ln":
        x = x + params["dec_pos"][:x.shape[1]][None].astype(x.dtype)
    enc_out = None
    if cfg.encoder_decoder and enc_embeds is not None:
        enc_out = encode(params, cfg, enc_embeds)
    positions = jnp.arange(x.shape[1])[None]

    npat = len(cfg.pattern)
    n_groups, acfg_scan, acfg_rest = _layer_cfg_plan(params["blocks"],
                                                     approx_cfg, npat)

    def fill_block(p, kind, x, cl, approx_cfg=approx_cfg):
        from .layers import apply_rope
        x = lsc(x, "batch", None, None)
        if kind in ("global", "local"):
            h = _apply_norm(p["norm1"], x, cfg)
            k = _proj(h, p["attn"]["wk"], approx_cfg, p["attn"].get("bk"),
                      cfg, cfg.n_kv_heads)
            v = _proj(h, p["attn"]["wv"], approx_cfg, p["attn"].get("bv"),
                      cfg, cfg.n_kv_heads)
            if cfg.norm == "rms":
                k = apply_rope(k, positions, cfg.rope_theta)
            s_buf = cl["k"].shape[1]
            k_w = k[:, -s_buf:]
            v_w = v[:, -s_buf:]
            if true_len is not None:
                # zero the pad positions so the cache matches what an
                # unpadded prefill of length true_len would hold
                pad_mask = (jnp.arange(k_w.shape[1])[None]
                            < jnp.reshape(true_len, (1, 1)))
                k_w = k_w * pad_mask[:, :, None, None].astype(k_w.dtype)
                v_w = v_w * pad_mask[:, :, None, None].astype(v_w.dtype)
            cl = _kv_write(cl, kind, k_w, v_w, jnp.zeros((), jnp.int32), cfg,
                           cfg.window)
            if kind == "local" and x.shape[1] > s_buf:
                # ring-buffer invariant: position p lives at index p % s_buf.
                # prefill wrote positions [S-s_buf, S) at [0, s_buf); roll so
                # decode's pos % s_buf indexing lines up.
                roll = (x.shape[1] - s_buf) % s_buf
                cl = {kk: (jnp.roll(vv, roll, axis=1)
                           if kk in ("k", "v", "k_s", "v_s") else vv)
                      for kk, vv in cl.items()}
            if cfg.encoder_decoder and "xattn" in p:
                cl = dict(cl)
                cl["xk"] = _proj(enc_out, p["xattn"]["wk"], approx_cfg,
                                 cfg=cfg, heads=cfg.n_kv_heads
                                 ).astype(cl["xk"].dtype)
                cl["xv"] = _proj(enc_out, p["xattn"]["wv"], approx_cfg,
                                 cfg=cfg, heads=cfg.n_kv_heads
                                 ).astype(cl["xv"].dtype)
            x = _apply_block(p, kind, x, cfg, positions=positions,
                             approx_cfg=approx_cfg, causal=True,
                             enc_out=enc_out)
            return x, cl
        # recurrent kinds: run the parallel path, capture final state
        if kind == "recurrent":
            res = x
            h = _apply_norm(p["norm1"], x, cfg)
            y, state = recurrent_block(p["rec"], h, approx_cfg=approx_cfg,
                                       dense_kw=_dense_kw(cfg))
            x = res + y
            res = x
            h = _apply_norm(p["norm2"], x, cfg)
            return res + _mlp_apply(p["mlp"], h, cfg, approx_cfg), state
        if kind == "mlstm":
            from .recurrent import mlstm_final_state
            res = x
            h = _apply_norm(p["norm1"], x, cfg)
            y = mlstm_parallel(p["cell"], h, cfg.n_heads,
                               approx_cfg=approx_cfg, q_chunk=cfg.q_chunk,
                               unroll=cfg.unroll_chunks,
                               dense_kw=_dense_kw(cfg))
            state = mlstm_final_state(p["cell"], h, cfg.n_heads,
                                      approx_cfg=approx_cfg,
                                      dense_kw=_dense_kw(cfg))
            return res + y, state
        if kind == "slstm":
            res = x
            h = _apply_norm(p["norm1"], x, cfg)
            y, state = slstm_scan(p["cell"], h, cfg.n_heads,
                                  approx_cfg=approx_cfg,
                                  dense_kw=_dense_kw(cfg))
            return res + y, state
        raise ValueError(kind)

    new_cache: Params = {"pos": (jnp.asarray(s, jnp.int32) if true_len is None
                                 else jnp.asarray(true_len, jnp.int32))}
    if "scan" in params["blocks"]:
        def scan_fn(x, gp_cl_ac):
            gp, cl, ac = gp_cl_ac
            ncl = {}
            for j, kind in enumerate(cfg.pattern):
                x, c = fill_block(
                    gp[f"b{j}"], kind, x, cl[f"b{j}"],
                    approx_cfg=approx_cfg if ac is None else ac[j])
                ncl[f"b{j}"] = c
            return x, ncl
        if cfg.scan_layers:
            x, new_scan = jax.lax.scan(scan_fn, x, (params["blocks"]["scan"],
                                                    cache["scan"],
                                                    acfg_scan))
        else:
            outs = []
            for g in range(n_groups):
                gp_cl = jax.tree.map(lambda a: a[g],
                                     (params["blocks"]["scan"],
                                      cache["scan"]))
                ac = acfg_scan[g] if acfg_scan is not None else None
                x, ncl = scan_fn(x, gp_cl + (ac,))
                outs.append(ncl)
            new_scan = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache["scan"] = new_scan
    r = 0
    while f"rest{r}" in params["blocks"]:
        kind = cfg.pattern[r % len(cfg.pattern)]
        x, c = fill_block(params["blocks"][f"rest{r}"], kind, x,
                          cache[f"rest{r}"],
                          approx_cfg=(approx_cfg if acfg_rest is None
                                      else acfg_rest[r]))
        new_cache[f"rest{r}"] = c
        r += 1
    x = _apply_norm(params["final_norm"], x, cfg)
    last = (x[:, -1] if true_len is None
            else jnp.take(x, jnp.asarray(true_len, jnp.int32) - 1, axis=1))
    logits = logits_for(params, cfg, last)
    return logits, new_cache


# ---------------------------------------------------------------------------
# serving: paged KV cache (DESIGN.md §11)
# ---------------------------------------------------------------------------
# The paged entry points replace the dense (B, S, KV, hd) cache rows with
# one (num_blocks, block_size, KV, hd) pool per layer plus per-request
# block tables.  Tables, sequence lengths and the active mask are int32
# DATA operands — never shapes — so one compiled executable serves any
# mix of stream counts and prompt lengths (the zero-retrace invariant).
# Block ids 0/1 are reserved (see serve/paged_cache.py): 0 is all-zero
# and backs unallocated table entries, 1 absorbs masked-off writes.

def _paged_gate(cfg: ModelConfig):
    if any(k != "global" for k in cfg.layer_kinds()):
        raise ValueError("paged cache needs an all-'global' pattern")
    if cfg.kv_quant or cfg.kv_onehot_write:
        raise ValueError("paged cache is float-KV only (no kv_quant / "
                         "kv_onehot_write)")
    if cfg.encoder_decoder or cfg.vision_prefix_len:
        raise ValueError("paged cache does not cover encoder-decoder or "
                         "vision-prefix models")


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int):
    """Block-pool cache pytree (+ logical specs) for paged decode.

    Per attention layer: K/V pools of shape (num_blocks, block_size,
    KV, hd) — no batch axis; requests own pool blocks through their
    block tables.  Block 0 (ZERO_BLOCK) is all-zero and must never be
    written so unowned table entries gather zeros, matching what the
    dense cache holds past ``pos``."""
    _paged_gate(cfg)
    npat = len(cfg.pattern)
    n_groups, rem = cfg.n_layers // npat, cfg.n_layers % npat

    def layer_cache():
        z = jnp.zeros((num_blocks, block_size, cfg.n_kv_heads,
                       cfg.head_dim), cfg.compute_dtype)
        return ({"k": z, "v": z},
                {"k": (None, None, "tp?", "kv_hd"),
                 "v": (None, None, "tp?", "kv_hd")})

    cache: Params = {}
    cspec: Params = {}
    if n_groups:
        gc, gs = {}, {}
        for j in range(npat):
            c, sp = layer_cache()
            gc[f"b{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(), c)
            gs[f"b{j}"] = jax.tree.map(
                lambda t: (None,) + tuple(t), sp,
                is_leaf=lambda t: isinstance(t, tuple))
        cache["scan"], cspec["scan"] = gc, gs
    for r in range(rem):
        c, sp = layer_cache()
        cache[f"rest{r}"], cspec[f"rest{r}"] = c, sp
    return cache, cspec


def _paged_attn_block(p, x_t, cl, cfg, tables, seq_lens, active, *,
                      approx_cfg=0, backend="xla"):
    """One paged layer, one token per row.  x_t: (B,1,d); cl holds the
    layer's (NB, bs, KV, hd) K/V pools; tables: (B,P) int32; seq_lens:
    (B,) int32 tokens already cached per row; active: (B,) bool."""
    from repro.serve.paged_cache import TRASH_BLOCK

    from .layers import apply_rope
    res = x_t
    h = _apply_norm(p["norm1"], x_t, cfg)
    q = _proj(h, p["attn"]["wq"], approx_cfg, p["attn"].get("bq"), cfg,
              cfg.n_heads)
    k = _proj(h, p["attn"]["wk"], approx_cfg, p["attn"].get("bk"), cfg,
              cfg.n_kv_heads)
    v = _proj(h, p["attn"]["wv"], approx_cfg, p["attn"].get("bv"), cfg,
              cfg.n_kv_heads)
    if cfg.norm == "rms":
        posv = seq_lens[:, None]
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    bs = cl["k"].shape[1]
    b_idx = jnp.arange(x_t.shape[0])
    # the current token's K/V lands in the row's tail block; inactive
    # rows scatter into the trash block (contents never read)
    write_block = jnp.where(active, tables[b_idx, seq_lens // bs],
                            TRASH_BLOCK)
    write_off = seq_lens % bs
    cl = dict(cl)
    cl["k"] = cl["k"].at[write_block, write_off].set(
        k[:, 0].astype(cl["k"].dtype))
    cl["v"] = cl["v"].at[write_block, write_off].set(
        v[:, 0].astype(cl["v"].dtype))
    cache_len = seq_lens + 1
    if backend == "pallas":
        from repro.kernels.flash_attention.paged_attention import \
            paged_decode_attention
        attn = paged_decode_attention(
            q, cl["k"], cl["v"], tables, cache_len,
            logit_cap=cfg.attn_softcap, scale=cfg.query_scale,
            interpret=cfg.mac_interpret)
    else:
        # gather-view decode: (B, P*bs, KV, hd) through the table, then
        # the stock masked decode attention (bit-identical to the dense
        # pool when P*bs matches its max_len — same shapes, same values:
        # positions >= cache_len are masked to NEG_INF either way)
        kc = jnp.reshape(cl["k"][tables],
                         (x_t.shape[0], -1, cfg.n_kv_heads, cfg.head_dim))
        vc = jnp.reshape(cl["v"][tables],
                         (x_t.shape[0], -1, cfg.n_kv_heads, cfg.head_dim))
        attn = decode_attention(q, kc, vc, cache_len, window=0,
                                logit_cap=cfg.attn_softcap,
                                scale=cfg.query_scale)
    y = _attn_out(attn, p["attn"]["wo"], approx_cfg, cfg)
    if cfg.post_norm:
        y = _apply_norm(p["post1"], y, cfg)
    x_t = res + y
    res = x_t
    h = _apply_norm(p["norm2"], x_t, cfg)
    y = _mlp_apply(p["mlp"], h, cfg, approx_cfg)
    if cfg.post_norm:
        y = _apply_norm(p["post2"], y, cfg)
    return res + y, cl


def paged_decode_step(params, cfg: ModelConfig, cache, token, *,
                      approx_cfg=0, backend="xla"):
    """One token for every row against the block pool.

    ``cache`` carries the pool leaves ("scan"/"rest{r}") plus three data
    operands: "tables" (B,P) int32 block tables, "seq_lens" (B,) int32,
    "active" (B,) bool.  Returns (logits (B,V), new pool leaves) — table
    bookkeeping stays on the host (serve/paged_cache.py)."""
    tables = cache["tables"]
    seq_lens = cache["seq_lens"]
    active = cache["active"]
    x = embed_tokens(params, cfg, token)
    if cfg.norm == "ln":
        x = x + jnp.take(params["dec_pos"], seq_lens, axis=0
                         )[:, None].astype(x.dtype)
    new_cache: Params = {}
    npat = len(cfg.pattern)
    n_groups, acfg_scan, acfg_rest = _layer_cfg_plan(params["blocks"],
                                                     approx_cfg, npat)

    if "scan" in params["blocks"]:
        def scan_fn(x, gp_cl_ac):
            gp, cl, ac = gp_cl_ac
            ncl = {}
            for j in range(npat):
                x, c = _paged_attn_block(
                    gp[f"b{j}"], x, cl[f"b{j}"], cfg, tables, seq_lens,
                    active,
                    approx_cfg=approx_cfg if ac is None else ac[j],
                    backend=backend)
                ncl[f"b{j}"] = c
            return x, ncl
        if cfg.scan_layers:
            x, new_scan = jax.lax.scan(scan_fn, x, (params["blocks"]["scan"],
                                                    cache["scan"],
                                                    acfg_scan))
        else:
            outs = []
            for g in range(n_groups):
                gp_cl = jax.tree.map(lambda a: a[g],
                                     (params["blocks"]["scan"],
                                      cache["scan"]))
                ac = acfg_scan[g] if acfg_scan is not None else None
                x, ncl = scan_fn(x, gp_cl + (ac,))
                outs.append(ncl)
            new_scan = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache["scan"] = new_scan
    r = 0
    while f"rest{r}" in params["blocks"]:
        x, c = _paged_attn_block(
            params["blocks"][f"rest{r}"], x, cache[f"rest{r}"], cfg,
            tables, seq_lens, active,
            approx_cfg=approx_cfg if acfg_rest is None else acfg_rest[r],
            backend=backend)
        new_cache[f"rest{r}"] = c
        r += 1
    x = _apply_norm(params["final_norm"], x, cfg)
    logits = logits_for(params, cfg, x[:, 0])
    return logits, new_cache


def paged_prefill_chunk(params, cfg: ModelConfig, cache, tokens, *,
                        slot, start, count, approx_cfg=0):
    """Advance one request's prefill by one chunk of its prompt.

    tokens: (1, C) right-padded chunk; slot/start/count are traced int32
    scalars — the request's row, the absolute position of tokens[0], and
    the number of valid tokens in the chunk.  K/V for the valid tokens
    scatter into the slot's blocks (pads go to the trash block); each
    chunk position attends to every cached key at absolute position
    <= its own, so chaining chunks reproduces full-prompt prefill.
    Returns (logits (1,C,V) at EVERY chunk position, new pool leaves):
    prefill callers index ``count - 1`` on the host for the next-token
    sample; the speculative verify pass (DESIGN.md §12) consumes all
    rows — one chunk call scores k draft positions at once.
    """
    from repro.serve.paged_cache import TRASH_BLOCK

    from .attention import NEG_INF, _repeat_kv
    from .layers import apply_rope
    tables = cache["tables"]
    c_len = tokens.shape[1]
    tok_pos = start + jnp.arange(c_len)            # (C,) absolute
    positions = tok_pos[None]
    x = embed_tokens(params, cfg, tokens)
    if cfg.norm == "ln":
        x = x + jnp.take(params["dec_pos"], tok_pos, axis=0
                         )[None].astype(x.dtype)
    row = tables[slot]                             # (P,)
    scale = (cfg.query_scale if cfg.query_scale is not None
             else cfg.head_dim ** -0.5)

    def fill_chunk(p, x, cl, ac):
        h = _apply_norm(p["norm1"], x, cfg)
        q = _proj(h, p["attn"]["wq"], ac, p["attn"].get("bq"), cfg,
                  cfg.n_heads)
        k = _proj(h, p["attn"]["wk"], ac, p["attn"].get("bk"), cfg,
                  cfg.n_kv_heads)
        v = _proj(h, p["attn"]["wv"], ac, p["attn"].get("bv"), cfg,
                  cfg.n_kv_heads)
        if cfg.norm == "rms":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        bs = cl["k"].shape[1]
        blocks = jnp.where(jnp.arange(c_len) < count,
                           row[tok_pos // bs], TRASH_BLOCK)
        offs = tok_pos % bs
        cl = dict(cl)
        cl["k"] = cl["k"].at[blocks, offs].set(k[0].astype(cl["k"].dtype))
        cl["v"] = cl["v"].at[blocks, offs].set(v[0].astype(cl["v"].dtype))
        kc = jnp.reshape(cl["k"][row],
                         (1, -1, cfg.n_kv_heads, cfg.head_dim))
        vc = jnp.reshape(cl["v"][row],
                         (1, -1, cfg.n_kv_heads, cfg.head_dim))
        k_r = _repeat_kv(kc, cfg.n_heads // cfg.n_kv_heads)
        v_r = _repeat_kv(vc, cfg.n_heads // cfg.n_kv_heads)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                            k_r.astype(jnp.float32)) * scale
        if cfg.attn_softcap > 0:
            scores = softcap(scores, cfg.attn_softcap)
        key_pos = jnp.arange(kc.shape[1])
        valid = key_pos[None, :] <= tok_pos[:, None]       # (C, L)
        scores = jnp.where(valid[None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", w,
                          v_r.astype(jnp.float32)).astype(q.dtype)
        y = _attn_out(attn, p["attn"]["wo"], ac, cfg)
        if cfg.post_norm:
            y = _apply_norm(p["post1"], y, cfg)
        x = x + y
        res = x
        h = _apply_norm(p["norm2"], x, cfg)
        y = _mlp_apply(p["mlp"], h, cfg, ac)
        if cfg.post_norm:
            y = _apply_norm(p["post2"], y, cfg)
        return res + y, cl

    new_cache: Params = {}
    npat = len(cfg.pattern)
    n_groups, acfg_scan, acfg_rest = _layer_cfg_plan(params["blocks"],
                                                     approx_cfg, npat)
    if "scan" in params["blocks"]:
        def scan_fn(x, gp_cl_ac):
            gp, cl, ac = gp_cl_ac
            ncl = {}
            for j in range(npat):
                x, c = fill_chunk(gp[f"b{j}"], x, cl[f"b{j}"],
                                  approx_cfg if ac is None else ac[j])
                ncl[f"b{j}"] = c
            return x, ncl
        if cfg.scan_layers:
            x, new_scan = jax.lax.scan(scan_fn, x, (params["blocks"]["scan"],
                                                    cache["scan"],
                                                    acfg_scan))
        else:
            outs = []
            for g in range(n_groups):
                gp_cl = jax.tree.map(lambda a: a[g],
                                     (params["blocks"]["scan"],
                                      cache["scan"]))
                ac = acfg_scan[g] if acfg_scan is not None else None
                x, ncl = scan_fn(x, gp_cl + (ac,))
                outs.append(ncl)
            new_scan = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache["scan"] = new_scan
    r = 0
    while f"rest{r}" in params["blocks"]:
        x, c = fill_chunk(params["blocks"][f"rest{r}"], x,
                          cache[f"rest{r}"],
                          approx_cfg if acfg_rest is None else acfg_rest[r])
        new_cache[f"rest{r}"] = c
        r += 1
    x = _apply_norm(params["final_norm"], x, cfg)
    logits = logits_for(params, cfg, x)
    return logits, new_cache
