"""Recurrent sequence-mixing blocks: RG-LRU (Griffin/RecurrentGemma) and
xLSTM cells (mLSTM via decay-attention parallel form, sLSTM via scan).

All recurrences expose two execution paths:
  * train/prefill: full-sequence parallel (associative scan for RG-LRU,
    chunked decay-attention for mLSTM, lax.scan for sLSTM);
  * decode: O(1)-state single-step updates (the state is the "cache").

The paper's approx-MAC knob applies to the in/out projections of these
blocks; the recurrent updates themselves are elementwise/diagonal, not
GEMMs, so the knob does not reach them (DESIGN.md §2 adapts MACs only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import expand_left

from .attention import chunked_attention
from .layers import dense

SQRT2 = float(np.sqrt(2.0))
RG_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU (Griffin eq. 5-7)
# ---------------------------------------------------------------------------

def rg_lru_init(rng, width: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    # Lambda init so a = sigmoid(lam)^c is uniform in [0.9, 0.999]^(1/c)
    u = jax.random.uniform(k1, (width,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (1.0 / RG_LRU_C) / (1 - u ** (1.0 / RG_LRU_C)))
    return {
        "lam": lam.astype(jnp.float32),
        "w_a": (jax.random.normal(k2, (width, width)) / np.sqrt(width)
                ).astype(jnp.float32),
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_x": (jax.random.normal(k3, (width, width)) / np.sqrt(width)
                ).astype(jnp.float32),
        "b_x": jnp.zeros((width,), jnp.float32),
    }


def rg_lru_scan(params, x, h0=None):
    """x: (B, S, W) -> (y, h_last).  Diagonal linear recurrence
    h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t), via associative scan."""
    b, s, w = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"]
                       + expand_left(params["b_a"], xf.ndim))   # recurrence gate
    i = jax.nn.sigmoid(xf @ params["w_x"]
                       + expand_left(params["b_x"], xf.ndim))   # input gate
    log_a = -RG_LRU_C * r * expand_left(
        jax.nn.softplus(-params["lam"]), r.ndim)     # log sigmoid(lam)^(c r)
    a = jnp.exp(log_a)
    gated_x = i * xf
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bterm = beta * gated_x
    if h0 is not None:
        # fold h0 into the first step: b_1 += a_1 * h0
        bterm = bterm.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(params, x_t, h_prev):
    """Single decode step. x_t: (B, W); h_prev: (B, W)."""
    xf = x_t.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"] + expand_left(params["b_a"], xf.ndim))
    i = jax.nn.sigmoid(xf @ params["w_x"] + expand_left(params["b_x"], xf.ndim))
    log_a = -RG_LRU_C * r * expand_left(jax.nn.softplus(-params["lam"]), r.ndim)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a * h_prev.astype(jnp.float32) + beta * (i * xf)
    return h.astype(x_t.dtype), h


def recurrent_block_init(rng, d_model: int, width: int, conv_width: int = 4):
    ks = jax.random.split(rng, 5)
    return {
        "w_in_rec": dense_like(ks[0], d_model, width),
        "w_in_gate": dense_like(ks[1], d_model, width),
        "conv_w": (jax.random.normal(ks[2], (conv_width, width)) * 0.02
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((width,), jnp.float32),
        "lru": rg_lru_init(ks[3], width),
        "w_out": dense_like(ks[4], width, d_model),
    }


def dense_like(rng, d_in, d_out):
    return (jax.random.normal(rng, (d_in, d_out)) / np.sqrt(d_in)
            ).astype(jnp.float32)


def causal_conv1d(x, w, b):
    """x: (B,S,W); w: (K,W) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * expand_left(w[i], x.ndim)
              for i in range(k))
    return out + expand_left(b, out.ndim)


def causal_conv1d_step(x_t, conv_state, w, b):
    """x_t: (B,W); conv_state: (B,K-1,W) past inputs (oldest first)."""
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,W)
    out = jnp.einsum("bkw,kw->bw", window, w) + expand_left(b, 2)
    return out, window[:, 1:]


def recurrent_block(params, x, *, approx_cfg: int = 0, state=None,
                    decode: bool = False, dense_kw: dict | None = None):
    """Griffin recurrent block: gate branch * (conv -> RG-LRU) branch.
    state (decode): {"h": (B,W), "conv": (B,K-1,W)}."""
    kw = dense_kw or {}
    gate = jax.nn.gelu(dense(x, params["w_in_gate"], approx_cfg=approx_cfg,
                             **kw))
    rec = dense(x, params["w_in_rec"], approx_cfg=approx_cfg, **kw)
    if decode:
        x_t = rec[:, 0]
        c_out, conv_state = causal_conv1d_step(
            x_t.astype(jnp.float32), state["conv"],
            params["conv_w"], params["conv_b"])
        h_out, h = rg_lru_step(params["lru"], c_out, state["h"])
        y = h_out[:, None, :].astype(x.dtype)
        new_state = {"h": h, "conv": conv_state}
    else:
        c_out = causal_conv1d(rec.astype(jnp.float32), params["conv_w"],
                              params["conv_b"])
        y, h_last = rg_lru_scan(params["lru"], c_out.astype(x.dtype))
        k = params["conv_w"].shape[0]
        new_state = {"h": h_last,
                     "conv": rec.astype(jnp.float32)[:, -(k - 1):, :]}
    out = dense((y * gate).astype(x.dtype), params["w_out"],
                approx_cfg=approx_cfg, **kw)
    return out, new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix memory; parallel form == decay attention
# ---------------------------------------------------------------------------

def mlstm_block_init(rng, d_model: int, n_heads: int, proj_factor: float = 2.0):
    d_inner = int(d_model * proj_factor)
    hd = d_inner // n_heads
    ks = jax.random.split(rng, 8)
    return {
        "w_up": dense_like(ks[0], d_model, d_inner),
        "w_gate": dense_like(ks[1], d_model, d_inner),
        "w_q": dense_like(ks[2], d_inner, d_inner),
        "w_k": dense_like(ks[3], d_inner, d_inner),
        "w_v": dense_like(ks[4], d_inner, d_inner),
        "w_if": dense_like(ks[5], d_inner, 2 * n_heads),   # input+forget gates
        "b_if": jnp.concatenate([jnp.zeros((n_heads,)),
                                 jnp.ones((n_heads,)) * 3.0]).astype(jnp.float32),
        "ln_scale": jnp.ones((d_inner,), jnp.float32),
        "w_down": dense_like(ks[6], d_inner, d_model),
    }


def mlstm_parallel(params, x, n_heads: int, *, approx_cfg: int = 0,
                   q_chunk: int = 1024, unroll: bool = False,
                   dense_kw: dict | None = None):
    """x: (B,S,D) -> (B,S,D) via the stabilized parallel form."""
    kw = dense_kw or {}
    nh = n_heads
    b, s, _ = x.shape
    up = dense(x, params["w_up"], approx_cfg=approx_cfg, **kw)
    gate = jax.nn.silu(dense(x, params["w_gate"], approx_cfg=approx_cfg, **kw))
    d_inner = up.shape[-1]
    hd = d_inner // nh
    q = dense(up, params["w_q"], approx_cfg=approx_cfg,
              **kw).reshape(b, s, nh, hd)
    k = dense(up, params["w_k"], approx_cfg=approx_cfg,
              **kw).reshape(b, s, nh, hd)
    v = dense(up, params["w_v"], approx_cfg=approx_cfg,
              **kw).reshape(b, s, nh, hd)
    if_gates = (up.astype(jnp.float32) @ params["w_if"]
                + expand_left(params["b_if"], up.ndim))
    log_i = if_gates[..., :nh]                               # pre-activation
    log_f = jax.nn.log_sigmoid(if_gates[..., nh:])           # (B,S,H)
    log_fcum = jnp.cumsum(log_f, axis=1)
    h = chunked_attention(q, k, v, causal=True, q_chunk=min(q_chunk, s),
                          decay={"log_fcum": log_fcum, "log_i": log_i},
                          unroll=unroll)
    h = h.reshape(b, s, d_inner)
    from .layers import rmsnorm
    h = rmsnorm(h, params["ln_scale"] - 1.0)                 # scale offset=1
    out = dense((h * gate).astype(x.dtype), params["w_down"],
                approx_cfg=approx_cfg, **kw)
    return out


def mlstm_final_state(params, x, n_heads: int, *, approx_cfg: int = 0,
                      dense_kw: dict | None = None):
    """Materialize the recurrent state (C,n,m) after consuming x —
    needed to continue decoding after a parallel-form prefill.

    Telescoping the recurrence: m_S = max_j w_j with
    w_j = sum_{l=j+1..S} log_f_l + log_i_j, and
    C_S = sum_j exp(w_j - m_S) k_j v_j^T,  n_S = sum_j exp(w_j - m_S) k_j.
    """
    kw = dense_kw or {}
    nh = n_heads
    b, s, _ = x.shape
    up = dense(x, params["w_up"], approx_cfg=approx_cfg, **kw)
    d_inner = up.shape[-1]
    hd = d_inner // nh
    k = dense(up, params["w_k"], approx_cfg=approx_cfg,
              **kw).reshape(b, s, nh, hd)
    v = dense(up, params["w_v"], approx_cfg=approx_cfg,
              **kw).reshape(b, s, nh, hd)
    if_g = (up.astype(jnp.float32) @ params["w_if"]
            + expand_left(params["b_if"], up.ndim))
    log_i = if_g[..., :nh]
    log_f = jax.nn.log_sigmoid(if_g[..., nh:])               # (B,S,H)
    log_fcum = jnp.cumsum(log_f, axis=1)
    w = log_fcum[:, -1:, :] - log_fcum + log_i               # (B,S,H)
    m = jnp.max(w, axis=1)                                   # (B,H)
    wexp = jnp.exp(w - m[:, None, :])                        # (B,S,H)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_state = jnp.einsum("bsh,bshi,bshj->bhij", wexp, kf, vf)
    n_state = jnp.einsum("bsh,bshi->bhi", wexp, kf)
    return {"C": c_state, "n": n_state, "m": m}


def mlstm_step(params, x_t, state, n_heads: int, *, approx_cfg: int = 0,
               dense_kw: dict | None = None):
    """Decode step with matrix memory state {"C": (B,H,hd,hd),
    "n": (B,H,hd), "m": (B,H)}.  x_t: (B,1,D)."""
    kw = dense_kw or {}
    nh = n_heads
    b = x_t.shape[0]
    up = dense(x_t[:, 0], params["w_up"], approx_cfg=approx_cfg, **kw)
    gate = jax.nn.silu(dense(x_t[:, 0], params["w_gate"], approx_cfg=approx_cfg,
                             **kw))
    d_inner = up.shape[-1]
    hd = d_inner // nh
    q = dense(up, params["w_q"], approx_cfg=approx_cfg,
              **kw).reshape(b, nh, hd)
    k = dense(up, params["w_k"], approx_cfg=approx_cfg,
              **kw).reshape(b, nh, hd)
    v = dense(up, params["w_v"], approx_cfg=approx_cfg,
              **kw).reshape(b, nh, hd)
    if_g = (up.astype(jnp.float32) @ params["w_if"]
            + expand_left(params["b_if"], up.ndim))
    log_i = if_g[..., :nh]
    log_f = jax.nn.log_sigmoid(if_g[..., nh:])               # (B,H)
    m_prev, c_prev, n_prev = state["m"], state["C"], state["n"]
    m_new = jnp.maximum(log_f + m_prev, log_i)
    f_sc = jnp.exp(log_f + m_prev - m_new)[..., None, None]
    i_sc = jnp.exp(log_i - m_new)[..., None, None]
    qf = q.astype(jnp.float32) * hd ** -0.5
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c_new = f_sc * c_prev + i_sc * (kf[..., :, None] * vf[..., None, :])
    n_new = f_sc[..., 0] * n_prev + i_sc[..., 0] * kf
    num = jnp.einsum("bhij,bhi->bhj", c_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n_new, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, d_inner)
    from .layers import rmsnorm
    h = rmsnorm(h, params["ln_scale"] - 1.0)
    out = dense((h * gate).astype(x_t.dtype), params["w_down"],
                approx_cfg=approx_cfg, **kw)
    return out[:, None, :], {"C": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM): scalar memory with exponential gating + state mixing
# ---------------------------------------------------------------------------

def slstm_block_init(rng, d_model: int, n_heads: int):
    hd = d_model // n_heads
    ks = jax.random.split(rng, 4)
    w = (jax.random.normal(ks[0], (d_model, 4 * d_model)) / np.sqrt(d_model)
         ).astype(jnp.float32)
    r = (jax.random.normal(ks[1], (n_heads, hd, 4 * hd)) / np.sqrt(hd)
         ).astype(jnp.float32)
    return {
        "w": w,                       # input projection for i,f,z,o
        "r": r,                       # block-diagonal recurrent (per head)
        "b": jnp.concatenate([jnp.zeros((d_model,)),
                              jnp.ones((d_model,)),       # forget bias +1
                              jnp.zeros((2 * d_model,))]).astype(jnp.float32),
        "ln_scale": jnp.ones((d_model,), jnp.float32),
        "w_up": dense_like(ks[2], d_model, int(d_model * 4 / 3)),
        "w_gate": dense_like(ks[2], d_model, int(d_model * 4 / 3)),
        "w_down": dense_like(ks[3], int(d_model * 4 / 3), d_model),
    }


def _slstm_cell(params, wx_t, carry, n_heads: int):
    """One timestep. wx_t: (B, 4D) precomputed W@x; carry: h,c,n,m (B,D)."""
    nh = n_heads
    h, c, n, m = carry
    b_sz, d = h.shape
    hd = d // nh
    hh = h.reshape(b_sz, nh, hd)
    rec = jnp.einsum("bnh,nhk->bnk", hh, params["r"])      # (B,nh,4hd)
    rec = rec.reshape(b_sz, nh, 4, hd).transpose(0, 2, 1, 3).reshape(b_sz, 4 * d)
    pre = wx_t + rec + expand_left(params["b"], wx_t.ndim)
    i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
    log_i = i_p
    log_f = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(log_f + m, log_i)
    i_g = jnp.exp(log_i - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_scan(params, x, n_heads: int, *, approx_cfg: int = 0,
               state=None, dense_kw: dict | None = None):
    """x: (B,S,D) -> (B,S,D); sequential lax.scan over time."""
    kw = dense_kw or {}
    b, s, d = x.shape
    wx = dense(x, params["w"], approx_cfg=approx_cfg, **kw).astype(jnp.float32)
    # reorder to (i,f,z,o) blocks of size D each — init is already blocked
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        carry = (zeros, zeros, zeros, zeros - 30.0)
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])

    def step(carry, wx_t):
        new = _slstm_cell(params, wx_t, carry, n_heads)
        return new, new[0]

    carry, hs = jax.lax.scan(step, carry, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)                                # (B,S,D)
    from .layers import rmsnorm
    h = rmsnorm(h.astype(x.dtype), params["ln_scale"] - 1.0)
    up = jax.nn.silu(dense(h, params["w_gate"], approx_cfg=approx_cfg, **kw)) \
        * dense(h, params["w_up"], approx_cfg=approx_cfg, **kw)
    out = dense(up, params["w_down"], approx_cfg=approx_cfg, **kw)
    new_state = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    return out, new_state


def slstm_step(params, x_t, state, n_heads: int, *, approx_cfg: int = 0,
               dense_kw: dict | None = None):
    """Decode step; x_t: (B,1,D)."""
    out, new_state = slstm_scan(params, x_t, n_heads, approx_cfg=approx_cfg,
                                state=state, dense_kw=dense_kw)
    return out, new_state
