"""Building-block layers: norms, dense (exact / quantized-approximate),
embeddings, RoPE.  Pure functions over param dicts.

Every dense layer can run in three modes (per-layer, runtime-selectable):
  * float (training / exact serving)
  * quantized exact (config 0): dynamic int8 activations x int8 weights
  * quantized approximate (configs 1..31): the paper's error knob via
    ``approx_dense`` (operand-truncation TPU path)

The error config for a layer comes from the ``approx_cfg`` argument
threading through the model apply functions; 0 everywhere by default.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx_matmul import approx_dense
from repro.core.quantization import (QTensor, expand_left, fake_quant,
                                     quantize)

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# dense with the error-config knob
# ---------------------------------------------------------------------------

def dense(x, w, *, approx_cfg: int = 0, quantized: bool = False,
          compute_dtype=jnp.bfloat16, backend: str = "xla",
          interpret: bool = False,
          block_shapes: tuple[int, int, int] = (128, 128, 256)):
    """y = x @ w under the selected arithmetic mode.

    w may be a float array or a QTensor (pre-quantized weights — see
    transformer.quantize_lm_params; quantizing once at load time instead
    of inside every traced call removes a per-decode-step requantize).
    When `quantized` or approx_cfg>0, runs the integer pipeline: dynamic
    per-tensor int8 activations x int8 weights, operand-truncation
    approximation, f32 rescale (DESIGN.md §2).

    `approx_cfg` may be a TRACED int32 scalar (the runtime power knob):
    the integer pipeline then always runs, with the error config gathered
    per call — traced config 0 is the exact int8 MAC (the paper's exact
    mode), bit-identical to the static quantized path.  On the "pallas"
    backend it may also be a (g,) per-neuron-group config VECTOR: group
    j covers output columns [j*N/g, (j+1)*N/g) at the kernel's
    bn-column block resolution; blocks straddling a group boundary (or
    GEMMs narrower than g blocks) run the lowest-measured-MRED config
    among their groups — never higher error than any covered neuron
    asked for (DESIGN.md §3).  An (E, g) per-EXPERT matrix (an engine
    config with an expert axis reaching a GEMM that has no expert
    dimension) collapses the expert axis per group by the same
    lowest-measured-MRED rule (DESIGN.md §4).

    backend: "xla" (operand-truncation ops compiled by XLA) or "pallas"
    (the fused approx-MAC kernel: quantize + truncate + int8 MAC +
    rescale in one pallas_call).  Both are bit-identical per config;
    `interpret` runs the kernel in interpret mode (CPU tests);
    `block_shapes` is the kernel's (bm, bn, bk) tiling — results are
    tiling-invariant, so feed it an autotune_block_shapes winner."""
    vector_cfg = isinstance(approx_cfg, jax.Array) and approx_cfg.ndim >= 1
    if isinstance(approx_cfg, jax.Array) or approx_cfg > 0 or quantized:
        w_qt = w if isinstance(w, QTensor) else quantize(w, axis=1)
        if backend == "pallas":
            from repro.kernels.approx_mac.ops import (approx_dense_pallas,
                                                      collapse_expert_cfg)
            if isinstance(approx_cfg, jax.Array) and approx_cfg.ndim == 2:
                approx_cfg = collapse_expert_cfg(approx_cfg)
            bm, bn, bk = block_shapes
            y = approx_dense_pallas(x.astype(jnp.float32), w_qt,
                                    config=approx_cfg, interpret=interpret,
                                    bm=bm, bn=bn, bk=bk,
                                    compute_dtype=jnp.float32)
            return y.astype(compute_dtype)
        assert not vector_cfg, \
            "per-block config vectors require backend='pallas'"
        y = approx_dense(x.astype(jnp.float32), w_qt, approx_cfg)
        return y.astype(compute_dtype)
    if isinstance(w, QTensor):
        w = w.dequantize()
    return jnp.dot(x, w.astype(x.dtype))


def qat_dense(x, w, *, compute_dtype=jnp.bfloat16):
    """Quantization-aware training path (straight-through fake quant)."""
    return jnp.dot(fake_quant(x.astype(jnp.float32)),
                   fake_quant(w.astype(jnp.float32), axis=1)).astype(compute_dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6, offset: float = 1.0):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    s = offset + scale.astype(jnp.float32)
    return (y * expand_left(s, y.ndim)).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * expand_left(scale.astype(jnp.float32), y.ndim)
            + expand_left(bias.astype(jnp.float32), y.ndim)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    pos = positions[..., :, None, None].astype(jnp.float32)
    ang = pos * expand_left(freqs, pos.ndim)            # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / misc
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    """tanh logit soft-capping (Gemma-2)."""
    return jnp.tanh(x / cap) * cap


ACT = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}
