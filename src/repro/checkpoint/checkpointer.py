"""Fault-tolerant checkpointing: atomic, async, mesh-independent.

Format: one directory per step containing
  arrays.npz      — flattened pytree leaves as full (unsharded) arrays
  meta.msgpack    — tree structure, step, leaf keys, user metadata

Properties required at 1000-node scale:
  * atomic: written to ``<dir>.tmp`` then os.rename'd — a crash mid-save
    never corrupts the latest checkpoint;
  * mesh-independent restore: leaves are saved as full arrays
    (process-gathered), so a checkpoint saved on a (16,16) mesh restores
    onto (2,16,16), (4,2) or a single device — elastic scaling;
  * async: ``save_async`` snapshots device arrays to host then writes in
    a daemon thread, overlapping I/O with the next training step;
  * retention: keep_last_k garbage collection;
  * resume: ``latest_step``/``restore`` give the auto-resume loop its
    restart point.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any

import jax
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep_last_k: int = 3):
        self.directory = directory
        self.keep_last_k = keep_last_k
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- paths -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: dict | None = None):
        self.wait()   # only one outstanding async save
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._write(step, host_tree, metadata or {})

    def save_async(self, step: int, tree: Any, metadata: dict | None = None):
        self.wait()
        # snapshot to host synchronously (cheap), write in background
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, metadata or {}),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, metadata: dict):
        leaves, treedef = _flatten(host_tree)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
        meta = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "metadata": metadata}
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep_last_k]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings` (optional pytree of
        NamedSharding) places leaves directly onto a (possibly different)
        mesh — the elastic-restore path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        data = np.load(os.path.join(d, "arrays.npz"))
        with open(os.path.join(d, "meta.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        leaves_like, treedef = _flatten(like)
        assert meta["n_leaves"] == len(leaves_like), \
            f"leaf count mismatch: ckpt {meta['n_leaves']} vs {len(leaves_like)}"
        leaves = [data[f"leaf_{i}"] for i in range(len(leaves_like))]
        for i, (l, ref) in enumerate(zip(leaves, leaves_like)):
            assert tuple(l.shape) == tuple(ref.shape), \
                f"leaf {i} shape {l.shape} != expected {ref.shape}"
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda x, ref: jax.numpy.asarray(x, dtype=ref.dtype),
                tree, like)
        return tree, meta["metadata"]
