"""GPipe-style pipeline parallelism over a "stage" mesh axis.

``pipeline_forward`` runs m microbatches through n_stages stages mapped
one-per-device: stage weights are sharded on their leading dim, and at
every tick each stage applies its ``stage_fn`` and forwards the
activation to the next stage with a collective-permute — the classic
(m + n_stages - 1)-tick schedule.  Output equals the sequential
composition stage_{n-1}(... stage_0(x)) per microbatch (verified in
test_multidevice.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map


def pipeline_forward(stage_fn, stage_weights, microbatches, mesh,
                     stage_axis: str = "stage"):
    """stage_weights: (n_stages, ...) sharded over `stage_axis`;
    microbatches: (m, mb, d) replicated.  Returns (m, mb, d)."""
    n_stages = int(mesh.shape[stage_axis])
    assert stage_weights.shape[0] == n_stages, \
        (stage_weights.shape, n_stages)
    m = microbatches.shape[0]
    ticks = m + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(w_l, xs):
        w_s = w_l[0]                            # this device's stage
        idx = jax.lax.axis_index(stage_axis)
        carry = jnp.zeros_like(xs[0])           # activation from prev stage
        outs = []
        for t in range(ticks):
            # stage 0 consumes microbatch t (garbage after the last one —
            # those bubble ticks never reach the final stage in time)
            feed = xs[min(t, m - 1)]
            inp = jnp.where(idx == 0, feed, carry)
            out = stage_fn(w_s, inp)
            outs.append(out)
            carry = jax.lax.ppermute(out, stage_axis, perm)
        outs = jnp.stack(outs)                  # (ticks, mb, d)
        # microbatch j leaves the last stage at tick j + n_stages - 1
        final = jnp.where(idx == n_stages - 1, outs, 0.0)
        final = jax.lax.psum(final, stage_axis)
        return final[n_stages - 1:n_stages - 1 + m]

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False,
    )(stage_weights, microbatches)
