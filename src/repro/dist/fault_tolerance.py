"""Fault tolerance for long training runs: auto-resume from the latest
checkpoint, bounded failure replay, straggler detection, preemption.

``resilient_train_loop`` is the single entry point used by the launchers
and examples: it restores from the checkpointer when checkpoints exist
(restarted worker), replays failed steps from the last checkpoint (the
data iterator is step-indexed, so replay is deterministic), and records
per-step wall time into a ``StragglerMonitor``.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable

import jax


class StragglerMonitor:
    """EWMA step-time tracker that flags outlier steps.

    A step slower than ``threshold * ewma`` (after ``warmup_steps``) is
    flagged via ``on_straggler(step, seconds)`` and is NOT folded into
    the EWMA — one straggler must not inflate the baseline and mask the
    next one.
    """

    def __init__(self, threshold: float = 2.0, warmup_steps: int = 5,
                 alpha: float = 0.1):
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.alpha = alpha
        self.ewma: float | None = None
        self.n = 0
        self.flagged: list[int] = []

    def record(self, step: int, seconds: float,
               on_straggler: Callable[[int, float], None] | None = None):
        if (self.ewma is not None and self.n >= self.warmup_steps
                and seconds > self.threshold * self.ewma):
            self.flagged.append(step)
            if on_straggler is not None:
                on_straggler(step, seconds)
            return
        self.ewma = (seconds if self.ewma is None
                     else self.ewma + self.alpha * (seconds - self.ewma))
        self.n += 1


class PreemptionHandler:
    """SIGTERM-aware graceful shutdown flag (cloud spot/preemptible VMs)."""

    SIGNALS = (signal.SIGTERM,)

    def __init__(self):
        self.preempted = False
        self._previous: dict[int, Any] = {}

    def _handler(self, signum, frame):
        self.preempted = True

    def install(self):
        for sig in self.SIGNALS:
            self._previous[sig] = signal.getsignal(sig)
            try:
                signal.signal(sig, self._handler)
            except ValueError:   # not on the main thread
                pass

    def uninstall(self):
        for sig, prev in self._previous.items():
            try:
                signal.signal(sig, prev)
            except ValueError:
                pass
        self._previous.clear()


def resilient_train_loop(*, train_step, state, data_iter, checkpointer,
                         total_steps: int, checkpoint_every: int = 100,
                         max_retries: int = 3,
                         fail_injector: Callable[[int], None] | None = None,
                         on_metrics: Callable[[int, dict], None] | None = None,
                         monitor: StragglerMonitor | None = None,
                         preemption: PreemptionHandler | None = None,
                         clock: Callable[[], float] = time.time):
    """Run ``train_step`` for ``total_steps`` steps with auto-resume.

    train_step(state, batch) -> (state, metrics); data_iter(step) -> batch.
    Checkpoints are labeled with the number of COMPLETED steps, written
    every ``checkpoint_every`` steps and at the end, so a restarted
    worker resumes exactly where the label says.  On a step failure the
    loop restores the last checkpoint (or the initial state) and replays;
    more than ``max_retries`` failures re-raises.

    ``clock`` is the injected time source feeding the straggler
    monitor's per-step durations (same convention as serve.Engine): the
    default is the wall clock, tests pass a fake for deterministic
    step-time sequences.

    Returns (state, monitor, completed_steps).
    """
    monitor = monitor or StragglerMonitor()
    initial = state
    start = 0
    latest = checkpointer.latest_step()
    if latest is not None and latest <= total_steps:
        state, _ = checkpointer.restore(state, step=latest)
        start = latest

    failures = 0
    step = start
    while step < total_steps:
        if preemption is not None and preemption.preempted:
            checkpointer.save(step, state)
            break
        t0 = clock()
        try:
            if fail_injector is not None:
                fail_injector(step)
            batch = data_iter(step)
            state, metrics = train_step(state, batch)
        except Exception:
            failures += 1
            if failures > max_retries:
                raise
            latest = checkpointer.latest_step()
            if latest is not None and latest <= total_steps:
                state, _ = checkpointer.restore(initial, step=latest)
                step = latest
            else:
                state = initial
                step = 0
            continue
        jax.block_until_ready(jax.tree.leaves(state)[0])
        monitor.record(step, clock() - t0)
        step += 1
        if on_metrics is not None:
            on_metrics(step, metrics)
        if step % checkpoint_every == 0 or step == total_steps:
            checkpointer.save(step, state)
    return state, monitor, step
