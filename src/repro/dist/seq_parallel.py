"""Sequence-parallel decode attention: the KV cache is sharded along its
sequence dimension over a mesh axis; each shard computes a partial
flash-style softmax over its local positions and the shards combine with
one pmax + two psums of (B, H, hd)-sized tensors — never gathering the
cache (the point of SP decode for 500k-token contexts).

Numerically identical to ``nn.attention.decode_attention`` (same mask,
scale, GQA head repeat); verified in test_multidevice.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                       # jax >= 0.6 moved shard_map
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

NEG_INF = -1e30


def sp_decode_attention(q, k_cache, v_cache, cache_len, mesh,
                        seq_axis: str = "data", *,
                        logit_cap: float = 0.0,
                        scale: float | None = None):
    """q: (B, 1, H, hd); caches: (B, S, KV, hd) sharded on S over
    `seq_axis`; cache_len: number of valid cache positions."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    scale = scale if scale is not None else hd ** -0.5
    n_shards = int(mesh.shape[seq_axis])
    s_local = k_cache.shape[1] // n_shards
    cache_len = jnp.asarray(cache_len, jnp.int32)

    def body(q_l, k_l, v_l):
        # local shard: positions [offset, offset + s_local)
        offset = jax.lax.axis_index(seq_axis) * s_local
        k_r = jnp.repeat(k_l.astype(jnp.float32), rep, axis=2)
        v_r = jnp.repeat(v_l.astype(jnp.float32), rep, axis=2)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_l.astype(jnp.float32),
                            k_r) * scale                       # (B,H,1,Sl)
        if logit_cap > 0:
            scores = jnp.tanh(scores / logit_cap) * logit_cap
        pos = offset + jnp.arange(s_local)
        valid = pos[None, :] < cache_len.reshape(-1, 1)        # (B,Sl)
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        m_loc = jnp.max(scores, axis=-1)                       # (B,H,1)
        m_glob = jax.lax.pmax(m_loc, seq_axis)
        p = jnp.exp(scores - m_glob[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, axis=-1)                            # (B,H,1)
        o_loc = jnp.einsum("bhqk,bkhd->bqhd", p, v_r)          # (B,1,H,hd)
        l_glob = jax.lax.psum(l_loc, seq_axis)
        o_glob = jax.lax.psum(o_loc, seq_axis)
        denom = jnp.maximum(l_glob, 1e-30)                     # (B,H,1)
        return (o_glob / denom.transpose(0, 2, 1)[..., None]).astype(q.dtype)

    spec_kv = P(None, seq_axis, None, None)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(), spec_kv, spec_kv),
        out_specs=P(),
        check_rep=False,
    )(q, k_cache, v_cache)
