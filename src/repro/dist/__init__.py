"""Distribution utilities: logical-axis sharding, fault tolerance,
sequence-parallel decode attention, pipeline parallelism."""
