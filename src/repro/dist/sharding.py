"""Logical-axis sharding: one vocabulary of axis names for every model.

Model code annotates arrays with *logical* axes ("batch", "fsdp", "tp",
"tp?", "vocab", "expert", "kv_seq", "kv_hd", None) via ``lsc`` — the
logical sharding constraint.  A ``Mapping`` binds those names to mesh
axes ("data", "model", optionally "pod") and is activated around the
jit'd region with ``activate``; with no active mapping every ``lsc`` is
the identity, so single-device code pays nothing and never imports mesh
machinery.

Resolution rules (mirrors the init-time spec trees in nn/transformer.py):

  "batch"   -> the mapping's batch axes (default ("data",))
  "fsdp"    -> ("data",) when Mapping.fsdp else replicated (zero-3)
  "tp"      -> ("model",)
  "tp?"     -> ("model",) if the dim is divisible by its size, else
               replicated (archs whose head counts don't divide TP)
  "vocab"   -> ("model",)  (embedding / lm-head vocab dim)
  "expert"  -> ("model",)  (expert-parallel MoE dispatch)
  "kv_seq"  -> Mapping.kv_seq_axis (sequence-parallel KV caches)
  "kv_hd"   -> Mapping.kv_hd_axis
  None      -> replicated

Every mapped axis is divisibility-checked and dropped (replicated) when
it does not divide the dim — GSPMD would otherwise reject the spec — and
a mesh axis is never assigned twice within one PartitionSpec.
"""
from __future__ import annotations

import contextlib
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: list["Mapping"] = []


class Mapping:
    """Binds logical axis names to the axes of a concrete mesh."""

    def __init__(self, mesh: Mesh, *, fsdp: bool = False,
                 batch_axes: Sequence[str] = ("data",),
                 kv_seq_axis: Sequence[str] | None = None,
                 kv_hd_axis: Sequence[str] | None = None):
        self.mesh = mesh
        self.fsdp = fsdp
        self.batch_axes = tuple(a for a in batch_axes
                                if a in mesh.axis_names)
        self.kv_seq_axis = tuple(kv_seq_axis) if kv_seq_axis else None
        self.kv_hd_axis = tuple(kv_hd_axis) if kv_hd_axis else None

    # -- logical -> mesh axis resolution --------------------------------
    def _axis_size(self, axes: tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= int(self.mesh.shape[a])
        return n

    def _resolve_one(self, name, dim: int, used: set[str]):
        if name is None:
            return None
        table = {
            "batch": self.batch_axes,
            "fsdp": ("data",) if self.fsdp else None,
            "tp": ("model",),
            "tp?": ("model",),
            "vocab": ("model",),
            "expert": ("model",),
            "kv_seq": self.kv_seq_axis,
            "kv_hd": self.kv_hd_axis,
        }
        axes = table.get(name)
        if not axes:
            return None
        axes = tuple(a for a in axes if a in self.mesh.axis_names
                     and a not in used)
        if not axes or dim % self._axis_size(axes) != 0:
            return None
        used.update(axes)
        return axes if len(axes) > 1 else axes[0]

    def spec(self, logical: Sequence, shape: Sequence[int]) -> P:
        """PartitionSpec for one array from its logical axes + shape."""
        if len(logical) != len(shape):
            # spec/shape rank mismatch (e.g. scalar with a stale spec):
            # replicate rather than guess.
            return P()
        used: set[str] = set()
        return P(*[self._resolve_one(n, d, used)
                   for n, d in zip(logical, shape)])

    def named(self, logical: Sequence, shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # -- tree-level helpers ---------------------------------------------
    def batch_sharding(self, tree):
        """Shard dim 0 of every leaf over the batch axes (replicate when
        not divisible); scalars replicated."""
        def one(x):
            shape = tuple(x.shape)
            if (not shape or not self.batch_axes
                    or shape[0] % self._axis_size(self.batch_axes) != 0):
                return self.replicated()
            first = (self.batch_axes if len(self.batch_axes) > 1
                     else self.batch_axes[0])
            return NamedSharding(
                self.mesh, P(first, *([None] * (len(shape) - 1))))
        return jax.tree.map(one, tree)

    def shardings(self, spec_tree, shape_tree):
        """NamedSharding pytree for `shape_tree` (arrays or
        ShapeDtypeStructs), resolving each leaf's spec by walking
        `spec_tree` along the leaf's path.

        The walk is tolerant of structural mismatch: path entries with no
        matching key in the spec tree (optimizer-state wrappers, scan
        stacking, list indices) are skipped, so one param-spec tree
        serves params, Adam moments, and velocity states alike.  Leaves
        whose walk does not end on a spec tuple are replicated.
        """
        flat = jax.tree_util.tree_flatten_with_path(shape_tree)[0]
        treedef = jax.tree.structure(shape_tree)
        out = []
        for path, leaf in flat:
            spec = _walk(spec_tree, path)
            if isinstance(spec, tuple) and _is_leaf_spec(spec):
                out.append(self.named(spec, tuple(leaf.shape)))
            else:
                out.append(self.replicated())
        return jax.tree.unflatten(treedef, out)


def _is_leaf_spec(t) -> bool:
    return all(e is None or isinstance(e, str) for e in t)


def _path_name(entry):
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return getattr(entry, attr)
    return None


def _walk(spec_tree, path):
    node = spec_tree
    for entry in path:
        if isinstance(node, tuple) and _is_leaf_spec(node):
            break                      # broadcast a leaf spec downward
        name = _path_name(entry)
        if isinstance(node, dict) and name in node:
            node = node[name]
    return node


def serve_mapping(mesh: Mesh, *, kv: str = "hd",
                  batch_axes: Sequence[str] = ("data",),
                  fsdp: bool = False) -> Mapping:
    """Mapping preset for the sharded serving engine (DESIGN.md §8).

    Tensor parallelism always binds ``"tp"``/``"tp?"``/``"vocab"``/
    ``"expert"`` to the mesh's "model" axis; the ``kv`` argument picks
    how the decode KV cache is laid out:

      * ``"hd"``  — TP over the cache's head dims: the KV-head count
        dim ("tp?") takes "model" whenever the TP size divides the
        KV-head count — attention then stays whole per head and
        sharded decode is BIT-identical to the single-host path — and
        ``kv_hd`` (the head_dim) is the fallback axis when it does not
        (GQA head counts below TP), where the float score contraction
        reassociates across shards: numerically equivalent, not
        bit-exact (DESIGN.md §8);
      * ``"seq"`` — sequence parallelism (``kv_seq`` → "model"): the
        cache's sequence dim is sharded, the per-step softmax reduces
        across shards (also allclose, not bit-exact).  Pair it with
        ``ModelConfig.kv_onehot_write`` so the per-token cache write
        stays shard-local.

    ``fsdp`` defaults to False for serving: decode wants whole weight
    shards resident, not zero-3 gathering per step."""
    if kv == "hd":
        return Mapping(mesh, fsdp=fsdp, batch_axes=batch_axes,
                       kv_hd_axis=("model",))
    if kv == "seq":
        return Mapping(mesh, fsdp=fsdp, batch_axes=batch_axes,
                       kv_seq_axis=("model",))
    raise ValueError(f"kv must be 'hd' or 'seq', got {kv!r}")


def train_state_specs(param_specs):
    """Spec tree for ``train.step.init_state`` output: params and the
    (param-shaped) optimizer moments share the param specs; step counters
    replicate.  Works for any optimizer whose state leaves either mirror
    the param tree or are scalars (see Mapping.shardings' tolerant walk).
    """
    return {"params": param_specs, "opt": param_specs, "step": ()}


# ---------------------------------------------------------------------------
# activation + the logical sharding constraint
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def activate(mapping: Mapping):
    """Make `mapping` visible to ``lsc`` calls inside jit traces."""
    _ACTIVE.append(mapping)
    try:
        yield mapping
    finally:
        _ACTIVE.pop()


def current_mapping() -> Mapping | None:
    return _ACTIVE[-1] if _ACTIVE else None


def lsc(x, *logical):
    """Logical sharding constraint: identity without an active mapping."""
    m = current_mapping()
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, m.named(logical, tuple(x.shape)))


def lsc_tree(tree, spec_tree):
    """Tree-wide ``lsc`` from an init-time spec tree (e.g. cache specs)."""
    m = current_mapping()
    if m is None:
        return tree
    sh = m.shardings(spec_tree, tree)
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, sh)
