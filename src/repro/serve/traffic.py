"""Replayable bursty traffic for the serving engine (DESIGN.md §10).

Arrival streams for chaos/robustness experiments must be replayable
the same way faults are: ``TrafficGenerator.arrivals(tick)`` is a pure
function of ``(seed, tick)`` — the per-tick PRNG is
``np.random.default_rng((seed, tick))``, so tick 37's arrivals are the
same whether the whole trace is replayed or the generator is asked for
that one tick, and a chaos scenario (traffic + fault plan) is fully
pinned by two seeds.

Load shape: per-tick Poisson arrivals at ``rate_per_tick``, multiplied
by any active ``(start_tick, end_tick, multiplier)`` spike window —
the classic base-load-plus-burst shape SLO studies use.  Each arrival
draws a ``TrafficClass`` (weighted), which fixes its prompt length,
decode budget, and the TTFT/e2e SLOs the engine's deadline eviction
enforces.  ``slo_report`` scores a finished run per class — the
availability / attainment numbers BENCH_resilience.json reports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

from .engine import Request


@dataclass(frozen=True)
class TrafficClass:
    """One request class: its size and its service-level objectives.

    SLOs are in injected-clock seconds (None = no deadline); weight is
    the class's relative share of arrivals; ``budget_share`` is its
    slice of the scheduler's global energy budget (DESIGN.md §13) —
    None opts the class out of per-class budgeting (all-None classes
    leave the scheduler on one global budget)."""
    name: str
    ttft_slo_s: float | None = None
    e2e_slo_s: float | None = None
    prompt_len: int = 8
    max_new_tokens: int = 16
    temperature: float = 0.0
    weight: float = 1.0
    budget_share: float | None = None


def class_budget_shares(classes: Sequence[TrafficClass]) -> dict:
    """The ``{name: share}`` mapping for
    ``PowerBudgetScheduler.set_class_budgets``, from the classes that
    declare a ``budget_share``; classes without one default to their
    arrival ``weight`` when ANY class declares a share (so a partial
    declaration still covers the whole mix).  Empty when no class
    declares a share — per-class budgeting stays off."""
    if not any(c.budget_share is not None for c in classes):
        return {}
    return {c.name: (c.budget_share if c.budget_share is not None
                     else c.weight)
            for c in classes}


class TrafficGenerator:
    """Seeded Poisson/spike arrival process over weighted classes.

    classes: the TrafficClass mix (weights need not sum to 1).
    rate_per_tick: base mean arrivals per engine tick.
    spikes: ``(start_tick, end_tick, multiplier)`` windows; a tick in
        [start, end) multiplies the base rate (overlaps compound).
    vocab_size: prompts are uniform token draws from [1, vocab_size).
    seed: the replay key — same seed, same trace, any access order.
    """

    def __init__(self, classes: Sequence[TrafficClass], *,
                 rate_per_tick: float = 1.0, seed: int = 0,
                 vocab_size: int = 64,
                 spikes: Iterable[tuple[int, int, float]] = ()):
        assert classes, "need at least one TrafficClass"
        self.classes = tuple(classes)
        self.rate_per_tick = float(rate_per_tick)
        self.seed = int(seed)
        self.vocab_size = int(vocab_size)
        self.spikes = tuple((int(a), int(b), float(m))
                            for a, b, m in spikes)
        w = np.asarray([c.weight for c in self.classes], np.float64)
        assert (w > 0).all(), "class weights must be positive"
        self._p = w / w.sum()

    def rate_at(self, tick: int) -> float:
        rate = self.rate_per_tick
        for start, end, mult in self.spikes:
            if start <= tick < end:
                rate *= mult
        return rate

    def arrivals(self, tick: int) -> list[Request]:
        """The requests arriving at ``tick`` — deterministic in
        ``(seed, tick)`` alone.  rids encode ``(tick, index)`` so every
        request in a trace is globally unique and self-describing."""
        rng = np.random.default_rng((self.seed, tick))
        n = int(rng.poisson(self.rate_at(tick)))
        out = []
        for i in range(n):
            c = self.classes[int(rng.choice(len(self.classes), p=self._p))]
            prompt = rng.integers(1, self.vocab_size, size=c.prompt_len,
                                  dtype=np.int64).astype(np.int32)
            out.append(Request(
                rid=(tick << 16) | i, prompt=prompt,
                max_new_tokens=c.max_new_tokens,
                temperature=c.temperature,
                ttft_slo_s=c.ttft_slo_s, e2e_slo_s=c.e2e_slo_s,
                cls=c.name))
        return out


def slo_report(requests: Iterable[Request]) -> dict:
    """Per-class and overall service scorecard over a finished run.

    availability = served / offered (rejected + expired count against
    it); slo_attainment = among SERVED requests, the fraction whose
    stamps met their class SLOs (no-deadline classes trivially
    attain)."""
    per_cls: dict[str, dict] = {}
    for r in requests:
        row = per_cls.setdefault(r.cls, {
            "offered": 0, "served": 0, "rejected": 0, "expired": 0,
            "failed": 0, "slo_met": 0})
        row["offered"] += 1
        if r.status in ("rejected", "expired", "failed"):
            row[r.status] += 1
            continue
        row["served"] += 1
        ok = True
        if (r.ttft_slo_s is not None and r.first_token_at is not None
                and r.submitted_at is not None):
            ok &= r.first_token_at - r.submitted_at <= r.ttft_slo_s
        if (r.e2e_slo_s is not None and r.finished_at is not None
                and r.submitted_at is not None):
            ok &= r.finished_at - r.submitted_at <= r.e2e_slo_s
        row["slo_met"] += int(ok)
    total = {k: sum(row[k] for row in per_cls.values())
             for k in ("offered", "served", "rejected", "expired",
                       "failed", "slo_met")}
    def _rates(row):
        served = row["served"]
        return dict(row,
                    availability=(row["served"] / row["offered"]
                                  if row["offered"] else 1.0),
                    slo_attainment=(row["slo_met"] / served
                                    if served else 1.0))
    return {"classes": {name: _rates(row)
                        for name, row in sorted(per_cls.items())},
            "total": _rates(total)}
