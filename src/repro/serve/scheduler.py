"""Online power-budget scheduler — the paper's dynamic power control
closed at serve time (DESIGN.md §7).

``PowerBudgetScheduler`` consumes a joules/token budget (in pJ) and
retunes a live ``Engine``'s error-config pool every ``retune_every``
ticks over the full allocation space the engine exposes — per layer,
per expert, per neuron group: one key per cell of the engine's
(n_layers[, cfg_experts][, cfg_groups]) config tensor.  Allocation is
the SAME greedy saving/degradation-ratio core the offline
``DynamicPowerController.allocate`` runs (``core.controller
.greedy_allocate``) with two online twists:

  * the stop rule is the energy budget: upgrades stop as soon as the
    modeled joules/token (``power_model.energy_per_token_pj``, the same
    integral ``Engine.energy_report`` charges — expert-collapsed dense
    share included) meets the budget, then a refinement pass steps the
    most-degrading keys back DOWN while the budget still holds, so the
    pool converges to the budget from below instead of overshooting;
  * degradation is DRIVEN BY MEASURED FEEDBACK, not the static MRED
    table: every ``probe_every``-th decode step re-runs the pool's step
    at the exact config on the pre-step cache — through the SAME
    compiled decode executable, zero retraces — and scores greedy-token
    agreement on one sampled slot.  Disagreements update per-(key, cfg)
    degradation estimates (EWMA, floored at a fraction of the MRED
    prior so the model is never fully forgotten).

Hysteresis/backoff: ``hysteresis`` consecutive disagreeing probes step
the OFFENDING key — the one with the highest estimated degradation at
its current config — down exactly ONE probe config
(``controller.step_down_config``), pin it there for ``hold_ticks``
ticks, and charge its estimate with the full disagreement budget.  A
burst of disagreement costs one notch of saving on one key, never the
pool (the same one-notch rule as the offline validation backoff).
Estimates of (key, config) pairs that are not currently executing
relax toward the MRED prior at ``recover`` per retune (they receive no
probe signal — this is also what un-bans a backed-off config once its
hold expires; injected ``sensitivity`` tables relax the same way, pass
``recover=0`` to pin them).

Shadow probes are measurement, not service traffic — but they ARE real
executed decodes, so they are billed: each probe adds a ``kind="probe"``
row to ``engine.energy_log`` (whose rows sum to the report totals),
while staying OUT of the serve-only counters the budget integral reads
(``engine.serve_mac_energy_pj_per_param`` /
``engine.n_serve_tokens_charged``).  Measurement overhead is accounted
for without ever reading as service traffic (the modeled overhead is one
extra decode step per ``probe_every`` ticks).

Speculative decoding (PR 9, DESIGN.md §12) gives the scheduler a second
control axis: ``Engine(spec=...)`` calls ``configure_spec`` and feeds
per-slot draft acceptance through ``record_spec``, which attributes
agreement to the executed DRAFT config via the same ``record_probe``/
EWMA plumbing (``ladder=False`` — expected draft disagreement must never
back the POOL assignment off) and runs the draft depth ``k`` through the
same one-notch hysteresis: zero-acceptance bursts step ``draft_k`` down
(floor 1), hold, then recover one notch per retune.

Per-class budgets (PR 10, DESIGN.md §13): ``set_class_budgets({cls:
frac})`` splits the budget across traffic classes.  Class c's pJ/token
target is ``share_c / mix_c * B`` (mix = its measured token share), so
the token-weighted sum of class targets is always exactly the global
budget and the planner still plans ONE pool; each retune diffs the
engine's per-class serve counters (``serve_energy_by_class`` /
``serve_tokens_by_class``, fed by ``energy_log`` class attribution) and
re-splits the shares from measured usage (``resplit_shares``) —
unspent budget flows to starved classes, floors guarantee a minimum
slice.  All smoothed signals — the probe-agreement window, the backoff
streaks, the measured-energy median and its spike early-warning — read
through ``serve.telemetry`` (no ad-hoc EWMA/streak state).

Usage::

    sched = PowerBudgetScheduler(budget_pj_per_token=0.8 * exact_pj)
    eng = Engine(params, cfg, scheduler=sched)
    ... submit/run ...
    sched.report()   # budget vs measured pJ/token, agreement, history
"""
from __future__ import annotations

from collections import deque
from typing import Any, Mapping

import jax.numpy as jnp
import numpy as np

from repro.core.approx_multiplier import N_CONFIGS
from repro.core.controller import (Candidate, greedy_allocate,
                                   step_down_config)
from repro.core.error_metrics import mred_table
from repro.core.power_model import (ENERGY_PER_MAC_PJ, MAC_SAVING_FRAC,
                                    energy_per_token_pj, error_rank)
from repro.serve.telemetry import (RollingWindow, SpikeDetector, Streak,
                                   ewma)

# every non-exact config is an allocation rung by default: the ladder's
# consecutive saving gaps bound how closely the budget can be tracked
DEFAULT_LADDER = tuple(range(1, N_CONFIGS))


def resplit_shares(shares: Mapping[str, float],
                   usage: Mapping[str, float],
                   floors: Mapping[str, float]) -> dict[str, float]:
    """Re-split per-class budget shares from measured usage.

    ``usage[c]`` is class c's measured-over-target energy ratio for the
    last window (> 1 = the class ran hot / was starved by its split,
    < 1 = it left budget unspent; missing = no signal, treated as 1.0).
    The raw re-split is ``share_c * usage_c`` — unspent budget flows
    from under-using classes to hot ones — renormalized to sum EXACTLY
    to 1 with iterative floor-pinning: any class whose renormalized
    share would fall below its floor is pinned AT the floor and the
    remaining mass is split proportionally among the rest, so a quiet
    class can never be starved out of its guaranteed slice.  Pure
    function (property-tested: output sums to 1 and respects every
    floor whenever the floors themselves sum to ≤ 1)."""
    names = sorted(shares)
    assert names, "no classes to split across"
    floors = {c: max(float(floors.get(c, 0.0)), 0.0) for c in names}
    raw = {c: max(float(shares[c]) * float(usage.get(c, 1.0)), 0.0)
           for c in names}
    pinned: set[str] = set()
    for _ in range(len(names)):
        free = [c for c in names if c not in pinned]
        mass = 1.0 - sum(floors[c] for c in pinned)
        tot = sum(raw[c] for c in free)
        if not free or tot <= 0.0 or mass <= 0.0:
            break
        out = {c: floors[c] for c in pinned}
        out.update({c: mass * raw[c] / tot for c in free})
        low = [c for c in free if out[c] < floors[c]]
        if not low:
            return out
        pinned.update(low)
    # degenerate (every class at its floor, zero usage everywhere, or
    # oversubscribed floors): scale the floors themselves to sum 1
    tot = sum(floors.values())
    if tot > 0.0:
        return {c: floors[c] / tot for c in names}
    return {c: 1.0 / len(names) for c in names}


class _EnergyState:
    """Incremental joules/token evaluator over one config tensor.

    ``trial(key, c)`` — the energy if cell `key` were set to `c` — runs
    in O(1) (O(E) with an expert axis: only that (layer, group)'s
    collapse column changes), instead of the O(cells) rebuild
    ``energy_per_token_pj`` does; planning loops that scan every key
    per iteration stay linear in the key space.  ``commit`` re-syncs
    the sums exactly from the tensor (commits are rare — one per
    accepted upgrade/step-down), so no float drift accumulates and
    ``energy()`` is bit-identical to ``energy_per_token_pj``."""

    def __init__(self, vec, macs_per_token: float, moe_mac_frac: float):
        self.macs = float(macs_per_token)
        self.f = float(moe_mac_frac)
        self.vec = np.array(vec, np.int64)
        self._sync()

    def _sync(self):
        E = ENERGY_PER_MAC_PJ
        self.total = float(E[self.vec].sum())
        if self.vec.ndim >= 3:
            idx = np.argmin(error_rank()[self.vec], axis=-2)
            self.collapsed = np.take_along_axis(
                self.vec, np.expand_dims(idx, -2), axis=-2)[..., 0, :]
            self.csum = float(E[self.collapsed].sum())

    def _energy(self, total: float, csum: float) -> float:
        per_mac = total / self.vec.size
        if self.vec.ndim >= 3:
            per_mac = (self.f * per_mac
                       + (1.0 - self.f) * (csum / self.collapsed.size))
        return self.macs * per_mac

    def energy(self) -> float:
        return self._energy(self.total, getattr(self, "csum", 0.0))

    def trial(self, key: tuple, c: int) -> float:
        E = ENERGY_PER_MAC_PJ
        total = self.total - float(E[self.vec[key]]) + float(E[c])
        if self.vec.ndim < 3:
            return self._energy(total, 0.0)
        l, e_ix, g = key
        col = self.vec[l, :, g].copy()
        col[e_ix] = c
        newc = col[np.argmin(error_rank()[col])]
        csum = (self.csum - float(E[self.collapsed[l, g]])
                + float(E[newc]))
        return self._energy(total, csum)

    def commit(self, key: tuple, c: int):
        self.vec[key] = c
        self._sync()


class PowerBudgetScheduler:
    """Budget-aware retuner for ``serve.engine.Engine`` (one engine per
    scheduler instance; see module docstring for the control law).

    Knobs (feedback-state keys follow the engine's (layer[, expert][,
    group]) config-key convention — see serve/engine.py):

    budget_pj_per_token: the energy target, in picojoules per generated
        token (compare ``power_model.energy_per_token_pj``); retargets
        live via ``set_budget``.
    retune_every (ticks, default 8): full re-plan + engine retune
        cadence, in engine ticks (decode steps with active slots).
    probe_every (decode steps, default 2): shadow-probe cadence — every
        N-th decode step is re-run at the exact config to measure token
        agreement (overhead: 1/N extra decode steps).
    probe_configs (default 1..31): the allocation ladder — configs the
        planner may assign and backoff steps down through.
    agreement_target (fraction, default 0.99): quality floor; 1 - target
        is the disagreement budget greedy allocation may spend, and a
        backed-off config's estimate is charged up to it.
    hysteresis (probes, default 3): consecutive disagreeing probes that
        trigger a one-notch backoff of the offending key.
    hold_ticks (ticks, default 64): how long a backed-off key's probe
        ladder stays capped at its stepped-down config.
    ema (fraction, default 0.25): probe-feedback EWMA weight on the
        per-(key, config) degradation estimates.
    recover (fraction/retune, default 0.05): how fast non-executing
        estimates relax toward the MRED prior at each retune (0 pins
        injected sensitivities).
    prior_scale / prior_floor (defaults 0.05 / 0.25): scale of the
        MRED-proportional degradation prior, and the floor under decayed
        estimates as a fraction of that prior.
    sensitivity: optional {(key, config): degradation} table seeding the
        estimates (e.g. from an offline calibration run).
    seed (default 0): probe slot-sampling PRNG seed.

    The scheduler is sharding-agnostic: on an ``Engine(mapping=...)``
    (DESIGN.md §8) its probes run through the same mesh-compiled decode
    executable and its retunes write the replicated config tensor, so
    one scheduler instance retunes every shard at once — zero retraces
    either way (tests/test_sharded_serving.py)."""

    def __init__(self, budget_pj_per_token: float, *,
                 retune_every: int = 8, probe_every: int = 2,
                 probe_configs=DEFAULT_LADDER,
                 agreement_target: float = 0.99, hysteresis: int = 3,
                 hold_ticks: int = 64, ema: float = 0.25,
                 recover: float = 0.05,
                 prior_scale: float = 0.05, prior_floor: float = 0.25,
                 sensitivity: Mapping[tuple, float] | None = None,
                 seed: int = 0):
        assert 0 < probe_every and 0 < retune_every
        self.budget_pj_per_token = float(budget_pj_per_token)
        # brownout composition (DESIGN.md §10): an external degradation
        # controller scales the budget instead of writing configs — one
        # writer per knob, the planner keeps its feedback state
        self.budget_scale = 1.0
        self.retune_every = int(retune_every)
        self.probe_every = int(probe_every)
        self.probe_configs = [c for c in probe_configs
                              if 1 <= c < N_CONFIGS]
        self.agreement_target = float(agreement_target)
        self.hysteresis = int(hysteresis)
        self.hold_ticks = int(hold_ticks)
        self.ema = float(ema)
        self.recover = float(recover)
        self.prior_scale = float(prior_scale)
        self.prior_floor = float(prior_floor)
        self._rng = np.random.default_rng(seed)

        # allocation space (set by bind/attach)
        self.engine = None
        self.shape: tuple | None = None
        self.keys: list[tuple] = []
        self.macs_per_token = 1.0
        self.moe_mac_frac = 0.0
        self.assignment: dict[tuple, int] = {}

        # online state — every smoothed/streaked signal reads through
        # serve.telemetry (DESIGN.md §13): the probe-agreement window,
        # the pool and draft-depth hysteresis streaks, the measured-
        # pJ/token window with its spike early-warning
        self.est: dict[tuple, float] = dict(sensitivity or {})
        self.hold: dict[tuple, tuple[int, int]] = {}  # key -> (cap, expiry)
        self.tick = 0
        self.n_probes = 0
        self.n_agree = 0
        self.agree_window = RollingWindow(maxlen=4096)  # since last retune
        self.pool_streak = Streak()
        self.n_backoffs = 0
        # speculative draft-depth axis (PR 9): configured by
        # Engine(spec=...) via configure_spec; None = speculation off
        self.draft_k: int | None = None
        self._k0: int | None = None
        self.spec_streak = Streak()
        self._k_hold_until = 0
        self._mark = (0.0, 0)          # (pj_per_param, tokens) at last retune
        # measured-energy telemetry: windowed median over retunes plus
        # a MAD spike detector on measured/budget (scale-free), whose
        # firing is surfaced in the retune history as an early warning
        self.measured_window = RollingWindow(maxlen=64)
        self.measured_spike = SpikeDetector(window=32, threshold=4.0,
                                            min_scale=0.02, min_samples=4)
        # per-class budget splits (set_class_budgets): shares over
        # traffic-class names, re-split each retune from measured
        # per-class energy; empty = one global budget
        self.class_shares: dict[str, float] = {}
        self._class_base: dict[str, float] = {}
        self._class_floor_frac = 0.25
        self._class_marks: dict[str, tuple[float, int]] = {}
        self.class_report: dict[str, dict] = {}
        # bounded audit window (one entry per retune/backoff): the
        # counters above carry the lifetime stats
        self.history: deque = deque(maxlen=4096)

    # -- binding ---------------------------------------------------------
    def bind(self, shape, macs_per_token: float = 1.0,
             moe_mac_frac: float = 0.0, initial=None) -> None:
        """Fix the allocation space: one key per cell of the engine's
        config tensor.  Standalone entry point for offline use/tests;
        ``attach`` wires it to a live engine."""
        self.shape = tuple(shape)
        self.keys = [tuple(ix) for ix in np.ndindex(self.shape)]
        self.macs_per_token = float(macs_per_token)
        self.moe_mac_frac = float(moe_mac_frac)
        init = np.zeros(self.shape, np.int32) if initial is None \
            else np.asarray(initial, np.int32)
        self.assignment = {k: int(init[k]) for k in self.keys}

    def attach(self, engine) -> None:
        """Called by ``Engine.__init__`` (``Engine(scheduler=...)``)."""
        assert self.engine is None, "scheduler already attached"
        self.engine = engine
        self.bind(engine.approx_cfg.shape, engine.macs_per_token,
                  engine._moe_mac_frac, initial=engine.approx_cfg)
        self._mark = self._serve_counters(engine)
        if self.class_shares:
            self._mark_classes(engine)

    @staticmethod
    def _serve_counters(engine) -> tuple[float, int]:
        """The SERVE-traffic energy integral (excludes kind="probe"
        rows) — the measured-pJ/token window must not count the
        scheduler's own probe decodes as service output.  getattr
        fallback: the scheduler also runs against engine stubs that
        predate the serve-only counters."""
        e = getattr(engine, "serve_mac_energy_pj_per_param",
                    engine.mac_energy_pj_per_param)
        n = getattr(engine, "n_serve_tokens_charged",
                    engine.n_tokens_charged)
        return float(e), int(n)

    # -- degradation model ----------------------------------------------
    def _prior(self, config: int) -> float:
        """MRED-proportional prior on one key's disagreement
        contribution (the offline controller's interpolate-from-the-
        table fallback, split across keys so the sum over the pool
        stays comparable to a probability)."""
        return (self.prior_scale * float(mred_table()[config])
                / max(len(self.keys), 1))

    def _delta(self, key: tuple, config: int) -> float:
        if config == 0:
            return 0.0
        return self.est.get((key, config), self._prior(config))

    # -- config algebra helpers ------------------------------------------
    def _tensor(self, assignment: Mapping[tuple, int]) -> np.ndarray:
        vec = np.zeros(self.shape, np.int32)
        for k, c in assignment.items():
            vec[k] = c
        return vec

    def _energy_pj(self, assignment: Mapping[tuple, int]) -> float:
        return energy_per_token_pj(self._tensor(assignment),
                                   self.macs_per_token,
                                   self.moe_mac_frac)

    def _ladder(self, key: tuple) -> list[int]:
        """Probe ladder for one key, capped while a backoff hold is
        active (the key may not climb above its stepped-down config
        until the hold expires)."""
        cap = self.hold.get(key)
        if cap is None:
            return self.probe_configs
        top = MAC_SAVING_FRAC[cap[0]]
        return [c for c in self.probe_configs
                if MAC_SAVING_FRAC[c] <= top]

    # -- planning --------------------------------------------------------
    def plan(self) -> dict[tuple, int]:
        """One full allocation pass over the current feedback state:
        greedy-by-ratio upgrades until the energy budget is met (or the
        disagreement budget 1 - agreement_target is spent), then
        step-down refinement while the budget still holds."""
        assert self.shape is not None, "bind()/attach() first"
        budget = self.budget_pj_per_token * self.budget_scale
        cands = [Candidate(k, c, self._delta(k, c),
                           float(MAC_SAVING_FRAC[c]))
                 for k in self.keys for c in self._ladder(k)]
        loss_budget = max(1.0 - self.agreement_target, 0.0)
        # incremental energy state tracks greedy's assignment (all-exact
        # start; one commit per accepted upgrade, passed by the core)
        state = _EnergyState(np.zeros(self.shape, np.int64),
                             self.macs_per_token, self.moe_mac_frac)

        def stop(assignment, accepted):
            if accepted is not None:
                state.commit(accepted.key, accepted.config)
            return state.energy() <= budget

        assignment, _ = greedy_allocate(self.keys, cands, loss_budget,
                                        stop=stop)
        # refinement: recover accuracy (and close the gap to the budget
        # from below) by stepping keys back down one probe config at a
        # time while the energy stays within budget.  O(1)/O(E) trials
        # against the incremental state — no per-candidate rebuilds
        state = _EnergyState(self._tensor(assignment),
                             self.macs_per_token, self.moe_mac_frac)
        while True:
            best = None
            for k in self.keys:
                cur = assignment[k]
                if cur == 0:
                    continue
                down = step_down_config(cur, self._ladder(k))
                if state.trial(k, down) > budget:
                    continue
                gain = self._delta(k, cur) - self._delta(k, down)
                if gain < 0:
                    continue
                # most degradation recovered; ties toward the smallest
                # saving give-up (stay closest to the budget)
                rank = (gain, -(MAC_SAVING_FRAC[cur]
                                - MAC_SAVING_FRAC[down]))
                if best is None or rank > best[0]:
                    best = (rank, k, down)
            if best is None:
                break
            _, k, down = best
            prev = assignment[k]
            state.commit(k, down)
            if state.energy() > budget:   # ulp-edge guard: a trial may
                state.commit(k, prev)     # differ from the exact sum in
                break                     # the last bit
            assignment[k] = down
        return assignment

    # -- engine hooks ----------------------------------------------------
    def on_step(self, engine, active, cache, token, logits,
                pool_cfg, multiplicity: int = 1) -> None:
        """Decode-step hook: every ``probe_every``-th step, shadow-decode
        the SAME pre-step state at the exact config (same compiled
        executable — the config is a traced argument) and score greedy-
        token agreement on one sampled active slot.  An all-exact pool
        has nothing to measure (the probe would compare exact against
        exact), so it costs nothing.

        ``multiplicity`` is the chaos-faulted telemetry delivery count
        (faults.probe_multiplicity: 0 = dropped, 2 = duplicated).
        At-least-once delivery duplicates the RECORDED outcome, never
        the probe compute: the exact-config decode runs exactly once
        per probed step, whatever the delivery count (satellite fix —
        the engine used to loop this whole hook, re-executing the
        shadow decode per duplicate)."""
        if multiplicity <= 0:
            return
        if engine.n_decode_steps % self.probe_every:
            return
        if not np.any(pool_cfg):
            return
        exact = np.zeros_like(pool_cfg)
        # _replicate keeps the probe's operand shardings identical to
        # the serving call's on a sharded engine (same executable)
        probe_logits, _ = engine._decode(engine.params, cache,
                                         jnp.asarray(token),
                                         engine._replicate(exact))
        # the probe is a real executed exact-config decode: bill it
        # (kind="probe" — in energy_log totals, out of serve counters)
        engine._count_energy(len(active), exact, "probe")
        slot = int(self._rng.choice(active))
        got = int(np.argmax(np.asarray(logits)[slot]))
        want = int(np.argmax(np.asarray(probe_logits)[slot]))
        for _ in range(int(multiplicity)):
            self.record_probe(got == want, pool_cfg)

    def record_probe(self, agree: bool, executed_cfg=None, *,
                     ladder: bool = True) -> None:
        """Fold one probe outcome into the feedback state (public so
        tests — or an external quality signal — can inject outcomes):
        EWMA-update the degradation estimates of the configs that
        EXECUTED and run the hysteresis counter; a ``hysteresis``-long
        disagreement burst triggers a one-notch backoff of the
        offending key.

        ``executed_cfg`` is the config tensor the probed step actually
        ran — the POOL config, which pinned requests can hold below the
        scheduler's assignment.  Feedback lands on those executed
        (key, config) cells only: an agreement measured at a
        pinned-down config says nothing about the assignment's (more
        aggressive) configs, so those estimates are left alone.
        Defaults to the current assignment (the no-pins case).

        ``ladder=False`` updates the estimates WITHOUT feeding the
        pool's backoff hysteresis — speculative draft feedback
        (``record_spec``) measures the DRAFT config, and its expected
        disagreement must never step the pool assignment down (the
        draft depth has its own hysteresis axis)."""
        self.n_probes += 1
        self.n_agree += int(agree)
        self.agree_window.push(1.0 if agree else 0.0)
        r = 0.0 if agree else 1.0
        ran = (self._tensor(self.assignment) if executed_cfg is None
               else np.asarray(executed_cfg))
        up = [k for k in self.keys if ran[k] > 0]
        if up:
            # split the observation across executed upgraded keys by
            # their current suspicion share, so sum(est) tracks
            # P(disagree)
            d = np.asarray([max(self._delta(k, int(ran[k])), 1e-9)
                            for k in up])
            w = d / d.sum()
            for k, wk in zip(up, w):
                cfg_k = int(ran[k])
                cur = self._delta(k, cfg_k)
                # never forget the model entirely: floor at a fraction
                # of the MRED prior
                self.est[(k, cfg_k)] = max(
                    ewma(cur, r * float(wk), self.ema),
                    self.prior_floor * self._prior(cfg_k))
        if not ladder:
            return
        if self.pool_streak.observe(not agree) >= self.hysteresis:
            self._backoff(ran)
            self.pool_streak.reset()

    # -- speculative draft-depth axis (PR 9) -----------------------------
    def configure_spec(self, k: int) -> None:
        """Arm the draft-depth control axis at depth ``k`` (called by
        ``Engine.__init__``/``set_spec`` when speculation is on)."""
        self._k0 = int(k)
        self.draft_k = int(k)
        self.spec_streak.reset()

    def record_spec(self, accepted: int, k: int, draft_cfg) -> None:
        """Fold one slot's speculative acceptance into the feedback
        state: ``accepted`` of the ``k`` drafts agreed with the
        verifier.  Each agreement/disagreement lands on the executed
        DRAFT config's (key, cfg) cells through the same
        ``record_probe``/EWMA plumbing as the shadow probes — with
        ``ladder=False``, so expected draft disagreement never backs
        the POOL assignment off.  The draft depth is its own one-notch
        hysteresis axis: ``hysteresis`` consecutive zero-acceptance
        ticks step ``draft_k`` down one (floor 1) and hold it for
        ``hold_ticks``; ``on_tick`` recovers one notch per retune once
        the hold expires."""
        ran = np.asarray(draft_cfg)
        for _ in range(int(accepted)):
            self.record_probe(True, ran, ladder=False)
        if accepted < k:
            self.record_probe(False, ran, ladder=False)
        if self.draft_k is None:
            return
        streak = self.spec_streak.observe(accepted == 0)
        if streak >= self.hysteresis and self.draft_k > 1:
            self.draft_k -= 1
            self.spec_streak.reset()
            self._k_hold_until = self.tick + self.hold_ticks
            self.history.append({"event": "spec_backoff",
                                 "tick": self.tick,
                                 "draft_k": int(self.draft_k)})

    def _backoff(self, ran: np.ndarray) -> None:
        """Step the offending key down exactly ONE probe config and hold
        it there — a disagreement burst never resets the pool.  Only
        keys whose config actually EXECUTED in the probed steps (and
        that the scheduler has upgraded) are candidates: disagreement
        produced solely by pinned requests' own configs is their
        owners' choice, not the assignment's fault."""
        up = [k for k in self.keys
              if ran[k] > 0 and self.assignment.get(k, 0) > 0]
        if not up:
            return
        worst = max(up, key=lambda k: self._delta(k, int(ran[k])))
        cur = self.assignment[worst]
        down = step_down_config(cur, self.probe_configs)
        self.assignment[worst] = down
        self.hold[worst] = (down, self.tick + self.hold_ticks)
        # that config has measurably missed the quality bar: charge it
        # the full disagreement budget so greedy won't re-pick it until
        # agreeing probes have decayed the estimate back down
        self.est[(worst, cur)] = max(
            self._delta(worst, cur), 1.0 - self.agreement_target)
        self.n_backoffs += 1
        if self.engine is not None:
            self.engine.set_approx_cfg(self._tensor(self.assignment))
        self.history.append({
            "event": "backoff", "tick": self.tick, "key": worst,
            "from": int(cur), "to": int(down)})

    def on_tick(self, engine) -> None:
        """End-of-tick hook: every ``retune_every`` ticks, re-plan from
        the live feedback and retune the engine (zero retraces — the
        engine's config is a traced runtime value)."""
        self.tick += 1
        for k in [k for k, (_, exp) in self.hold.items()
                  if exp <= self.tick]:
            del self.hold[k]
        if self.tick % self.retune_every:
            return
        # estimates of (key, cfg) pairs NOT currently executing get no
        # probe signal, so they relax toward the MRED prior instead —
        # without this, a backoff's full-budget penalty would ban that
        # config forever (probes only ever re-measure the pair once the
        # config executes again)
        cur = {(k, self.assignment[k]) for k in self.keys
               if self.assignment.get(k, 0) > 0}
        for kk in list(self.est):
            if kk not in cur:
                prior = self._prior(kk[1])
                self.est[kk] += self.recover * (prior - self.est[kk])
        e1, n1 = self._serve_counters(engine)
        e0, n0 = self._mark
        measured = ((e1 - e0) / (n1 - n0) * self.macs_per_token
                    if n1 > n0 else None)
        self._mark = (e1, n1)
        # measured-energy telemetry: feed the windowed median and the
        # scale-free spike detector (measured over effective budget —
        # a fired spike is the retune history's early warning that the
        # loop is chasing, not tracking)
        spike = False
        if measured is not None:
            self.measured_window.push(measured)
            budget_eff = self.budget_pj_per_token * self.budget_scale
            if budget_eff > 0.0:
                spike = self.measured_spike.observe(measured / budget_eff)
        class_budgets = self._retune_classes(engine)
        # draft-depth recovery: one notch back toward the configured k
        # per retune once a spec backoff's hold has expired (the mirror
        # of the config ladder's hold-expiry un-ban)
        if (self.draft_k is not None and self._k0 is not None
                and self.draft_k < self._k0
                and self.tick >= self._k_hold_until):
            self.draft_k += 1
        assignment = self.plan()
        if assignment != self.assignment:
            self.assignment = assignment
            engine.set_approx_cfg(self._tensor(assignment))
        agree = self.agree_window.mean()
        self.agree_window.clear()
        self.history.append({
            "event": "retune", "tick": self.tick,
            "time": engine.clock(),
            # paged engines (PR 8) report the free-block watermark; the
            # brownout folds it into the budget scale this loop serves
            # (getattr: the scheduler also runs against engine stubs)
            "kv_utilization": getattr(engine, "backpressure",
                                      {}).get("kv_utilization"),
            "budget_pj_per_token": self.budget_pj_per_token,
            "modeled_pj_per_token": self._energy_pj(assignment),
            "measured_pj_per_token": measured,
            "measured_median_pj_per_token": self.measured_window.median(),
            "measured_spike": spike,
            "window_agreement": agree,
            "draft_k": self.draft_k,
            "class_budgets": class_budgets,
            "assignment": self._tensor(assignment).tolist()})

    def quarantine(self, executed_cfg) -> None:
        """Immediate one-notch backoff — the engine's NaN/Inf guard
        path (DESIGN.md §10).  Non-finite decode output is a far
        stronger signal than a probe disagreement, so it skips the
        hysteresis streak and backs the offending executed key off NOW
        (same ``_backoff`` rule: one notch, held, estimate charged).
        The engine rolls the corrupted step back itself; this hook only
        moves the config policy."""
        self._backoff(np.asarray(executed_cfg))
        self.pool_streak.reset()

    # -- reporting -------------------------------------------------------
    def set_budget(self, budget_pj_per_token: float) -> None:
        """Retarget the loop live (takes effect at the next retune)."""
        self.budget_pj_per_token = float(budget_pj_per_token)

    def set_budget_scale(self, scale: float) -> None:
        """Brownout hook: multiply the effective budget by ``scale``
        (1.0 = no brownout) from the next retune on.  Scaling — rather
        than overwriting — the budget keeps ``set_budget`` retargets
        and brownout pressure composable in either order."""
        assert 0.0 < scale <= 1.0, scale
        self.budget_scale = float(scale)

    # -- per-class budget splits (DESIGN.md §13) -------------------------
    def set_class_budgets(self, shares: Mapping[str, float], *,
                          floor_frac: float = 0.25) -> None:
        """Split the global budget across traffic classes.

        ``shares`` maps class name -> budget fraction (normalized to
        sum 1).  Class c's pJ/token TARGET is ``share_c / mix_c * B``
        where ``mix_c`` is its measured token share of the window and B
        the effective global budget — so the token-weighted sum of the
        class targets is always exactly B and the planner's pool budget
        is untouched (one physical knob, one global loop; the class
        layer is attribution + adaptation on top).  Each retune
        re-splits the shares from measured usage via ``resplit_shares``
        — a class running hot against its target pulls share from
        classes leaving budget unspent — with every class floored at
        ``floor_frac`` of its CONFIGURED share, so re-splitting never
        starves a class out of its guaranteed slice."""
        assert shares, "need at least one class share"
        assert all(float(v) > 0.0 for v in shares.values()), shares
        assert 0.0 < floor_frac < 1.0, floor_frac
        tot = sum(float(v) for v in shares.values())
        self._class_base = {str(c): float(v) / tot
                            for c, v in shares.items()}
        self.class_shares = dict(self._class_base)
        self._class_floor_frac = float(floor_frac)
        self.class_report = {}
        self._class_marks = {}
        if self.engine is not None:
            self._mark_classes(self.engine)

    def _mark_classes(self, engine) -> None:
        """Snapshot each class's serve counters as the next window's
        baseline (same diffing discipline as the global ``_mark``)."""
        e_by = getattr(engine, "serve_energy_by_class", {})
        n_by = getattr(engine, "serve_tokens_by_class", {})
        for c in self.class_shares:
            self._class_marks[c] = (float(e_by.get(c, 0.0)),
                                    int(n_by.get(c, 0)))

    def _retune_classes(self, engine) -> dict[str, dict] | None:
        """Close each class's loop at retune: diff per-class serve
        counters, score measured pJ/token against the class target, and
        re-split the shares from usage.  Returns the per-class history
        entry (None when class budgets are off or the engine predates
        per-class counters)."""
        if not self.class_shares:
            return None
        e_by = getattr(engine, "serve_energy_by_class", None)
        n_by = getattr(engine, "serve_tokens_by_class", None)
        if e_by is None or n_by is None:
            return None
        budget = self.budget_pj_per_token * self.budget_scale
        deltas: dict[str, tuple[float, int]] = {}
        tot_tok = 0
        for c in self.class_shares:
            e1 = float(e_by.get(c, 0.0))
            n1 = int(n_by.get(c, 0))
            e0, n0 = self._class_marks.get(c, (0.0, 0))
            self._class_marks[c] = (e1, n1)
            deltas[c] = (e1 - e0, n1 - n0)
            tot_tok += n1 - n0
        report: dict[str, dict] = {}
        usage: dict[str, float] = {}
        for c, share in self.class_shares.items():
            de, dn = deltas[c]
            mix = dn / tot_tok if tot_tok else 0.0
            target = share / mix * budget if mix > 0.0 else None
            measured = (de / dn * self.macs_per_token
                        if dn > 0 else None)
            if target and measured is not None:
                usage[c] = measured / target
            report[c] = {"share": share, "tokens": dn, "mix": mix,
                         "target_pj_per_token": target,
                         "measured_pj_per_token": measured}
        floors = {c: self._class_floor_frac * b
                  for c, b in self._class_base.items()}
        self.class_shares = resplit_shares(self.class_shares, usage,
                                           floors)
        for c in report:
            report[c]["next_share"] = self.class_shares[c]
        self.class_report = report
        return report

    def report(self) -> dict[str, Any]:
        retunes = [h for h in self.history if h["event"] == "retune"]
        last = retunes[-1] if retunes else {}
        return {
            "budget_pj_per_token": self.budget_pj_per_token,
            "budget_scale": self.budget_scale,
            "modeled_pj_per_token": (self._energy_pj(self.assignment)
                                     if self.shape else None),
            "measured_pj_per_token": last.get("measured_pj_per_token"),
            "assignment": (self._tensor(self.assignment).tolist()
                           if self.shape else None),
            "probes": self.n_probes,
            "agreement": (self.n_agree / self.n_probes
                          if self.n_probes else None),
            "backoffs": self.n_backoffs,
            "retunes": len(retunes),
            "ticks": self.tick,
            "draft_k": self.draft_k,
            "measured_median_pj_per_token": self.measured_window.median(),
            "spikes": self.measured_spike.n_spikes,
            "class_shares": dict(self.class_shares) or None,
            "class_budgets": self.class_report or None,
        }
