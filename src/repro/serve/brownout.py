"""Brownout: graceful degradation along the paper's error-config knob
(DESIGN.md §10).

Classic brownouts shed load by disabling features; here the shed axis
is the accelerator's OWN degradation knob — under queue pressure or
fault pressure, step the pool's error configs down the ladder to cut
joules/token (the paper's 13.33%-power-for-0.92%-accuracy trade,
composed over the 32-config family up to ~44% MAC energy), and under a
power-gated admission cap (``Engine(power_cap_pj_per_tick=...)``)
cheaper tokens directly buy MORE concurrent slots: the engine keeps
admitting instead of rejecting.  Degrade quality before availability.

Escalation/recovery are hysteresis-gated exactly like the scheduler's
probe backoff: a level change starts a ``hold_ticks`` freeze, and
recovery additionally needs a ``hold_ticks``-long calm streak — a
single good tick never whipsaws the pool back up.

Pressure reads through ``serve.telemetry`` (DESIGN.md §13): the
utilization/fault-delta readings land in bounded rolling windows (the
report's median view), the calm streak is a ``telemetry.Streak``, and a
median/MAD ``SpikeDetector`` on utilization is the early-warning axis —
a sudden load jump well above the recent window fires BEFORE the
absolute watermark is crossed, giving escalation a head start on fast
spikes (it ORs into pressure; the watermark semantics are unchanged on
slow ramps).

Composition with ``PowerBudgetScheduler`` (the two must not fight over
``engine.set_approx_cfg``): when the engine runs a scheduler, the
brownout NEVER writes configs itself — it scales the scheduler's
energy budget (``set_budget_scale``), and the scheduler's next retune
re-plans the pool toward the tightened budget with all of its measured
degradation feedback intact.  One writer, one knob, two control loops
stacked by priority.  Without a scheduler the brownout writes the
engine config directly: the base config is saved on first escalation
and restored exactly on full recovery, and escalation FLOORS each cell
at the ladder config's saving (a cell already saving more is left
alone — brownout only ever pushes toward more saving, never less).
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.power_model import MAC_SAVING_FRAC
from repro.serve.telemetry import RollingWindow, SpikeDetector, Streak

DEFAULT_LADDER = (0, 8, 16, 24, 31)


class BrownoutController:
    """Queue/fault-pressure → config-ladder level controller.

    Pass as ``Engine(brownout=...)``; the engine calls ``on_tick``
    first thing every tick (before admission, so a level change
    affects this very tick's power-gated admission).

    ladder: config per brownout level; level 0 is "no brownout"
        (ladder[0] is ignored — level 0 restores the base config or a
        1.0 budget scale).  Defaults sample the saving range up to the
        max-saving config 31.
    high_watermark / low_watermark (queue-utilization fractions):
        escalate above high, count calm below low — the gap is the
        hysteresis band.
    fault_threshold: per-tick fault-pressure delta (new retries + NaN
        events + failures since the last tick) that also escalates.
    hold_ticks: freeze after any level change, and the calm-streak
        length recovery requires.
    """

    def __init__(self, ladder=DEFAULT_LADDER, *,
                 high_watermark: float = 0.75,
                 low_watermark: float = 0.25,
                 fault_threshold: int = 2,
                 hold_ticks: int = 8):
        ladder = tuple(int(c) for c in ladder)
        assert len(ladder) >= 2, "need at least one degraded level"
        self.ladder = ladder
        self.high_watermark = float(high_watermark)
        self.low_watermark = float(low_watermark)
        self.fault_threshold = int(fault_threshold)
        self.hold_ticks = int(hold_ticks)

        self.level = 0
        self.n_escalations = 0
        self.n_recoveries = 0
        self._hold = 0
        # telemetry (DESIGN.md §13): pressure readings live in bounded
        # rolling windows, calm is a Streak, and a MAD spike detector
        # on utilization is the early-warning axis
        self._calm = Streak()
        self.util_window = RollingWindow(maxlen=64)
        self.fault_window = RollingWindow(maxlen=64)
        self.util_spike = SpikeDetector(window=32, threshold=4.0,
                                        min_scale=0.05, min_samples=8)
        self._base_cfg: np.ndarray | None = None
        self._last_faults = 0
        # bounded audit window: (tick-local level, utilization,
        # fault delta) per tick; counters above are the lifetime story
        self.history: deque[tuple[int, float, int]] = deque(maxlen=4096)

    # -- pressure signals ------------------------------------------------
    def _fault_pressure(self, engine) -> int:
        faults = engine.n_retries + engine.n_nan_events + engine.n_failed
        delta = faults - self._last_faults
        self._last_faults = faults
        return delta

    def budget_scale(self) -> float:
        """Budget multiplier for the scheduler-composition path: the
        fraction of exact energy the current ladder config keeps.
        Level 0 → 1.0; deeper levels tighten the scheduler's budget by
        exactly the ladder config's modeled saving."""
        return float(1.0 - MAC_SAVING_FRAC[self.ladder[self.level]])

    # -- engine hook -----------------------------------------------------
    def on_tick(self, engine) -> None:
        bp = engine.backpressure
        # paged serving (PR 8): the free-block watermark is a second
        # pressure axis — a nearly-exhausted pool preempts streams, so
        # brownout treats it exactly like a deep queue
        util = max(float(bp["utilization"]),
                   float(bp.get("kv_utilization", 0.0)))
        fault_delta = self._fault_pressure(engine)
        self.util_window.push(util)
        self.fault_window.push(float(fault_delta))
        # early warning: a utilization jump far above the recent window
        # median (MAD units) counts as pressure BEFORE the absolute
        # watermark trips — fast spikes escalate a tick early, slow
        # ramps see identical watermark behavior
        early = self.util_spike.observe(util)
        pressure = (util >= self.high_watermark
                    or fault_delta >= self.fault_threshold
                    or early)
        calm = util <= self.low_watermark and fault_delta == 0
        calm_len = self._calm.observe(calm)
        if self._hold > 0:
            self._hold -= 1
        elif pressure and self.level < len(self.ladder) - 1:
            self.level += 1
            self.n_escalations += 1
            self._hold = self.hold_ticks
            self._calm.reset()
            self._apply(engine)
        elif calm and self.level > 0 and calm_len >= self.hold_ticks:
            self.level -= 1
            self.n_recoveries += 1
            self._hold = self.hold_ticks
            self._calm.reset()
            self._apply(engine)
        self.history.append((self.level, util, fault_delta))

    # -- actuation -------------------------------------------------------
    def _apply(self, engine) -> None:
        if engine.scheduler is not None:
            # one writer: tighten/relax the scheduler's budget and let
            # its next retune re-plan the pool (feedback state intact)
            engine.scheduler.set_budget_scale(self.budget_scale())
            return
        if self.level == 0:
            if self._base_cfg is not None:
                engine.set_approx_cfg(self._base_cfg)
                self._base_cfg = None
            return
        if self._base_cfg is None:
            self._base_cfg = engine.approx_cfg.copy()
        floor = self.ladder[self.level]
        base = self._base_cfg
        # floor every cell at the ladder config's saving: cells already
        # saving at least that much keep their (possibly hand-tuned)
        # config — brownout never reduces saving
        vec = np.where(MAC_SAVING_FRAC[base] >= MAC_SAVING_FRAC[floor],
                       base, floor).astype(np.int32)
        engine.set_approx_cfg(vec)

    def report(self) -> dict:
        return {"level": self.level, "ladder": list(self.ladder),
                "escalations": self.n_escalations,
                "recoveries": self.n_recoveries,
                "early_warnings": self.util_spike.n_spikes,
                "util_median": self.util_window.median(),
                "fault_median": self.fault_window.median(),
                "budget_scale": self.budget_scale()}
