"""Streaming telemetry for the serving control loops (DESIGN.md §13).

Every adaptive signal in the serving stack — the scheduler's
probe-agreement window, the brownout's utilization/fault pressure, the
speculative acceptance streak — used to keep its own ad-hoc counters.
This module is the one shared vocabulary they all read through now:

  * ``RollingWindow`` — a bounded (``deque(maxlen=...)`` by
    construction) sample window with streaming median/quantile/mean,
    the HomebrewNLP-style windowed-median treatment of noisy signals:
    a median over the last N observations is robust to the single-tick
    outliers an EWMA smears into the estimate.
  * ``SpikeDetector`` — median/MAD early warning.  ``score(x)`` is
    x's deviation from the window median in MAD units (robust z-score);
    ``observe(x)`` fires when the score crosses ``threshold`` with
    enough history.  For a FIXED history the score is monotone
    increasing in x — a bigger spike always fires at least as hard
    (property-tested in tests/test_telemetry.py).
  * ``Streak`` — consecutive-event counter, the hysteresis primitive
    behind one-notch backoffs (scheduler pool + spec axes, brownout
    calm streak).
  * ``ewma`` — the one EWMA everybody shares, as a pure function.

Everything here is pure state-in/state-out arithmetic: no clock reads,
no unbounded containers — repro-lint's ``injected-clock`` and
``bounded-state`` rules pass by construction, and every consumer
inherits that.
"""
from __future__ import annotations

from collections import deque


def ewma(prev: float, x: float, alpha: float) -> float:
    """One exponentially-weighted moving-average step:
    ``(1 - alpha) * prev + alpha * x``.  Pure — callers own the
    state."""
    a = float(alpha)
    return (1.0 - a) * float(prev) + a * float(x)


class RollingWindow:
    """Bounded rolling sample window with order statistics.

    ``maxlen`` caps memory by construction (the buffer is a
    ``deque(maxlen=...)``); pushes past the cap evict the oldest
    sample.  Statistics are over the CURRENT window contents and are
    permutation-invariant in them (sorted-copy order statistics, no
    incremental state to drift)."""

    def __init__(self, maxlen: int):
        assert maxlen > 0, maxlen
        self.maxlen = int(maxlen)
        self._buf: deque = deque(maxlen=self.maxlen)

    def push(self, x: float) -> None:
        self._buf.append(float(x))

    def clear(self) -> None:
        self._buf.clear()

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def count(self) -> int:
        return len(self._buf)

    @property
    def last(self) -> float | None:
        return self._buf[-1] if self._buf else None

    def mean(self) -> float | None:
        if not self._buf:
            return None
        return sum(self._buf) / len(self._buf)

    def quantile(self, q: float) -> float | None:
        """Linear-interpolation quantile of the window (q in [0, 1]);
        None when empty.  O(n log n) per call — windows are small and
        control-loop cadence is per-retune, not per-token."""
        if not self._buf:
            return None
        assert 0.0 <= q <= 1.0, q
        s = sorted(self._buf)
        pos = q * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        frac = pos - lo
        return s[lo] * (1.0 - frac) + s[hi] * frac

    def median(self) -> float | None:
        return self.quantile(0.5)


class Streak:
    """Consecutive-event counter — the hysteresis primitive.

    ``observe(event)`` returns the updated run length (an event extends
    it, a non-event zeroes it); ``reset`` zeroes it out of band (e.g.
    after the backoff the streak triggered fires)."""

    def __init__(self):
        self.length = 0

    def observe(self, event: bool) -> int:
        self.length = self.length + 1 if event else 0
        return self.length

    def reset(self) -> None:
        self.length = 0


class SpikeDetector:
    """Median/MAD early-warning detector over a rolling window.

    ``score(x)`` is the robust z-score of ``x`` against the CURRENT
    window: ``(x - median) / max(MAD, min_scale)`` — ``min_scale``
    floors the denominator so a flat history (MAD 0) cannot make every
    epsilon a spike.  ``observe(x)`` scores x against the history
    EXCLUDING x (a spike must not mask itself), then admits x to the
    window, and returns True when the score reached ``threshold`` with
    at least ``min_samples`` of history.  For a fixed history the score
    is monotone increasing in x, so firing is monotone in spike
    magnitude."""

    def __init__(self, *, window: int = 64, threshold: float = 4.0,
                 min_scale: float = 0.05, min_samples: int = 8):
        assert threshold > 0.0 and min_scale > 0.0
        self.window = RollingWindow(maxlen=window)
        self.threshold = float(threshold)
        self.min_scale = float(min_scale)
        self.min_samples = int(min_samples)
        self.n_spikes = 0

    def score(self, x: float) -> float:
        """Robust z-score of ``x`` vs the current window (0.0 while the
        window is empty).  Read-only — does not admit ``x``."""
        med = self.window.median()
        if med is None:
            return 0.0
        devs = sorted(abs(v - med) for v in self.window._buf)
        pos = 0.5 * (len(devs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(devs) - 1)
        mad = devs[lo] * (1.0 - (pos - lo)) + devs[hi] * (pos - lo)
        return (float(x) - med) / max(mad, self.min_scale)

    def observe(self, x: float) -> bool:
        fired = (self.window.count >= self.min_samples
                 and self.score(x) >= self.threshold)
        self.window.push(x)
        self.n_spikes += int(fired)
        return fired
