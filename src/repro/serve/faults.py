"""Deterministic fault injection for the serving engine (DESIGN.md §10).

Chaos testing only earns its keep when a failure found once can be
replayed forever: every fault here is a ``FaultEvent`` pinned to an
engine TICK (not a wall-clock instant), the plan is sorted and applied
by an internal tick counter, and the one random knob (which slot a
plan-less event hits) derives from ``(seed, tick)`` — so a chaos
scenario is a pure function of ``(plan, seed)`` and a failing seed is a
regression test, not an anecdote.

Fault kinds (the rows of DESIGN.md §10's fault-model table):

  nan_logits   corrupt the decode logits of a slot (or all active
               slots) with ``value`` (NaN/Inf) AFTER the jitted step —
               models an aggressive-config numeric blowup surfacing in
               the output.  Caught by the engine's NaN/Inf guard before
               the cache commits, so recovery is a free rollback.
  nan_cache    poison a slot's KV rows in the POOL cache before the
               step — models silent state corruption.  Rollback cannot
               help (the poisoned state IS the rollback target); this
               is the scenario snapshot/restore exists for.
  step_fail    raise ``InjectedFault`` in place of the decode call —
               models a device/runtime error.  Exercises the engine's
               retry + capped-exponential-backoff path.
  clock_skew   add ``skew_s`` to every subsequent reading of the
               engine's injected clock — models clock drift; deadlines
               must fire from skewed time, not tick counts.
  stall        one-tick straggler: jump the clock forward ``stall_s``
               as if the tick took that long — models a slow device.
               Distinct from clock_skew only in intent (latency, not
               drift); SLO eviction is the response either way.
  drop_probe   suppress this tick's scheduler feedback (``on_step`` is
               skipped once) — models lost telemetry.
  dup_probe    deliver this tick's scheduler feedback twice — models
               at-least-once telemetry.  The scheduler's EWMA estimates
               must tolerate both without diverging.

The injector touches the engine only through documented surfaces
(``engine.cache``, the clock wrapper, the ``begin_tick`` /
``check_step_fail`` / ``corrupt_logits`` / ``probe_multiplicity``
hooks ``Engine._step`` calls) — it never reaches into the jitted
functions, so an injected run compiles EXACTLY the executables an
uninjected run does (zero retraces under chaos is asserted in
tests/test_resilience.py and the resilience benchmark).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

KINDS = ("nan_logits", "nan_cache", "step_fail", "clock_skew", "stall",
         "drop_probe", "dup_probe")


class InjectedFault(RuntimeError):
    """Raised by ``step_fail`` events in place of the decode call."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` fires at engine tick ``tick``.

    slot: target decode slot for nan_logits/nan_cache; None hits every
        active slot (nan_logits) or slot 0 (nan_cache).
    value: the corruption payload (default NaN; pass ``float("inf")``
        to exercise the Inf side of the guard).
    skew_s: seconds added to the injected clock (clock_skew).
    stall_s: seconds the stalled tick appears to take (stall).
    """
    tick: int
    kind: str
    slot: int | None = None
    value: float = float("nan")
    skew_s: float = 0.0
    stall_s: float = 0.0

    def __post_init__(self):
        assert self.kind in KINDS, f"unknown fault kind {self.kind!r}"
        assert self.tick >= 0, self.tick


class FaultInjector:
    """Replays a sorted ``FaultEvent`` plan against a live engine.

    Pass as ``Engine(fault_injector=...)``: the engine wraps its
    injected clock with ``wrap_clock`` and calls the tick hooks in a
    fixed order (begin_tick → check_step_fail → corrupt_logits →
    probe_multiplicity).  ``log`` is a bounded audit window of fired
    events; ``counts`` carries the lifetime totals per kind.
    """

    def __init__(self, plan: Iterable[FaultEvent], seed: int = 0):
        self.plan = sorted(plan, key=lambda e: (e.tick, KINDS.index(e.kind)))
        # events fire when DUE (plan tick reached), at the first hook
        # that can deliver them — a tick spent in a backoff window
        # defers its faults rather than silently dropping them
        self._remaining = list(self.plan)
        self.seed = int(seed)
        self.tick = -1                  # begin_tick increments first
        self.skew_s = 0.0
        self.counts = {k: 0 for k in KINDS}
        self.log: deque[tuple[int, str]] = deque(maxlen=4096)

    # -- clock -----------------------------------------------------------
    def wrap_clock(self, clock: Callable[[], float]) -> Callable[[], float]:
        """Skew-aware view of the engine's injected clock: clock_skew
        and stall events shift every subsequent reading, so deadline
        and backoff logic sees the faulted time without the engine ever
        reading an ambient wall clock."""
        def skewed() -> float:
            return clock() + self.skew_s
        return skewed

    # -- tick hooks (called by Engine._step, in this order) --------------
    def begin_tick(self, engine) -> None:
        """Advance to the next tick and apply its pre-step faults:
        clock skew / stall (time shifts) and cache poisoning."""
        self.tick += 1
        for e in self._pending("clock_skew"):
            self.skew_s += e.skew_s
            self._fire(e)
        for e in self._pending("stall"):
            self.skew_s += e.stall_s
            self._fire(e)
        for e in self._pending("nan_cache"):
            self._poison_cache(engine, e)
            self._fire(e)

    def check_step_fail(self) -> None:
        for e in self._pending("step_fail"):
            self._fire(e)
            raise InjectedFault(
                f"injected decode failure at tick {self.tick}")

    def corrupt_logits(self, logits, active: list[int]):
        """Overwrite the logits rows of the targeted slots with the
        event's payload — a host-side round-trip on purpose: the jitted
        decode's output is corrupted, never its trace."""
        events = self._pending("nan_logits")
        if not events:
            return logits
        rows = np.asarray(logits).copy()
        for e in events:
            targets = active if e.slot is None else [e.slot]
            for s in targets:
                rows[s] = e.value
            self._fire(e)
        return jnp.asarray(rows)

    def probe_multiplicity(self) -> int:
        """How many times this tick's scheduler feedback is delivered:
        1 normally, 0 under drop_probe, 2 under dup_probe (drop wins
        when both fire — the duplicate of a dropped message is still
        dropped)."""
        mult = 1
        for e in self._pending("dup_probe"):
            mult = 2
            self._fire(e)
        for e in self._pending("drop_probe"):
            mult = 0
            self._fire(e)
        return mult

    # -- internals -------------------------------------------------------
    def _pending(self, kind: str) -> list[FaultEvent]:
        return [e for e in self._remaining
                if e.tick <= self.tick and e.kind == kind]

    def _fire(self, e: FaultEvent) -> None:
        self._remaining.remove(e)
        self.counts[e.kind] += 1
        self.log.append((self.tick, e.kind))

    def _poison_cache(self, engine, e: FaultEvent) -> None:
        """Overwrite one slot's rows of every KV pool buffer with the
        payload.  Cache leaves under ``cache["scan"]`` are stacked
        (layers_in_block, batch, seq, kv_heads, head_dim) — batch is
        axis 1 (the axis ``_splice_cache`` writes)."""
        slot = 0 if e.slot is None else int(e.slot)
        assert 0 <= slot < engine.max_batch, (slot, engine.max_batch)

        def poison(leaf):
            if getattr(leaf, "ndim", 0) < 2:
                return leaf
            assert leaf.shape[1] == engine.max_batch, leaf.shape
            return leaf.at[:, slot].set(e.value)

        cache = dict(engine.cache)
        cache["scan"] = jax.tree.map(poison, cache["scan"])
        engine.cache = cache
        if engine.mapping is not None:
            engine.cache = jax.device_put(engine.cache, engine._cache_sh)

    def report(self) -> dict:
        return {"ticks": self.tick + 1, "skew_s": self.skew_s,
                "counts": dict(self.counts),
                "fired": sum(self.counts.values())}
