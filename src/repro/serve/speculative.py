"""Approx-draft self-speculative decoding (DESIGN.md §12).

The paper's knob gives speculation its draft model FOR FREE: the same
compiled decode executable already runs at ANY of the 32 error configs
with zero retraces (PR 1/PR 4), so an aggressive low-power config IS a
cheap draft model and the service config is its verifier — no second
network, no extra weights, no extra executables for the draft side.

Protocol (per eligible decode tick, per participating slot):

  1. run ``k`` draft steps at ``draft_cfg`` from the pending input
     token — k greedy draft tokens d_1..d_k, K/V written at the draft
     config (disposable state);
  2. ONE verify pass at the service config scores the window
     ``[t0, d_1..d_k]`` — a chunked-prefill-shaped call (the paged
     path literally reuses the prefill-chunk executable; the dense
     path runs ``transformer.decode_verify`` over a static window
     W = max_k + 1) whose row i logits give the verifier's own next
     token e_{i+1} at position P+i.  The verify OVERWRITES every entry
     the drafts touched, so the committed cache is service-config
     state end to end;
  3. accept the longest agreeing prefix (j* = #leading i with
     d_i == e_i) and emit a = j* + 1 tokens e_1..e_a — the verifier's
     one corrected token on a mismatch, a BONUS token when every draft
     agreed.  Every emitted token is the verifier's own argmax, so the
     stream is identical to non-speculative greedy decoding at the
     service config by construction;
  4. rewind the cache past the acceptance point: dense needs no undo
     at all (the pool position is host state recomputed each tick and
     stale entries are rewritten before any read); paged rewinds
     ``seq_lens`` and releases the surplus spec-allocated blocks
     (serve/engine.py ``_rewind_slot``).

Acceptance statistics flow into the scheduler through the existing
``record_probe``/EWMA plumbing attributed to the DRAFT config
(``PowerBudgetScheduler.record_spec``), and the draft depth ``k``
becomes a second control axis with the same one-notch hysteresis as
the config ladder.  Energy accounting bills draft steps at the draft
config and the verify pass as one service-config weight-pass per slot
(``kind="spec_draft"`` / ``"spec_verify"`` rows).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.approx_multiplier import N_CONFIGS


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs (``Engine(spec=SpecConfig(...))``).

    draft_cfg: the aggressive low-power error config drafts run at —
        an int (broadcast per layer) or anything
        ``Engine._as_layer_vector`` accepts.  Traced DATA at run time,
        never a shape: sweeping it recompiles nothing.
    k: draft depth — tokens drafted per speculative tick.  A host loop
        count (the scheduler may lower it live, ``Engine.set_spec``
        may retarget it), bounded by ``max_k``.
    max_k: static ceiling on k.  The dense verify window W = max_k + 1
        is the ONE static shape speculation adds; k itself never
        becomes a shape (repro-lint cfg-shape enforces this).
    """

    draft_cfg: int = 8
    k: int = 3
    max_k: int = 7

    def __post_init__(self):
        assert 1 <= self.k <= self.max_k, (self.k, self.max_k)
        if isinstance(self.draft_cfg, int):
            assert 0 < self.draft_cfg < N_CONFIGS, self.draft_cfg


def longest_agreeing_prefix(draft, exact) -> int:
    """j* — number of leading positions where the draft tokens equal
    the verifier's own argmax tokens.  ``draft``: the k drafted tokens
    d_1..d_k; ``exact``: the verifier's e_1..e_k (row i-1 of the
    verify logits).  The caller emits e_1..e_{j*+1}: j* verified draft
    tokens plus the verifier's correction (or bonus) token."""
    n = 0
    for d, e in zip(draft, exact):
        if int(d) != int(e):
            break
        n += 1
    return n
