"""Serving engine: batched prefill + decode with continuous batching.

A fixed pool of `max_batch` decode slots runs the jitted ``decode_step``
every tick; a request queue feeds empty slots via per-request prefill
(cache rows are spliced into the pool).  This is the standard orca-style
continuous-batching control loop in its jax-native form: python-side
scheduling around two jitted functions with static shapes.

The engine exposes the paper's knob end-to-end **as a runtime value**:
the per-layer error-config vector is a traced int32 argument of both
jitted functions, so

  * each request may carry its own ``approx_cfg`` (applied to its
    prefill, and folded into the decode pool config);
  * ``set_approx_cfg`` / ``apply_allocation`` retune live slots between
    ticks — a power-budget scheduler can sweep all 32 configs with ZERO
    recompilations (asserted in tests/test_runtime_config.py);
  * ``energy_report`` integrates the calibrated per-MAC energy model
    over the executed steps at the configs they actually ran
    (DESIGN.md §2: energy is modeled — the knob's effect on accuracy is
    real, measured on the generated tokens).

Pool semantics: decode runs one batched step for all slots, so per
layer the pool runs the LOWEST-ERROR config among the active requests'
vectors (ranked by measured MRED — config index is ordered by energy
saving, in which error is non-monotone) — a slot never executes at a
higher-error config than its request asked for.

PR 2: with ``cfg.mac_backend == "pallas"`` every GEMM runs through the
fused approx-MAC kernel; ``cfg_groups > 1`` widens all of the above
from per-layer vectors to per-layer-per-neuron-group (n_layers,
cfg_groups) matrices (DESIGN.md §3).  Weights are pre-quantized into
QTensors ONCE at init (``quantize_weights``), so no decode step
re-quantizes weights inside the traced graph.

PR 3: ``cfg_experts > 1`` (MoE models) adds an EXPERT axis — configs
become (n_layers, cfg_experts, cfg_groups) tensors, each expert of each
MoE layer at its own error config through the grouped expert kernel
(DESIGN.md §4; MoE expert weights now pre-quantize into stacked QTensor
banks too).  Dense GEMMs in those layers collapse the expert axis to
the lowest-measured-MRED config — the pool-join rule — and
``apply_allocation`` accepts (layer, expert) tuple keys so a controller
can target single experts.

PR 4: ``Engine(scheduler=...)`` closes the power loop ONLINE
(DESIGN.md §7): a ``serve.scheduler.PowerBudgetScheduler`` hooks into
every tick — periodic shadow-decode probes re-run the pool's step at
exact config through the SAME decode executable (zero retraces) to
measure token agreement, and every K ticks the pool is retuned toward
a joules/token budget over the full (layer[, expert][, group]) space.
Time is injected (``Engine(clock=...)``) so request ordering and the
scheduler's tick timing are deterministic under test; ``energy_log``
records every charged (kind, tokens, per-MAC-pJ) increment so budget
accounting is auditable step by step.

PR 5: ``Engine(mapping=..., param_specs=...)`` serves one TP/SP-SHARDED
model (DESIGN.md §8): params (incl. the stacked MoE QTensor banks) are
placed by their logical specs (``dist.sharding.Mapping`` over a
``launch.mesh`` mesh, specs transformed by
``transformer.quantize_lm_specs`` to match the quantized layout), the
KV cache is sharded along ``kv_hd``/``kv_seq``, and every config
tensor is REPLICATED across the mesh — the decode step runs under the
activated mapping (GSPMD via ``lsc``/``lsc_tree`` constraints) with
the config as a traced replicated operand, so ``set_approx_cfg`` /
``apply_allocation`` / the scheduler retune the WHOLE mesh with zero
retraces, and — in the heads-TP regime (``serve_mapping(kv="hd")``
with TP dividing the KV-head count) — the sharded decode is
bit-identical to the single-host path (int8 MACs accumulate in int32,
which is exact under any contraction-dim split, and per-head attention
stays whole on one shard; tests/test_sharded_serving.py).

PR 7 hardens the loop for chaos (DESIGN.md §10).  Admission is BOUNDED
(``queue_capacity``; ``submit`` returns False and stamps the request
``rejected`` when full — ``backpressure`` exposes the signal) and
optionally POWER-GATED (``power_cap_pj_per_tick``: a request is only
admitted while the pool's modeled pJ/tick stays under the cap — cheaper
configs therefore buy concurrency, the brownout lever).  Requests carry
TTFT/e2e deadlines evicted from the injected clock; decode failures
retry with capped exponential backoff + deterministic jitter; a NaN/Inf
guard checks decode logits BEFORE the cache commits, so a corrupted
step is rolled back for free while the offending config steps one
notch toward exact (``scheduler.quarantine`` when one is attached — the
same one-notch hysteresis as probe backoff — else directly).
``Engine(checkpointer=...)`` snapshots the full serving state (cache,
config tensors, slots, queue, counters, sampler key) through
``checkpoint.Checkpointer`` so a killed engine resumes mid-stream
bit-identically, and ``run(preemption=...)`` wires
``dist.fault_tolerance.PreemptionHandler`` in for graceful drain.
Chaos itself is injected via ``Engine(fault_injector=...)``
(serve/faults.py) and degradation policy via ``Engine(brownout=...)``
(serve/brownout.py) — both pure python around the SAME two compiled
executables: zero retraces under chaos.

PR 8 scales concurrency past the dense pool: ``Engine(paged=
PagedCacheConfig(...))`` swaps the (max_batch, max_len) cache rows for
a PAGED pool (DESIGN.md §11) — fixed-size blocks owned per request
through block tables, a host-side refcounting allocator
(serve/paged_cache.py), chunked prefill interleaved with decode ticks
(prompts advance ``prefill_chunk`` tokens per tick instead of
monopolizing one), prefix block sharing across requests with a common
prompt (copy-on-write), and preempt-by-recompute when the pool runs
dry (victim blocks are freed, the request re-queues at the FRONT and
re-prefills prompt+generated on re-admission — token stream
unchanged).  Block tables and sequence lengths are traced int32 DATA
operands of the decode executable, never shapes, so the zero-retrace
invariant extends to any stream count / prompt-length mix; at equal
occupancy the gathered paged view is bit-identical to the dense rows
(tests/test_paged_serving.py).  ``prefill_pad`` (independent of
paging) pads prompts to a boundary and passes the true length as a
traced scalar, collapsing the per-prompt-length prefill retrace to ONE
executable.

PR 9 turns the knob into a FREE draft model: ``Engine(spec=
SpecConfig(...))`` makes eligible greedy decode ticks run k draft
steps at an aggressive low-power config, then ONE service-config
verify pass scores the whole window — the dense path through a static
W = max_k + 1 ``decode_verify`` executable, the paged path through the
PR-8 prefill-chunk executable per slot — accepting the longest
agreeing prefix plus the verifier's correction/bonus token
(DESIGN.md §12, serve/speculative.py).  Every emitted token is the
verifier's own argmax, so the stream equals non-speculative greedy;
drafts bill at the draft config (``kind="spec_draft"``), verifies as
one service-config weight-pass per slot (``"spec_verify"``), and the
scheduler gains draft depth as a second control axis
(``record_spec`` / draft-k hysteresis).  k is a host loop count and
draft_cfg traced data: live (k, draft-cfg) retargets via ``set_spec``
compile nothing.

CONFIG-KEY CONVENTION (used by ``apply_allocation``, the scheduler,
and the controller alike): a config-tensor cell is addressed by
``layer`` (int index into the depth axis), then — only when the engine
has the corresponding axis — ``expert`` (index into ``cfg_experts``)
and ``group`` (index into ``cfg_groups``), in that order.
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx_multiplier import N_CONFIGS
from repro.core.controller import step_down_config
from repro.core.power_model import (ENERGY_PER_MAC_PJ, MAC_SAVING_FRAC,
                                    energy_per_token_pj, error_rank)
from repro.dist.sharding import activate as _activate, lsc_tree
from repro.nn import transformer as T
from .paged_cache import ZERO_BLOCK, PagedCacheConfig, PageAllocator
from .sampling import sample
from .speculative import SpecConfig, longest_agreeing_prefix

_ENERGY_PJ = ENERGY_PER_MAC_PJ


class _SpecAbort(RuntimeError):
    """Internal: roll back a speculative tick (draft-side corruption —
    the DRAFT config misbehaving must not quarantine the pool config,
    so it gets its own control flow, not the failure/NaN paths)."""


def _mred_table() -> np.ndarray:
    """Per-config measured MRED — the error ranking for the pool join
    (shared per-process table, see core.error_metrics.mred_table)."""
    from repro.core.error_metrics import mred_table
    return mred_table()


def pool_join(stack) -> np.ndarray:
    """Join k config tensors (stacked on axis 0) elementwise at the
    LOWEST measured MRED, ties broken toward the lower config index —
    the decode-pool rule (DESIGN.md §5): no participant executes at a
    higher error than it asked for.  A commutative, associative,
    idempotent lattice meet over the ``power_model.error_rank`` total
    order — the ONE definition of that order, shared with the
    expert-axis collapse (``ops.collapse_expert_cfg``) and the
    scheduler's energy state (property-tested in
    tests/test_config_algebra.py)."""
    stack = np.asarray(stack)
    idx = np.argmin(error_rank()[stack], axis=0)
    return np.take_along_axis(stack, idx[None, ...], axis=0)[0]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    approx_cfg: Any = None        # None -> engine default; int or
                                  # (n_layers,) per-layer vector
    submitted_at: float | None = None   # stamped by Engine.submit from
                                        # the injected clock (was
                                        # wall-clock at construction —
                                        # untestable ordering)
    tokens: list = field(default_factory=list)
    done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None
    # -- resilience (PR 7) ---------------------------------------------
    ttft_slo_s: float | None = None     # deadline queue→first token;
                                        # expired in the queue when missed
    e2e_slo_s: float | None = None      # deadline submit→finish; the
                                        # slot is evicted when missed
    cls: str = "default"                # traffic class (serve/traffic.py)
    status: str = "queued"              # queued|active|done|rejected|
                                        # expired|failed
    retries: int = 0                    # decode failures survived


def _pack_request(r: Request | None) -> dict | None:
    """Request → msgpack-able dict (snapshot metadata)."""
    if r is None:
        return None
    return {"rid": int(r.rid), "prompt": np.asarray(r.prompt).tolist(),
            "max_new_tokens": int(r.max_new_tokens),
            "temperature": float(r.temperature),
            "approx_cfg": (None if r.approx_cfg is None
                           else np.asarray(r.approx_cfg).tolist()),
            "submitted_at": r.submitted_at,
            "tokens": [int(t) for t in r.tokens], "done": bool(r.done),
            "first_token_at": r.first_token_at,
            "finished_at": r.finished_at,
            "ttft_slo_s": r.ttft_slo_s, "e2e_slo_s": r.e2e_slo_s,
            "cls": r.cls, "status": r.status, "retries": int(r.retries)}


def _unpack_request(d: dict | None) -> Request | None:
    if d is None:
        return None
    r = Request(rid=d["rid"],
                prompt=np.asarray(d["prompt"], np.int32),
                max_new_tokens=d["max_new_tokens"],
                temperature=d["temperature"],
                approx_cfg=d["approx_cfg"],
                submitted_at=d["submitted_at"],
                ttft_slo_s=d["ttft_slo_s"], e2e_slo_s=d["e2e_slo_s"],
                cls=d["cls"], status=d["status"], retries=d["retries"])
    r.tokens = list(d["tokens"])
    r.done = d["done"]
    r.first_token_at = d["first_token_at"]
    r.finished_at = d["finished_at"]
    return r


class Engine:
    def __init__(self, params, cfg: T.ModelConfig, *, max_batch: int = 4,
                 max_len: int = 512, approx_cfg=0, seed: int = 0,
                 cfg_groups: int = 1, cfg_experts: int = 1,
                 quantize_weights: bool = True, scheduler=None,
                 clock: Callable[[], float] = time.time,
                 mapping=None, param_specs=None,
                 queue_capacity: int = 256,
                 max_retries: int = 2, retry_base_s: float = 0.05,
                 retry_cap_s: float = 2.0, nan_max_strikes: int = 2,
                 power_cap_pj_per_tick: float | None = None,
                 fault_injector=None, brownout=None,
                 checkpointer=None, snapshot_every: int = 0,
                 paged: PagedCacheConfig | None = None,
                 prefill_pad: int = 0,
                 spec: SpecConfig | None = None):
        """Continuous-batching engine over one compiled prefill + one
        compiled decode executable.

        Knobs (see the module docstring for the config-key convention):

        max_batch (default 4): decode-pool slots — one batched decode
            step serves up to this many in-flight requests per tick.
        max_len (default 512): KV-cache length in tokens (prompt +
            generated), the static shape of every cache buffer.
        approx_cfg (default 0 = exact): engine-wide error config; an
            int broadcasts over the whole config tensor, or pass a
            per-layer / per-(layer, expert[, group]) array.
        seed (default 0): sampling PRNG seed.
        cfg_groups (default 1): neuron groups per layer — widens the
            config tensor's trailing axis so each layer's GEMM output
            columns split into `cfg_groups` contiguous groups, each at
            its own config (requires ``cfg.mac_backend == "pallas"``).
        cfg_experts (default 1): expert axis (MoE models; must equal
            ``cfg.n_experts``) — every expert of every MoE layer at its
            own config through the grouped expert kernel.
        quantize_weights (default True): pre-quantize every GEMM weight
            into QTensors once at init (serving mode).  False keeps
            float params (each call quantizes in-trace — debugging/A-B
            only).
        scheduler (default None): a ``serve.scheduler
            .PowerBudgetScheduler`` to close the power loop online; the
            engine calls its ``on_step``/``on_tick`` hooks every tick.
        clock (default time.time): injected time source, read for
            request ``submitted_at``/TTFT/finish stamps and the
            scheduler's tick timing — pass a fake for deterministic
            tests.  Units: seconds (float).
        mapping (default None = single-host): a ``dist.sharding
            .Mapping`` (e.g. ``dist.sharding.serve_mapping`` over a
            ``launch.mesh.make_serve_mesh`` mesh).  Params and KV cache
            are placed by logical specs, config tensors are replicated,
            and every jitted call runs under the activated mapping.
        param_specs (default None): the logical-spec tree ``init_lm``
            returned for these params; required to shard the params
            when ``mapping`` is given (without it they replicate, the
            cache still shards).

        Resilience knobs (PR 7, DESIGN.md §10):

        queue_capacity (default 256): admission-queue bound; a full
            queue REJECTS (``submit`` returns False) instead of
            growing — ``backpressure`` reports utilization.
        max_retries (default 2): decode failures a request survives
            before it is evicted as ``failed``.
        retry_base_s / retry_cap_s (defaults 0.05 / 2.0): capped
            exponential backoff between failed decode attempts
            (base·2^(streak-1), plus ≤10% deterministic jitter seeded
            from ``seed`` and the failure count).
        nan_max_strikes (default 2): consecutive non-finite-logits
            strikes a slot survives; past it the engine restores the
            last snapshot (when a checkpointer holds one — persistent
            cache corruption) or evicts the slot as ``failed``.
        power_cap_pj_per_tick (default None = ungated): admission power
            gate — a request is admitted only while (active+1) slots'
            modeled pJ/tick stays under the cap, so stepping configs
            down (brownout) buys admission headroom.
        fault_injector (default None): a ``serve.faults.FaultInjector``;
            the engine wraps its clock and calls the injector's tick
            hooks — chaos is replayable from the injector's plan+seed.
        brownout (default None): a ``serve.brownout
            .BrownoutController`` consulted at the top of every tick.
        checkpointer (default None): a ``checkpoint.Checkpointer`` for
            ``save_snapshot``/``restore_snapshot`` (and graceful
            drain's snapshot-and-exit path).
        snapshot_every (default 0 = off): auto-snapshot cadence in
            decode steps.

        Paged serving knobs (PR 8, DESIGN.md §11):

        paged (default None = dense pool): a ``serve.paged_cache
            .PagedCacheConfig`` — the KV cache becomes a block pool
            with per-request block tables, chunked prefill, prefix
            sharing, and preempt-by-recompute.  Single-host only (v1);
            requires an all-'global', float-KV model and
            ``max_len % block_size == 0``.
        prefill_pad (default 0 = off): pad prompts up to a multiple of
            this many tokens and pass the true length as a TRACED
            scalar, so all prompt lengths share ONE compiled prefill
            executable (paged mode implies the chunk boundary).
            Attention-only patterns, float KV.

        Speculative decoding (PR 9, DESIGN.md §12):

        spec (default None = off): a ``serve.speculative.SpecConfig``
            — eligible decode ticks run ``k`` draft steps at the
            aggressive ``draft_cfg`` then ONE service-config verify
            pass over all k positions, emitting the longest agreeing
            prefix + the verifier's corrected token (stream identical
            to non-speculative greedy by construction).  Greedy slots
            only; needs an all-'global' float-KV model; single-host.
        """
        # quantize every dense GEMM weight ONCE at engine init and carry
        # QTensors through the jitted step functions — no decode step
        # re-quantizes weights inside the traced graph (PR 2; MoE expert
        # weights join as stacked banks in PR 3)
        self.params = (T.quantize_lm_params(params, cfg)
                       if quantize_weights else params)
        self.cfg = cfg
        # -- sharded serving (PR 5, DESIGN.md §8): place params by their
        # logical specs (transformed to the quantized QTensor layout),
        # shard the KV cache, replicate every config tensor.  All jitted
        # calls then run under the activated mapping (_ctx), so the lsc
        # constraints inside the model bake GSPMD shardings into the
        # (still unique) executables.
        self.mapping = mapping
        if mapping is not None:
            specs = param_specs
            if specs is not None and quantize_weights:
                specs = T.quantize_lm_specs(specs, cfg)
            sh = (mapping.shardings(specs, self.params)
                  if specs is not None
                  else jax.tree.map(lambda _: mapping.replicated(),
                                    self.params))
            self.params = jax.device_put(self.params, sh)
        self.max_batch = max_batch
        self.max_len = max_len
        # cfg_groups > 1 widens the knob to per-layer-per-N-block config
        # matrices (n_layers, cfg_groups): each layer's GEMMs split their
        # output columns into cfg_groups contiguous neuron groups, each
        # at its own error config (requires cfg.mac_backend == "pallas").
        # cfg_experts > 1 (MoE models) adds the expert axis in between:
        # (n_layers, cfg_experts, cfg_groups) — each expert of a MoE
        # layer at its own config via the grouped expert kernel; dense
        # GEMMs collapse the expert axis to the lowest-MRED config.
        self.cfg_groups = cfg_groups
        self.cfg_experts = cfg_experts
        if cfg_groups > 1 or cfg_experts > 1:
            assert cfg.mac_backend == "pallas", \
                "per-block/per-expert configs require mac_backend='pallas'"
        if cfg_experts > 1:
            assert cfg_experts == cfg.n_experts, (cfg_experts,
                                                  cfg.n_experts)
        # share of a MoE layer's MACs executed by the expert GEMMs (the
        # remainder — attention/router — runs at the expert-COLLAPSED
        # config): weights the expert axis in the energy integral.
        # Equal-share-per-expert modeling, like the per-group caveat in
        # energy_report.
        if cfg.n_experts > 0:
            d, h, kv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim)
            attn_macs = d * (h + 2 * kv) * hd + h * hd * d
            moe_macs = 3 * d * cfg.d_ff * max(cfg.top_k, 1)
            self._moe_mac_frac = moe_macs / (moe_macs + attn_macs)
        else:
            self._moe_mac_frac = 0.0
        self.approx_cfg = self._as_layer_vector(
            0 if approx_cfg is None else approx_cfg)
        # injected time source: request ordering, TTFT stamps, and the
        # scheduler's tick timing all read it — deterministic in tests.
        # A fault injector interposes its skew/stall view, so deadline
        # and backoff logic sees faulted time through the same source.
        self.fault_injector = fault_injector
        self.clock = (clock if fault_injector is None
                      else fault_injector.wrap_clock(clock))
        self.rng = jax.random.PRNGKey(seed)
        # bounded admission (PR 7): submit() checks the bound and
        # rejects explicitly — the maxlen is belt-and-braces so the
        # queue can never grow past its capacity even if a caller
        # appends directly
        self.queue_capacity = int(queue_capacity)
        assert self.queue_capacity > 0, queue_capacity
        self.queue: deque[Request] = deque(maxlen=self.queue_capacity)
        self.slots: list[Request | None] = [None] * max_batch
        self.slot_cfg = np.broadcast_to(
            self.approx_cfg, (max_batch,) + self.approx_cfg.shape).copy()
        # slots whose request carried its OWN approx_cfg are pinned to
        # it; unpinned slots follow the engine config live, so
        # set_approx_cfg retunes in-flight generation at the next tick
        self.slot_pinned = np.zeros(max_batch, dtype=bool)
        # -- paged KV cache (PR 8, DESIGN.md §11) ---------------------
        self.paged = paged
        self.prefill_pad = int(prefill_pad)
        if paged is not None:
            assert mapping is None, \
                "paged serving is single-host in v1 (DESIGN.md §11)"
            assert max_len % paged.block_size == 0, (max_len,
                                                     paged.block_size)
            # paged prefill always runs chunked, which needs the padded
            # one-executable prefill path
            self.prefill_pad = paged.prefill_chunk
            self.allocator = PageAllocator(paged)
            self.pages_per_slot = max_len // paged.block_size
            self.block_tables = np.full((max_batch, self.pages_per_slot),
                                        ZERO_BLOCK, dtype=np.int32)
            self.seq_lens = np.zeros(max_batch, dtype=np.int32)
            # authoritative per-slot owned-block lists, in table order
            # (block_tables is the derived device operand)
            self._slot_blocks: list[list[int]] = [[] for _ in
                                                  range(max_batch)]
            # slot -> {"tokens": np.ndarray, "next": int}: requests mid
            # chunked-prefill (excluded from the decode batch)
            self._prefill_progress: dict[int, dict] = {}
            self.n_preempted = 0
            self.n_shared_blocks = 0
            self.cache, self.cache_spec = T.init_paged_cache(
                cfg, paged.num_blocks, paged.block_size)
        else:
            self.cache, self.cache_spec = T.init_cache(cfg, max_batch,
                                                       max_len)
        if self.prefill_pad > 0 and paged is None:
            # satellite gate: padded prefill masks K/V by true_len,
            # which needs an attention-only float-KV model
            assert all(k in ("global", "local")
                       for k in cfg.layer_kinds()) and not cfg.kv_quant, \
                "prefill_pad needs an attention-only float-KV model"
        if mapping is not None:
            # canonical cache placement: kv_seq/kv_hd shard per the
            # mapping, batch over the data axis when divisible.  Kept
            # around (_cache_sh) so host-side cache surgery (_splice_
            # cache) can re-pin — the decode executable's input sharding
            # signature must never drift, or "zero retraces" breaks.
            self._cache_sh = mapping.shardings(self.cache_spec, self.cache)
            self.cache = jax.device_put(self.cache, self._cache_sh)
        self.slot_pos = np.zeros(max_batch, dtype=np.int64)
        self.n_decode_steps = 0
        self.n_prefill_tokens = 0
        self.mac_energy_pj_per_param = 0.0   # sum over tokens of E(cfg)
        self.exact_energy_pj_per_param = 0.0
        self.n_tokens_charged = 0
        # serve-only twins of the integrals above: every charge EXCEPT
        # kind="probe" (shadow probes are measurement overhead, not
        # service traffic — the scheduler's measured-pJ/token feedback
        # and the serving benches read these; the totals above keep
        # summing every executed row, probes included)
        self.serve_mac_energy_pj_per_param = 0.0
        self.n_serve_tokens_charged = 0
        # per-class split of the serve-only integrals (DESIGN.md §13):
        # class name -> accumulated pJ/param charge and tokens.  Fed by
        # every non-probe _count_energy row; the scheduler's per-class
        # budget loop (set_class_budgets) diffs these per retune.
        self.serve_energy_by_class: dict[str, float] = {}
        self.serve_tokens_by_class: dict[str, int] = {}
        # emitted-token counter (every token appended to a request):
        # the speculative bench's pJ/token denominator — under
        # speculation one verify step emits up to k+1 of these
        self.n_tokens_emitted = 0
        # every energy charge, in order: (kind, tokens, per-MAC pJ at
        # the executed config, traffic class) — the report totals are
        # exactly the sum of these rows while nothing has been evicted,
        # and per-class rows sum to the per-class counters
        # (tests/test_energy_accounting.py).  Class is None on probe
        # rows (measurement belongs to no class).  BOUNDED: the totals
        # live in the accumulators above, the log is an audit window,
        # so a long-running engine must not grow it forever.
        self.energy_log: deque[tuple[str, int, float, str | None]] = \
            deque(maxlen=65536)
        self.completed: list[Request] = []
        self._macs_per_token: float | None = None

        # -- resilience state (PR 7) ----------------------------------
        self.max_retries = int(max_retries)
        self.retry_base_s = float(retry_base_s)
        self.retry_cap_s = float(retry_cap_s)
        self.nan_max_strikes = int(nan_max_strikes)
        self.power_cap_pj_per_tick = power_cap_pj_per_tick
        self.brownout = brownout
        self.checkpointer = checkpointer
        self.snapshot_every = int(snapshot_every)
        self._jitter_seed = int(seed)
        self._draining = False
        self._backoff_until = 0.0   # injected-clock time decode resumes
        self._retry_streak = 0      # consecutive failed decode attempts
        self._nan_strikes = np.zeros(max_batch, dtype=np.int64)
        self._last_snapshot: int | None = None
        self.last_error: str | None = None
        self.n_rejected = 0
        self.n_expired = 0
        self.n_failed = 0
        self.n_retries = 0
        self.n_nan_events = 0
        self.n_quarantined = 0
        self.n_snapshots = 0
        self.n_restores = 0

        cfg_ = cfg
        cache_spec_ = self.cache_spec

        # approx_cfg is a TRACED (n_layers,) int32 argument: retuning the
        # engine or mixing request configs never retraces (PR 1).  The
        # lsc_tree pins are identities without an active mapping; under
        # one they constrain the cache in AND out to its canonical
        # sharding, so the decode-feeds-its-own-cache loop is a sharding
        # fixed point from the very first call (one executable, ever).
        if paged is not None:
            backend_ = paged.attn_backend

            @jax.jit
            def _decode(params, cache, token, acfg):
                return T.paged_decode_step(params, cfg_, cache, token,
                                           approx_cfg=acfg,
                                           backend=backend_)

            self._decode = _decode
            # two prefill executables, ever: the one-chunk fast path
            # (stock T.prefill on a chunk-length buffer — bit-identical
            # K/V to the dense engine's padded prefill; scattered into
            # the pool on the host) and the mid-prompt chunk step
            # (slot/start/count as traced scalars)
            self._prefill = jax.jit(
                lambda params, tokens, acfg, true_len: T.prefill(
                    params, cfg_, tokens, max_len=paged.prefill_chunk,
                    approx_cfg=acfg, true_len=true_len))
            self._prefill_chunk = jax.jit(
                lambda params, cache, tokens, slot, start, count, acfg:
                T.paged_prefill_chunk(params, cfg_, cache, tokens,
                                      slot=slot, start=start, count=count,
                                      approx_cfg=acfg))
        else:
            @jax.jit
            def _decode(params, cache, token, acfg):
                cache = lsc_tree(cache, cache_spec_)
                logits, new_cache = T.decode_step(params, cfg_, cache,
                                                  token, approx_cfg=acfg)
                return logits, lsc_tree(new_cache, cache_spec_)

            self._decode = _decode
            if self.prefill_pad > 0:
                # ONE compiled prefill for every prompt length: tokens
                # arrive padded to the boundary, the real length rides
                # along as a traced scalar (satellite: kills the
                # per-prompt-length retrace)
                self._prefill = jax.jit(
                    lambda params, tokens, acfg, true_len: T.prefill(
                        params, cfg_, tokens, max_len=max_len,
                        approx_cfg=acfg, true_len=true_len))
            else:
                self._prefill = jax.jit(
                    lambda params, tokens, acfg: T.prefill(
                        params, cfg_, tokens, max_len=max_len,
                        approx_cfg=acfg))

        # -- speculative decoding (PR 9, DESIGN.md §12) ----------------
        self.spec = spec
        self.n_spec_ticks = 0        # speculative ticks committed
        self.n_spec_aborts = 0       # spec ticks rolled back (NaN/fault)
        self.n_draft_tokens = 0      # draft-config tokens executed
        self.n_spec_emitted = 0      # tokens emitted by verify passes
        self.n_verify_steps = 0      # verify passes committed
        if spec is not None:
            assert mapping is None, \
                "speculative decoding is single-host in v1"
            T.verify_gate(cfg)
            W = spec.max_k + 1
            if paged is not None:
                # the verify window rides the prefill-chunk executable,
                # so it must fit one chunk
                assert W <= paged.prefill_chunk, (W, paged.prefill_chunk)
            else:
                assert W < max_len, (W, max_len)
                # ONE verify executable, ever: W is the only static
                # shape speculation adds — k and draft_cfg are host
                # loop count / traced data (zero retraces across the
                # whole (k, draft-cfg) sweep)
                self._verify = jax.jit(
                    lambda params, cache, tokens, pos, acfg:
                    T.decode_verify(params, cfg_, cache, tokens, pos,
                                    approx_cfg=acfg))

        # online power-budget scheduler (serve/scheduler.py): hooks into
        # every tick AFTER the jitted functions exist — its shadow
        # probes reuse self._decode, so the whole loop adds zero
        # compiled artifacts (asserted in tests/test_scheduler.py)
        self.scheduler = scheduler
        if scheduler is not None:
            scheduler.attach(self)
            if spec is not None and hasattr(scheduler, "configure_spec"):
                # the draft depth k becomes the scheduler's second
                # control axis (one-notch hysteresis, like the ladder)
                scheduler.configure_spec(spec.k)

    # -- sharded-serving helpers -----------------------------------------
    def _ctx(self):
        """Execution context for one tick: the mapping's mesh + the
        activated logical-axis mapping (so every ``lsc`` inside the
        traced functions resolves), or a no-op without one."""
        if self.mapping is None:
            return contextlib.nullcontext()
        es = contextlib.ExitStack()
        es.enter_context(self.mapping.mesh)
        es.enter_context(_activate(self.mapping))
        return es

    def _replicate(self, x):
        """Device-put a host value as a mesh-REPLICATED committed array
        (identity placement without a mapping).  Config tensors and
        token batches go through here: a replicated committed operand
        keeps the jitted functions' input-sharding signature constant
        across retunes/requests — the zero-retrace invariant — and is
        what lets one ``set_approx_cfg`` retune every shard at once."""
        x = jnp.asarray(x)
        if self.mapping is None:
            return x
        return jax.device_put(x, self.mapping.replicated())

    # -- config management ----------------------------------------------
    def _as_layer_vector(self, approx_cfg) -> np.ndarray:
        """Normalize int / sequence / None to the engine's config shape:
        (n_layers,) when cfg_groups == cfg_experts == 1, (n_layers,
        cfg_groups) with only neuron groups, (n_layers, cfg_experts,
        cfg_groups) with an expert axis.  Scalars broadcast everywhere;
        a per-layer vector broadcasts across experts and groups; a 2-D
        input with cfg_experts > 1 is per-layer-per-EXPERT (broadcast
        across the groups).  One fixed shape keeps every request/retune
        on the same compiled executables (zero retraces)."""
        if approx_cfg is None:
            return self.approx_cfg.copy()
        if self.cfg_experts > 1:
            shape = (self.cfg.n_layers, self.cfg_experts, self.cfg_groups)
        elif self.cfg_groups > 1:
            shape = (self.cfg.n_layers, self.cfg_groups)
        else:
            shape = (self.cfg.n_layers,)
        vec = np.asarray(approx_cfg, dtype=np.int32)
        while 1 <= vec.ndim < len(shape):
            vec = vec[..., None]
        vec = np.broadcast_to(vec, shape).copy()
        assert ((0 <= vec) & (vec < N_CONFIGS)).all(), vec
        return vec

    def set_approx_cfg(self, approx_cfg):
        """Live retune: from the next tick on, every active slot whose
        request did not pin its own config — plus all future
        admissions — runs at this config.  No recompilation (the config
        is a traced argument)."""
        self.approx_cfg = self._as_layer_vector(approx_cfg)

    def apply_allocation(self, assignment: Mapping[Any, int]):
        """Wire a ``DynamicPowerController.allocate`` result in: keys are
        layer indices, integer-suffixed names ('layer_<i>'), or — with
        cfg_experts > 1 — (layer, expert) tuples targeting one expert of
        one MoE layer; values are configs.  Layers/experts missing from
        the assignment stay at their current config.  Free-form
        controller layer names must be mapped to indices by the caller —
        unparseable or out-of-range keys raise."""
        vec = self.approx_cfg.copy()
        for key, c in assignment.items():
            expert = None
            if isinstance(key, tuple):
                if len(key) != 2 or self.cfg_experts <= 1:
                    raise ValueError(
                        f"key {key!r}: (layer, expert) tuples need "
                        f"len == 2 and an engine with cfg_experts > 1")
                key, expert = key
                expert = int(expert)
                if not 0 <= expert < self.cfg_experts:
                    raise ValueError(f"expert index {expert} out of range "
                                     f"[0, {self.cfg_experts})")
            if isinstance(key, str):
                tail = key.rsplit("_", 1)[-1]
                if not tail.isdigit():
                    raise ValueError(
                        f"layer key {key!r}: expected an integer index or "
                        f"an integer-suffixed name like 'layer_3'")
                i = int(tail)
            else:
                i = int(key)
            if not 0 <= i < self.cfg.n_layers:
                raise ValueError(f"layer index {i} (from key {key!r}) out "
                                 f"of range [0, {self.cfg.n_layers})")
            if expert is None:
                vec[i] = int(c)
            else:
                vec[i, expert] = int(c)
        self.set_approx_cfg(vec)

    def _pool_cfg(self) -> np.ndarray:
        """Decode-pool config: per layer, the lowest-MRED config among
        active slots (ties broken toward the lower config index), so no
        request executes at a higher error than it asked for.  Pinned
        slots contribute their request's config; unpinned slots track
        the engine's current config, so live retunes take effect on
        them immediately."""
        active = [self.slot_cfg[i] if self.slot_pinned[i]
                  else self.approx_cfg
                  for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return self.approx_cfg
        return pool_join(np.stack(active))  # (k, n_layers[, cfg_groups])

    # -- request management --------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit ``req`` to the bounded queue.  Returns False — and
        stamps the request ``rejected`` — when the queue is at capacity
        or the engine is draining: explicit rejection with backpressure
        beats unbounded growth (the pre-PR-7 queue was a bare list)."""
        if req.submitted_at is None:
            req.submitted_at = self.clock()
        if self._draining or len(self.queue) >= self.queue_capacity:
            req.status = "rejected"
            self.n_rejected += 1
            return False
        req.status = "queued"
        self.queue.append(req)
        return True

    @property
    def backpressure(self) -> dict:
        """Admission-pressure signal for callers and the brownout
        controller: queue depth/utilization, active slots, lifetime
        rejections, drain state."""
        bp = {"queued": len(self.queue),
              "capacity": self.queue_capacity,
              "utilization": len(self.queue) / self.queue_capacity,
              "active": sum(s is not None for s in self.slots),
              "rejected": self.n_rejected,
              "draining": self._draining}
        if self.paged is not None:
            # free-block watermark: the paged-pool pressure signal the
            # brownout controller folds into its utilization reading
            free = self.allocator.free_blocks()
            bp["kv_free_blocks"] = free
            bp["kv_utilization"] = 1.0 - free / self.paged.usable_blocks
            bp["preempted"] = self.n_preempted
        return bp

    def drain(self) -> None:
        """Stop admitting (submit rejects, _admit idles); in-flight
        slots finish — or are snapshot — in ``run``."""
        self._draining = True

    def _evict(self, slot: int, status: str) -> None:
        """Remove an in-flight request from its slot with a terminal
        status ("expired"/"failed").  The KV rows stay in the pool but
        are unreachable — the slot's next admission overwrites them."""
        req = self.slots[slot]
        if req is None:
            return
        req.status = status
        req.finished_at = self.clock()
        self.completed.append(req)
        self.slots[slot] = None
        self._nan_strikes[slot] = 0
        if self.paged is not None:
            self._release_slot(slot)
            self.slot_pos[slot] = 0
        if status == "expired":
            self.n_expired += 1
        elif status == "failed":
            self.n_failed += 1

    def _expire(self, now: float) -> None:
        """Deadline sweep from the injected clock: queued requests past
        their TTFT SLO can no longer meet it (prefill+first token would
        land late) and are expired in place; active slots past their
        e2e SLO are evicted — their remaining tokens would all be
        late, so the pool capacity goes to requests that can still
        meet their deadlines."""
        late = [r for r in self.queue
                if r.ttft_slo_s is not None
                and now - r.submitted_at > r.ttft_slo_s]
        if late:
            late_ids = {id(r) for r in late}   # dataclass __eq__ is by
            keep = [r for r in self.queue      # value — filter by identity
                    if id(r) not in late_ids]
            self.queue.clear()
            self.queue.extend(keep)
            for r in late:
                r.status = "expired"
                r.finished_at = now
                self.n_expired += 1
                self.completed.append(r)
        for i, r in enumerate(self.slots):
            if (r is not None and r.e2e_slo_s is not None
                    and now - r.submitted_at > r.e2e_slo_s):
                self._evict(i, "expired")

    def _splice_cache(self, slot: int, row_cache):
        """Copy a single-row prefill cache into slot `slot` of the pool.
        Mismatched `pos` semantics are kept per-slot in numpy.

        KV pool leaves are stacked (layers_in_block, batch, seq,
        kv_heads, head_dim) — batch is axis 1.  (This used to write
        ``pool.at[slot]``, which indexes the LAYER axis: slot k's row
        broadcast over every batch entry of layer k, silently
        corrupting every other in-flight request's cache — the exact
        shared-state poisoning class this PR's guards exist for;
        regression-pinned by tests/test_resilience.py's
        batched-vs-solo bit-identity test.)"""
        def splice(pool, row):
            if pool.ndim == 0 or row.ndim == 0:
                return pool
            assert pool.shape[1] == self.max_batch, pool.shape
            return pool.at[:, slot].set(row[:, 0])
        self.cache = jax.tree.map(splice, self.cache, row_cache)
        if self.mapping is not None:
            # re-pin the canonical sharding: the eager splice's output
            # placement is whatever GSPMD propagated, and a drifting
            # cache sharding would re-specialize the decode executable
            self.cache = jax.device_put(self.cache, self._cache_sh)

    def _energy_pj_mean(self, cfg_vec: np.ndarray) -> float:
        """Mean modeled per-MAC energy of one executed token under
        cfg_vec (power_model.energy_per_token_pj at macs_per_token=1).
        Without an expert axis this is the plain mean over (layer,
        group) cells.  With cfg_experts > 1 only the expert GEMMs run
        at their own configs — every dense GEMM of the layer executes
        at the expert-COLLAPSED (lowest-measured-MRED) config
        (layers.dense / ops.collapse_expert_cfg) — so the expert-axis
        mean is weighted by the MoE share of MACs and the dense share is
        charged at the collapsed config."""
        return energy_per_token_pj(cfg_vec,
                                   moe_mac_frac=self._moe_mac_frac)

    def _cls_counts(self, active: list[int]) -> dict[str, int]:
        """Token split of one pooled charge by the active slots'
        traffic classes (one token per slot per step) — the ``cls``
        operand of ``_count_energy`` for batched charges."""
        out: dict[str, int] = {}
        for i in active:
            c = self.slots[i].cls or "default"
            out[c] = out.get(c, 0) + 1
        return out

    def _count_energy(self, tokens: int, cfg_vec: np.ndarray,
                      kind: str = "decode", cls=None):
        """Charge ``tokens`` executed tokens at ``cfg_vec``.

        ``cls`` attributes the charge to traffic classes (DESIGN.md
        §13): a class name, a ``{class: tokens}`` split of a pooled
        charge (``_cls_counts``), or None — unattributed serve charges
        land on class "default"; probe charges are classless (they are
        measurement, not any class's traffic).  One ``energy_log`` row
        is appended PER CLASS, so rows keep summing to the report
        totals and per-class rows sum to the per-class counters."""
        pj = self._energy_pj_mean(cfg_vec)
        self.mac_energy_pj_per_param += tokens * pj
        self.exact_energy_pj_per_param += tokens * float(_ENERGY_PJ[0])
        self.n_tokens_charged += tokens
        if isinstance(cls, str) or cls is None:
            split = {cls or "default": int(tokens)}
        else:
            split = {str(c): int(n) for c, n in cls.items() if n}
        assert sum(split.values()) == int(tokens), (split, tokens)
        if kind != "probe":
            # shadow probes (scheduler.on_step) are billed — they are
            # real executed decodes, and energy_log rows must keep
            # summing to the report totals — but stay OUT of the
            # serve-only counters: measurement overhead must not read
            # as service traffic in the budget-feedback integral
            self.serve_mac_energy_pj_per_param += tokens * pj
            self.n_serve_tokens_charged += tokens
            for c, n in split.items():
                self.serve_energy_by_class[c] = (
                    self.serve_energy_by_class.get(c, 0.0) + n * pj)
                self.serve_tokens_by_class[c] = (
                    self.serve_tokens_by_class.get(c, 0) + n)
            for c, n in sorted(split.items()):
                self.energy_log.append((kind, n, pj, c))
        else:
            self.energy_log.append((kind, tokens, pj, None))

    def _admission_power_ok(self, req_cfg: np.ndarray,
                            pinned: bool) -> bool:
        """Power gate: admit only while the pool's modeled energy rate
        — (active+1) tokens/tick at the candidate pool config — stays
        under ``power_cap_pj_per_tick``.  The candidate joins the pool
        the same way _pool_cfg will, so the gate prices exactly the
        config the pool would execute.  This is the brownout lever:
        stepping configs down lowers pJ/token, so more slots fit under
        the cap and the queue drains instead of rejecting."""
        if self.power_cap_pj_per_tick is None:
            return True
        stack = [self.slot_cfg[i] if self.slot_pinned[i]
                 else self.approx_cfg
                 for i, r in enumerate(self.slots) if r is not None]
        stack.append(req_cfg if pinned else self.approx_cfg)
        cand = pool_join(np.stack(stack))
        pj_per_tick = (len(stack) * self._energy_pj_mean(cand)
                       * self.macs_per_token)
        return pj_per_tick <= self.power_cap_pj_per_tick

    def _admit(self):
        if self._draining:
            return
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                req = self.queue[0]
                req_cfg = self._as_layer_vector(req.approx_cfg)
                pinned = req.approx_cfg is not None
                if not self._admission_power_ok(req_cfg, pinned):
                    # head-of-line wait, not a skip: FIFO order is part
                    # of the fairness contract, and the brownout/
                    # scheduler lowering pJ/token is what unblocks it
                    break
                self.queue.popleft()
                req.status = "active"
                self._nan_strikes[slot] = 0
                self.slot_pinned[slot] = pinned
                toks = np.asarray(req.prompt, np.int32).reshape(-1)
                true_len = toks.shape[0]
                if self.prefill_pad > 0:
                    # pad to the boundary and pass the true length as a
                    # TRACED scalar: every prompt length in a boundary
                    # bucket shares ONE compiled prefill (satellite:
                    # kills the per-prompt-length retrace)
                    pad = (-true_len) % self.prefill_pad
                    if pad:
                        toks = np.concatenate(
                            [toks, np.zeros(pad, np.int32)])
                    assert toks.shape[0] <= self.max_len, (toks.shape,
                                                           self.max_len)
                    tokens = self._replicate(
                        jnp.asarray(toks, jnp.int32)[None, :])
                    logits, row_cache = self._prefill(
                        self.params, tokens, self._replicate(req_cfg),
                        jnp.asarray(true_len, jnp.int32))
                else:
                    tokens = self._replicate(
                        jnp.asarray(toks, jnp.int32)[None, :])
                    logits, row_cache = self._prefill(
                        self.params, tokens, self._replicate(req_cfg))
                self.n_prefill_tokens += true_len
                # energy charges the EXECUTED width (padded)
                self._count_energy(tokens.shape[1], req_cfg, "prefill",
                                   cls=req.cls)
                self._splice_cache(slot, row_cache)
                self.slot_pos[slot] = true_len
                self.slot_cfg[slot] = req_cfg
                self.rng, k = jax.random.split(self.rng)
                first = sample(logits, k, temperature=req.temperature)
                req.tokens.append(int(first[0]))
                self.n_tokens_emitted += 1
                req.first_token_at = self.clock()
                self.slots[slot] = req

    # -- paged serving (PR 8, DESIGN.md §11) -----------------------------
    def _release_slot(self, slot: int) -> None:
        """Free a paged slot's blocks and reset its table row to the
        zero block (gathers read zeros, like dense rows past pos)."""
        self.allocator.release(self._slot_blocks[slot])
        self._slot_blocks[slot] = []
        self.block_tables[slot] = ZERO_BLOCK
        self.seq_lens[slot] = 0
        self._prefill_progress.pop(slot, None)

    def _paged_operands(self, active_mask=None) -> dict:
        """Pool leaves + the three int32/bool DATA operands the paged
        executables read: block tables, sequence lengths, active mask.
        Data, never shapes — the zero-retrace invariant."""
        cache = dict(self.cache)
        # .copy(): jnp.asarray of a host ndarray may be zero-copy on CPU,
        # and the tick mutates block_tables/seq_lens in place after the
        # dispatch — the operands must be immutable snapshots
        cache["tables"] = self._replicate(
            jnp.asarray(self.block_tables.copy(), jnp.int32))
        cache["seq_lens"] = self._replicate(
            jnp.asarray(self.seq_lens.copy(), jnp.int32))
        if active_mask is None:
            active_mask = np.zeros(self.max_batch, dtype=bool)
        cache["active"] = self._replicate(jnp.asarray(active_mask))
        return cache

    def _copy_block(self, src: int, dst: int) -> None:
        """Copy one block's K/V across every pool leaf (COW fault)."""
        def cp(pool):
            if pool.ndim == 4:                     # (NB, bs, KV, hd)
                return pool.at[dst].set(pool[src])
            return pool.at[:, dst].set(pool[:, src])   # scan: (G, NB, ...)
        self.cache = jax.tree.map(cp, self.cache)

    def _scatter_prefill(self, slot: int, row_cache, count: int) -> None:
        """Host-scatter a one-chunk dense prefill row into the slot's
        blocks.  The fast admission path runs stock ``T.prefill`` on a
        chunk-length buffer — the same compute the dense engine's padded
        prefill does, so the scattered K/V is bit-identical to the dense
        pool's rows (positions >= count were zeroed by true_len)."""
        bs = self.paged.block_size
        blocks = self._slot_blocks[slot][: self.paged.blocks_for(count)]

        def scatter(pool, row):
            if pool.ndim == 4:                     # rest: row (1, C, ...)
                for i, blk in enumerate(blocks):
                    pool = pool.at[blk].set(row[0, i * bs:(i + 1) * bs])
                return pool
            for i, blk in enumerate(blocks):       # scan: row (G, 1, C, ...)
                pool = pool.at[:, blk].set(row[:, 0, i * bs:(i + 1) * bs])
            return pool

        row = {k: v for k, v in row_cache.items() if k != "pos"}
        self.cache = jax.tree.map(scatter, self.cache, row)

    def _preemption_victim(self) -> int | None:
        """Youngest in-flight request (latest submitted_at, ties toward
        the higher slot): cheapest to recompute, fairest to the oldest
        streams."""
        best, best_t = None, -np.inf
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            t = r.submitted_at if r.submitted_at is not None else 0.0
            if t >= best_t:
                best, best_t = i, t
        return best

    def _preempt(self, slot: int) -> None:
        """Preempt-by-recompute: free the victim's blocks and requeue it
        at the FRONT.  Its generated tokens ride along, so re-admission
        re-prefills prompt+generated and the stream continues exactly
        where it stopped (greedy decode: token-identical)."""
        req = self.slots[slot]
        if req is None:
            return
        self.n_preempted += 1
        self._release_slot(slot)
        self.slots[slot] = None
        self.slot_pos[slot] = 0
        self._nan_strikes[slot] = 0
        if len(self.queue) >= self.queue_capacity:
            req.status = "rejected"
            req.finished_at = self.clock()
            self.n_rejected += 1
            self.completed.append(req)
        else:
            req.status = "queued"
            self.queue.appendleft(req)

    def _admit_paged(self) -> None:
        """FIFO admission into free slots: reuse any cached prompt
        prefix (fork its blocks), reserve the first chunk's blocks, and
        register the request for chunked prefill.  Block shortage is a
        head-of-line wait, like the power gate."""
        if self._draining:
            return
        p = self.paged
        bs = p.block_size
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            req_cfg = self._as_layer_vector(req.approx_cfg)
            pinned = req.approx_cfg is not None
            if not self._admission_power_ok(req_cfg, pinned):
                break
            # resumed (preempted) requests re-prefill prompt+generated;
            # the LAST generated token stays out — it is the next decode
            # input, exactly as if the preemption never happened
            toks = np.asarray(req.prompt, np.int32).reshape(-1)
            resumed = bool(req.tokens)
            if resumed:
                toks = np.concatenate(
                    [toks, np.asarray(req.tokens[:-1], np.int32)])
            # a request whose PEAK committed length can never fit the
            # block pool must be rejected up front (satellite fix): it
            # used to be admitted, starve, preempt every other stream
            # and re-queue itself at the front — an eternal livelock.
            # Peak entries: generation stops at min(prompt + max_new
            # - 1, max_len - 1) committed cache entries (the first
            # token is sampled off the prefill, costing no entry).
            peak = min(len(np.asarray(req.prompt).reshape(-1))
                       + req.max_new_tokens - 1, self.max_len - 1)
            if (toks.size >= self.max_len
                    or p.blocks_for(peak) > p.usable_blocks):
                self.queue.popleft()
                req.status = "rejected"
                req.finished_at = self.clock()
                self.n_rejected += 1
                self.completed.append(req)
                continue
            shared = self.allocator.match_prefix(toks)
            start = len(shared) * bs
            first_end = min(toks.size, start + p.prefill_chunk)
            need = p.blocks_for(first_end) - len(shared)
            if not self.allocator.can_alloc(need):
                break                      # wait for blocks, FIFO order
            self.queue.popleft()
            req.status = "active"
            self._nan_strikes[slot] = 0
            self.slot_pinned[slot] = pinned
            self.slot_cfg[slot] = req_cfg
            blocks = self.allocator.fork(shared)
            self.n_shared_blocks += len(shared)
            self._slot_blocks[slot] = blocks
            self.block_tables[slot] = ZERO_BLOCK
            self.block_tables[slot, :len(blocks)] = blocks
            self.seq_lens[slot] = start
            self.slot_pos[slot] = start
            self._prefill_progress[slot] = {"tokens": toks,
                                            "next": start,
                                            "resumed": resumed}
            self.slots[slot] = req

    def _register_prefix_blocks(self, slot: int, toks: np.ndarray) -> None:
        """Publish the slot's FULL prompt blocks for prefix reuse, keyed
        by the token prefix they hold.  Full blocks are never written
        again (decode appends past them), so sharing them is safe
        without a copy; shared keys that already exist are no-ops."""
        bs = self.paged.block_size
        blocks = self._slot_blocks[slot]
        for i in range(toks.size // bs):
            key = tuple(int(t) for t in toks[: (i + 1) * bs])
            self.allocator.register_prefix(key, blocks[i])

    def _advance_prefills(self) -> None:
        """Advance every mid-prefill slot by ONE chunk this tick —
        chunked prefill interleaves with decode instead of monopolizing
        ticks.  Single-chunk fresh prompts take the fast path (stock
        prefill + host scatter: bit-identical K/V to the dense engine);
        continuations run the paged chunk executable."""
        p = self.paged
        bs, C = p.block_size, p.prefill_chunk
        for slot in sorted(self._prefill_progress):
            if slot not in self._prefill_progress:
                continue       # preempted by an earlier slot this tick
            prog = self._prefill_progress[slot]
            toks, start = prog["tokens"], prog["next"]
            count = int(min(C, toks.size - start))
            end = start + count
            have = len(self._slot_blocks[slot])
            need = p.blocks_for(end) - have
            if need > 0:
                # starved-pool escape (satellite fix): the decode path
                # preempts the youngest request when it cannot get a
                # write block (_ensure_write_blocks), but this path
                # used to just wait — two mid-prefill slots that
                # exhaust the pool then DEADLOCK forever, each holding
                # blocks the other needs while no decode tick ever
                # runs.  Preempt-by-recompute breaks the cycle; a slot
                # never preempts itself (if it is the youngest, an
                # older stuck slot's escape will preempt it instead)
                while not self.allocator.can_alloc(need):
                    victim = self._preemption_victim()
                    if victim is None or victim == slot:
                        break
                    self._preempt(victim)
                if not self.allocator.can_alloc(need):
                    continue               # pool short; retry next tick
                have = len(self._slot_blocks[slot])
                new = self.allocator.alloc_n(need)
                self._slot_blocks[slot].extend(new)
                self.block_tables[slot, have:have + need] = new
            req = self.slots[slot]
            cfg_vec = (self.slot_cfg[slot] if self.slot_pinned[slot]
                       else self.approx_cfg)
            acfg = self._replicate(cfg_vec)
            buf = np.zeros((1, C), np.int32)
            buf[0, :count] = toks[start:end]
            tokens = self._replicate(jnp.asarray(buf))
            if start == 0 and toks.size <= C:
                logits, row_cache = self._prefill(
                    self.params, tokens, acfg,
                    jnp.asarray(count, jnp.int32))
                self._scatter_prefill(slot, row_cache, count)
            else:
                logits, new_leaves = self._prefill_chunk(
                    self.params, self._paged_operands(), tokens,
                    jnp.asarray(slot, jnp.int32),
                    jnp.asarray(start, jnp.int32),
                    jnp.asarray(count, jnp.int32), acfg)
                self.cache = new_leaves
                # the chunk executable returns EVERY position's logits
                # (the speculative verify consumes all rows); prefill
                # completion samples from the last true one
                logits = logits[:, count - 1]
            self.n_prefill_tokens += count       # TRUE tokens advanced
            self._count_energy(C, cfg_vec, "prefill",  # executed width
                               cls=req.cls)
            self.seq_lens[slot] = end
            self.slot_pos[slot] = end
            prog["next"] = end
            if end == toks.size:
                del self._prefill_progress[slot]
                self._register_prefix_blocks(slot, toks)
                if not prog["resumed"]:
                    self.rng, k = jax.random.split(self.rng)
                    first = sample(logits, k,
                                   temperature=req.temperature)
                    req.tokens.append(int(first[0]))
                    self.n_tokens_emitted += 1
                if req.first_token_at is None:
                    req.first_token_at = self.clock()

    def _ensure_write_blocks(self, decodable: list[int]) -> list[int]:
        """Give every decode row a writable tail block for this tick's
        K/V scatter; preempt the youngest request when the pool runs
        dry.  Returns the rows that still hold a slot afterwards."""
        bs = self.paged.block_size
        rows: list[int] = []
        for i in decodable:
            if self.slots[i] is None:
                continue
            page = int(self.seq_lens[i]) // bs
            if page >= len(self._slot_blocks[i]):
                while not self.allocator.can_alloc(1):
                    victim = self._preemption_victim()
                    if victim is None:
                        break
                    self._preempt(victim)
                    if victim in rows:
                        rows.remove(victim)
                    if victim == i:
                        break
                if self.slots[i] is None:
                    continue               # preempted itself
                blk = self.allocator.alloc()
                self._slot_blocks[i].append(blk)
                self.block_tables[i, page] = blk
            else:
                # defensive COW: normal flow never shares a partial
                # block (match_prefix only returns FULL blocks), but a
                # shared tail must never be written in place
                old = self._slot_blocks[i][page]
                blk, copied = self.allocator.ensure_writable(old)
                if copied:
                    self._copy_block(old, blk)
                    self._slot_blocks[i][page] = blk
                    self.block_tables[i, page] = blk
            rows.append(i)
        return rows

    # -- speculative decoding (PR 9, DESIGN.md §12) ----------------------
    def _spec_k(self) -> int:
        """Live draft depth: the scheduler's draft-k control axis when
        one is attached (one-notch hysteresis backoff + recovery),
        else the configured k — always capped by the static window
        bound max_k (k itself is a host loop count, never a shape)."""
        k = self.spec.k
        if self.scheduler is not None:
            k = getattr(self.scheduler, "draft_k", None) or k
        return max(1, min(int(k), self.spec.max_k))

    def set_spec(self, spec: SpecConfig) -> None:
        """Live retarget of the draft axis — no recompilation: the
        draft config is traced DATA and k is a host loop count.  Only
        ``max_k`` is pinned (the verify window W = max_k + 1 is the
        one compiled shape speculation adds)."""
        assert self.spec is not None, "Engine(spec=...) required"
        assert spec.max_k == self.spec.max_k, (spec.max_k,
                                               self.spec.max_k)
        self.spec = spec
        if (self.scheduler is not None
                and hasattr(self.scheduler, "configure_spec")):
            self.scheduler.configure_spec(spec.k)

    def _trim_slot_blocks(self, slot: int, keep: int) -> None:
        """Release a paged slot's owned blocks past index ``keep`` —
        the speculative rewind: blocks allocated for rejected draft
        entries go back to the pool, their table columns re-zero so
        gathers past the committed length read zeros again.  Only
        blocks this spec tick allocated are ever trimmed (callers pass
        keep >= the pre-tick count), so shared/COW prefix blocks are
        untouchable here."""
        surplus = self._slot_blocks[slot][keep:]
        if not surplus:
            return
        self.allocator.release(surplus)
        del self._slot_blocks[slot][keep:]
        self.block_tables[slot, keep:] = ZERO_BLOCK

    def _rewind_slot(self, slot: int, new_len: int, keep: int) -> None:
        """Roll a paged slot's committed length back to ``new_len``
        (speculative abort/rejection): seq_lens rewinds and the spec-
        allocated surplus blocks are released.  Stale K/V past new_len
        needs no scrub — entries are masked by seq_lens and rewritten
        before any read, the same write-before-read invariant normal
        decode relies on."""
        self.seq_lens[slot] = new_len
        self._trim_slot_blocks(slot, keep)

    def _spec_ok_dense(self, active: list[int]) -> bool:
        """Dense spec-tick eligibility: every participant greedy (the
        acceptance rule only exists under argmax) and the whole static
        window inside the cache (the lockstep pool writes the window
        at the shared pool position)."""
        if any(self.slots[i].temperature > 0.0 for i in active):
            return False
        P = int(self.slot_pos[active].max())
        return P + self.spec.max_k + 1 <= self.max_len

    def _spec_ok_paged(self, active: list[int]) -> bool:
        """Paged eligibility: greedy participants, window headroom per
        slot, and the WHOLE window's blocks allocatable up front — the
        draft loop must never preempt a fellow participant mid-tick."""
        if any(self.slots[i].temperature > 0.0 for i in active):
            return False
        k = self._spec_k()
        p = self.paged
        need = 0
        for i in active:
            P = int(self.seq_lens[i])
            if P + k + 1 > self.max_len:
                return False
            need += max(0, p.blocks_for(P + k + 1)
                        - len(self._slot_blocks[i]))
        return self.allocator.can_alloc(need)

    def _spec_tick_dense(self, active: list[int], now: float, inj):
        """Speculative dense tick: k draft steps at the draft config
        (functional cache updates — draft K/V lives only in discarded
        intermediate leaves, so the rollback is free), then ONE
        ``decode_verify`` pass at the pool config from the PRE-draft
        cache.  The dense cache position is lockstep, so the pool
        advances the MINIMUM acceptance over participants; each slot
        still emits its OWN verifier argmaxes (valid: a_pool never
        exceeds any slot's own agreeing prefix + 1)."""
        spec = self.spec
        k = self._spec_k()
        W = spec.max_k + 1
        P = int(self.slot_pos[active].max())
        draft_vec = self._as_layer_vector(spec.draft_cfg)
        pool_cfg = self._pool_cfg()
        tokens = np.zeros((self.max_batch, W), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].tokens[-1]
        cache = dict(self.cache)
        cache["pos"] = self._replicate(jnp.asarray(P, jnp.int32))
        draft_acfg = self._replicate(draft_vec)
        try:
            for j in range(1, k + 1):
                dlogits, cache = self._decode(
                    self.params, cache,
                    self._replicate(jnp.asarray(tokens[:, j - 1:j])),
                    draft_acfg)
                if not np.isfinite(np.asarray(dlogits)[active]).all():
                    raise _SpecAbort("non-finite draft logits")
                self._count_energy(len(active), draft_vec, "spec_draft",
                                   cls=self._cls_counts(active))
                self.n_draft_tokens += len(active)
                tokens[:, j] = np.asarray(
                    jnp.argmax(dlogits, axis=-1).astype(jnp.int32))
            # ONE verify pass at the pool config from the PRE-draft
            # cache: its K/V writes at entries P..P+W-1 are the only
            # ones that commit, so the cache is service-config state
            # end to end
            if inj is not None:
                inj.check_step_fail()
            vlogits, new_cache = self._verify(
                self.params, dict(self.cache),
                self._replicate(jnp.asarray(tokens)),
                jnp.asarray(P, jnp.int32), self._replicate(pool_cfg))
            if inj is not None:
                vlogits = inj.corrupt_logits(vlogits, active)
        except _SpecAbort:
            # the DRAFT config corrupted: nothing committed, nothing to
            # quarantine (the pool config is innocent) — skip the tick
            self.n_spec_aborts += 1
            return True
        except Exception as err:  # noqa: BLE001 — same retry contract
            self.n_spec_aborts += 1          # as the normal decode path
            self._record_failure(active, now, err)
            return True
        rows = np.asarray(vlogits)
        bad = [i for i in active
               if not np.isfinite(rows[i, :k + 1]).all()]
        if bad:
            # the POOL config corrupted the verify: the standard
            # quarantine response (cache uncommitted — rollback free)
            self.n_spec_aborts += 1
            self._quarantine(bad, pool_cfg)
            return True
        self.cache = new_cache
        self._retry_streak = 0
        self.n_spec_ticks += 1
        self.n_verify_steps += len(active)
        # the verify chunk is ONE weight-pass over the params per slot:
        # one service-config token-charge each (weight-bound energy
        # model, DESIGN.md §12) vs k draft-config charges above
        self._count_energy(len(active), pool_cfg, "spec_verify",
                           cls=self._cls_counts(active))
        exact = np.asarray(jnp.argmax(vlogits, axis=-1).astype(jnp.int32))
        a_pool = k + 1
        accepted: dict[int, int] = {}
        for i in active:
            js = longest_agreeing_prefix(tokens[i, 1:k + 1],
                                         exact[i, :k])
            accepted[i] = js
            a_pool = min(a_pool, js + 1)
        if (self.scheduler is not None
                and hasattr(self.scheduler, "record_spec")):
            for i in active:
                self.scheduler.record_spec(accepted[i], k, draft_vec)
        for i in active:
            req = self.slots[i]
            done = False
            for j in range(a_pool):
                req.tokens.append(int(exact[i, j]))
                self.n_spec_emitted += 1
                self.n_tokens_emitted += 1
                if (len(req.tokens) >= req.max_new_tokens
                        or self.slot_pos[i] + j + 1 >= self.max_len - 1):
                    done = True
                    break
            self.slot_pos[i] += a_pool
            if done:
                req.done = True
                req.status = "done"
                req.finished_at = self.clock()
                # repro-lint: disable=bounded-state — completed holds the run()'s return payload, one entry per submitted request; bounding it would silently drop finished results
                self.completed.append(req)
                self.slots[i] = None
                self._nan_strikes[i] = 0
        if (self.snapshot_every and self.checkpointer is not None
                and (self.n_decode_steps + self.n_spec_ticks)
                % self.snapshot_every == 0):
            self.save_snapshot()
        if self.scheduler is not None:
            self.scheduler.on_tick(self)
        return True

    def _spec_tick_paged(self, active: list[int], now: float, inj):
        """Speculative paged tick: k committed draft steps (entries
        P..P+k-1 at the draft config — every one overwritten by the
        verify chunk, so stale draft state is never read), then per
        slot ONE chunked verify pass at the pool config through the
        SAME prefill-chunk executable, per-slot acceptance, and a
        seq_lens/block-table rewind past the acceptance point."""
        p = self.paged
        spec = self.spec
        k = self._spec_k()
        P0 = {i: int(self.seq_lens[i]) for i in active}
        pre_blocks = {i: len(self._slot_blocks[i]) for i in active}
        draft_vec = self._as_layer_vector(spec.draft_cfg)
        pool_cfg = self._pool_cfg()
        active_mask = np.zeros(self.max_batch, dtype=bool)
        active_mask[active] = True
        tokens = np.zeros((self.max_batch, k + 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].tokens[-1]
        draft_acfg = self._replicate(draft_vec)

        def rollback(slots_):
            for s in slots_:
                self._rewind_slot(s, P0[s], pre_blocks[s])

        try:
            for j in range(1, k + 1):
                # writable page for entry seq_lens: the eligibility
                # gate pre-checked can_alloc for the whole window, so
                # this never preempts a participant
                self._ensure_write_blocks(active)
                dlogits, new_leaves = self._decode(
                    self.params, self._paged_operands(active_mask),
                    self._replicate(jnp.asarray(tokens[:, j - 1:j])),
                    draft_acfg)
                if not np.isfinite(np.asarray(dlogits)[active]).all():
                    raise _SpecAbort("non-finite draft logits")
                self.cache = new_leaves
                self._count_energy(len(active), draft_vec, "spec_draft",
                                   cls=self._cls_counts(active))
                self.n_draft_tokens += len(active)
                tokens[:, j] = np.asarray(
                    jnp.argmax(dlogits, axis=-1).astype(jnp.int32))
                for i in active:
                    self.seq_lens[i] += 1
        except _SpecAbort:
            rollback(active)
            self.n_spec_aborts += 1
            return True
        except Exception as err:  # noqa: BLE001
            rollback(active)
            self.n_spec_aborts += 1
            self._record_failure(active, now, err)
            return True
        # one more writable page for the verify window's last entry P+k
        self._ensure_write_blocks(active)
        C = p.prefill_chunk
        committed = 0
        pending = list(active)
        while pending:
            i = pending[0]
            try:
                if inj is not None:
                    inj.check_step_fail()
                buf = np.zeros((1, C), np.int32)
                buf[0, :k + 1] = tokens[i, :k + 1]
                vlogits, new_leaves = self._prefill_chunk(
                    self.params, self._paged_operands(),
                    self._replicate(jnp.asarray(buf)),
                    jnp.asarray(i, jnp.int32),
                    jnp.asarray(P0[i], jnp.int32),
                    jnp.asarray(k + 1, jnp.int32),
                    self._replicate(pool_cfg))
            except Exception as err:  # noqa: BLE001
                rollback(pending)
                self.n_spec_aborts += 1
                self._record_failure(pending, now, err)
                break
            rows = np.asarray(vlogits)
            if not np.isfinite(rows[0, :k + 1]).all():
                rollback(pending)
                self.n_spec_aborts += 1
                self._quarantine([i], pool_cfg)
                break
            pending.pop(0)
            self.cache = new_leaves
            self._count_energy(1, pool_cfg, "spec_verify",
                               cls=self.slots[i].cls)
            self.n_verify_steps += 1
            committed += 1
            exact = np.asarray(jnp.argmax(
                vlogits[0, :k + 1], axis=-1).astype(jnp.int32))
            js = longest_agreeing_prefix(tokens[i, 1:k + 1], exact[:k])
            a = js + 1
            if (self.scheduler is not None
                    and hasattr(self.scheduler, "record_spec")):
                self.scheduler.record_spec(js, k, draft_vec)
            req = self.slots[i]
            done = False
            for j in range(a):
                req.tokens.append(int(exact[j]))
                self.n_spec_emitted += 1
                self.n_tokens_emitted += 1
                if (len(req.tokens) >= req.max_new_tokens
                        or self.slot_pos[i] + j + 1 >= self.max_len - 1):
                    done = True
                    break
            self.seq_lens[i] = P0[i] + a
            self.slot_pos[i] += a
            if done:
                req.done = True
                req.status = "done"
                req.finished_at = self.clock()
                # repro-lint: disable=bounded-state — completed holds the run()'s return payload, one entry per submitted request; bounding it would silently drop finished results
                self.completed.append(req)
                self.slots[i] = None
                self._nan_strikes[i] = 0
                self._release_slot(i)
                self.slot_pos[i] = 0
            else:
                # rejected draft entries' surplus blocks go back; only
                # blocks THIS tick allocated are candidates
                self._trim_slot_blocks(
                    i, max(p.blocks_for(P0[i] + a), pre_blocks[i]))
        if not committed:
            return True
        self._retry_streak = 0
        self.n_spec_ticks += 1
        if (self.snapshot_every and self.checkpointer is not None
                and (self.n_decode_steps + self.n_spec_ticks)
                % self.snapshot_every == 0):
            self.save_snapshot()
        if self.scheduler is not None:
            self.scheduler.on_tick(self)
        return True

    def _step_paged(self):
        """One paged tick: the dense tick's preamble, then chunked
        prefill for mid-prompt slots and ONE batched decode step for the
        rest — through the same compiled executables every tick."""
        inj = self.fault_injector
        if inj is not None:
            inj.begin_tick(self)
        if self.brownout is not None:
            self.brownout.on_tick(self)
        now = self.clock()
        self._expire(now)
        in_flight = bool(self.queue
                         or any(s is not None for s in self.slots))
        if now < self._backoff_until:
            return in_flight
        self._admit_paged()
        self._advance_prefills()
        active = self._ensure_write_blocks(
            [i for i, r in enumerate(self.slots)
             if r is not None and i not in self._prefill_progress])
        if not active:
            return bool(self.queue
                        or any(s is not None for s in self.slots))
        if self.spec is not None and self._spec_ok_paged(active):
            return self._spec_tick_paged(active, now, inj)
        token = np.zeros((self.max_batch, 1), dtype=np.int32)
        active_mask = np.zeros(self.max_batch, dtype=bool)
        for i in active:
            token[i, 0] = self.slots[i].tokens[-1]
            active_mask[i] = True
        pool_cfg = self._pool_cfg()
        cache = self._paged_operands(active_mask)
        token = self._replicate(token)
        try:
            if inj is not None:
                inj.check_step_fail()
            logits, new_leaves = self._decode(self.params, cache, token,
                                              self._replicate(pool_cfg))
            if inj is not None:
                logits = inj.corrupt_logits(logits, active)
        except Exception as err:  # noqa: BLE001 — retry path, like _step
            self._record_failure(active, now, err)
            return True
        # NaN/Inf guard BEFORE the pool commits: rollback stays free —
        # the scatters happened in the discarded new leaves and
        # seq_lens has not advanced, so the freshly ensured write
        # blocks are simply rewritten on the retry tick
        rows = np.asarray(logits)
        bad = [i for i in active if not np.isfinite(rows[i]).all()]
        if bad:
            self._quarantine(bad, pool_cfg)
            return True
        self.cache = new_leaves
        self._retry_streak = 0
        self.n_decode_steps += 1
        self._count_energy(len(active), pool_cfg,
                           cls=self._cls_counts(active))
        feedback = 1 if inj is None else inj.probe_multiplicity()
        if self.scheduler is not None:
            # `cache` still holds the PRE-step operands (tables, lens,
            # old pool), so the shadow probe re-runs this exact step
            # through the same executable.  dup_probe chaos duplicates
            # the TELEMETRY delivery, never the probe decode: the
            # multiplicity rides into on_step, which runs the compute
            # once and records the outcome `feedback` times
            self.scheduler.on_step(self, active, cache, token,
                                   logits, pool_cfg,
                                   multiplicity=feedback)
        self.rng, k = jax.random.split(self.rng)
        temps = np.asarray([r.temperature if r is not None else 0.0
                            for r in self.slots], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        if np.any(temps[active] > 0.0):
            safe = np.where(temps > 0.0, temps, 1.0).astype(np.float32)
            drawn = np.asarray(sample(
                logits / jnp.asarray(safe)[:, None], k))
            nxt = np.where(temps > 0.0, drawn, greedy)
        else:
            nxt = greedy
        for i in active:
            req = self.slots[i]
            self.seq_lens[i] += 1
            req.tokens.append(int(nxt[i]))
            self.n_tokens_emitted += 1
            self.slot_pos[i] += 1
            if (len(req.tokens) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                req.status = "done"
                req.finished_at = self.clock()
                # repro-lint: disable=bounded-state — completed holds the run()'s return payload, one entry per submitted request; bounding it would silently drop finished results
                self.completed.append(req)
                self.slots[i] = None
                self._nan_strikes[i] = 0
                self._release_slot(i)
                self.slot_pos[i] = 0
        if (self.snapshot_every and self.checkpointer is not None
                and self.n_decode_steps % self.snapshot_every == 0):
            self.save_snapshot()
        if self.scheduler is not None:
            self.scheduler.on_tick(self)
        return True

    # -- main loop ------------------------------------------------------
    def step(self):
        """One engine tick: admit requests, one decode step for the pool.
        Runs under the sharding mapping's mesh context when one is
        attached (a no-op single-host otherwise)."""
        with self._ctx():
            return self._step()

    def _step(self):
        if self.paged is not None:
            return self._step_paged()
        inj = self.fault_injector
        if inj is not None:
            inj.begin_tick(self)
        if self.brownout is not None:
            # before admission, so a level change prices THIS tick's
            # power-gated admissions
            self.brownout.on_tick(self)
        now = self.clock()
        self._expire(now)
        if now < self._backoff_until:
            # failure backoff window: hold decoding (and admission —
            # whatever failed the decode likely fails prefill too)
            return bool(self.queue
                        or any(s is not None for s in self.slots))
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return False
        if self.spec is not None and self._spec_ok_dense(active):
            return self._spec_tick_dense(active, now, inj)
        token = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i in active:
            token[i, 0] = self.slots[i].tokens[-1]
        # pool-level pos: decode_step uses a scalar cache pos; per-slot
        # positions differ after splicing — the pool position is the max,
        # and per-slot validity is handled by each row's own written range
        # (rows beyond a slot's true length hold zeros written at admit).
        pos = int(self.slot_pos[active].max())
        pool_cfg = self._pool_cfg()
        cache = dict(self.cache)
        cache["pos"] = self._replicate(jnp.asarray(pos, jnp.int32))
        token = self._replicate(token)
        try:
            if inj is not None:
                inj.check_step_fail()
            logits, new_cache = self._decode(self.params, cache, token,
                                             self._replicate(pool_cfg))
            if inj is not None:
                logits = inj.corrupt_logits(logits, active)
        except Exception as err:  # noqa: BLE001 — any decode failure
            # enters the retry path; the cause is kept in last_error
            self._record_failure(active, now, err)
            return True
        # NaN/Inf guard BEFORE the cache commits and BEFORE the
        # scheduler sees the logits: a corrupted step must neither
        # poison the shared pool nor pollute probe feedback.  Rollback
        # is free — self.cache still holds the pre-step state — and the
        # slot's token is simply re-decoded next tick.
        rows = np.asarray(logits)
        bad = [i for i in active if not np.isfinite(rows[i]).all()]
        if bad:
            self._quarantine(bad, pool_cfg)
            return True
        self.cache = new_cache
        self._retry_streak = 0
        self.n_decode_steps += 1
        # one token comes out of every active slot this tick
        self._count_energy(len(active), pool_cfg,
                           cls=self._cls_counts(active))
        # drop_probe/dup_probe chaos: scheduler feedback is delivered
        # 0, 1 or 2 times — the control loop must tolerate lost and
        # at-least-once telemetry
        feedback = 1 if inj is None else inj.probe_multiplicity()
        if self.scheduler is not None:
            # shadow probe: `cache` still holds the PRE-step state, so
            # the scheduler can re-run this exact step at the exact
            # config through the same executable and score agreement.
            # dup_probe chaos duplicates the TELEMETRY delivery, never
            # the probe decode: the multiplicity rides into on_step,
            # which runs the compute once and records it `feedback`
            # times
            self.scheduler.on_step(self, active, cache, token,
                                   logits, pool_cfg,
                                   multiplicity=feedback)
        self.rng, k = jax.random.split(self.rng)
        # per-slot temperatures (sampling.sample takes one scalar): rows
        # at temperature t sample categorically from logits/t, rows at
        # 0 take the argmax — the decode loop used to sample EVERY
        # slot at temperature 1.0, ignoring Request.temperature (whose
        # default, 0.0, promises greedy decoding; only the first token
        # from _admit honored it)
        temps = np.asarray([r.temperature if r is not None else 0.0
                            for r in self.slots], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        if np.any(temps[active] > 0.0):
            safe = np.where(temps > 0.0, temps, 1.0).astype(np.float32)
            drawn = np.asarray(sample(
                logits / jnp.asarray(safe)[:, None], k))
            nxt = np.where(temps > 0.0, drawn, greedy)
        else:
            nxt = greedy
        for i in active:
            req = self.slots[i]
            req.tokens.append(int(nxt[i]))
            self.n_tokens_emitted += 1
            self.slot_pos[i] += 1
            if (len(req.tokens) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                req.status = "done"
                req.finished_at = self.clock()
                # repro-lint: disable=bounded-state — completed holds the run()'s return payload, one entry per submitted request; bounding it would silently drop finished results
                self.completed.append(req)
                self.slots[i] = None
                self._nan_strikes[i] = 0
        if (self.snapshot_every and self.checkpointer is not None
                and self.n_decode_steps % self.snapshot_every == 0):
            self.save_snapshot()
        if self.scheduler is not None:
            self.scheduler.on_tick(self)
        return True

    # -- failure handling (PR 7) -----------------------------------------
    def _record_failure(self, active: list[int], now: float,
                        err: Exception) -> None:
        """A decode attempt failed before any state was committed:
        charge a retry to every in-flight request (the pool steps
        together, so attribution to one slot is impossible), evict
        requests past ``max_retries`` as failed, and open a capped
        exponential backoff window with deterministic jitter (seeded
        by the engine seed and the failure ordinal — replayable, yet
        de-synchronized across engines with different seeds)."""
        self.n_retries += 1
        self._retry_streak += 1
        self.last_error = repr(err)
        for i in active:
            req = self.slots[i]
            if req is None:
                continue
            req.retries += 1
            if req.retries > self.max_retries:
                self._evict(i, "failed")
        back = min(self.retry_cap_s,
                   self.retry_base_s * 2.0 ** (self._retry_streak - 1))
        jitter = float(np.random.default_rng(
            (self._jitter_seed, self.n_retries)).uniform(0.0, 0.1 * back))
        self._backoff_until = now + back + jitter

    def _quarantine(self, bad: list[int], pool_cfg: np.ndarray) -> None:
        """Respond to non-finite decode logits: the step is already
        rolled back (cache uncommitted); step the likeliest-offending
        config ONE notch toward exact — through the scheduler's
        quarantine path when one is attached (same one-notch
        hysteresis as probe backoff, so the two responses can't fight),
        else directly on the engine/slot config — and strike the bad
        slots.  A slot out of strikes means the corruption survives
        config changes (poisoned cache state): restore the last
        snapshot when one exists, else evict the slot as failed."""
        self.n_nan_events += 1
        self.n_quarantined += len(bad)
        if self.scheduler is not None and np.any(np.asarray(pool_cfg)):
            self.scheduler.quarantine(pool_cfg)
        elif np.any(self.approx_cfg):
            self.set_approx_cfg(self._step_toward_exact(self.approx_cfg))
        for i in bad:
            if self.slot_pinned[i] and np.any(self.slot_cfg[i]):
                self.slot_cfg[i] = self._step_toward_exact(
                    self.slot_cfg[i])
            self._nan_strikes[i] += 1
        if any(self._nan_strikes[i] > self.nan_max_strikes for i in bad):
            if (self.checkpointer is not None
                    and self._last_snapshot is not None):
                self.restore_snapshot(self._last_snapshot)
                return
            for i in bad:
                if self._nan_strikes[i] > self.nan_max_strikes:
                    self._evict(i, "failed")

    @staticmethod
    def _step_toward_exact(cfg_vec: np.ndarray) -> np.ndarray:
        """One-notch quarantine response without a scheduler: step the
        highest-measured-MRED non-exact cell down one probe config
        (``controller.step_down_config`` — the repo's single backoff
        rule)."""
        vec = np.asarray(cfg_vec).copy()
        flat = vec.reshape(-1)
        nonzero = flat > 0
        if not nonzero.any():
            return vec
        mred = _mred_table()
        worst = int(np.argmax(np.where(nonzero, mred[flat], -np.inf)))
        flat[worst] = step_down_config(int(flat[worst]),
                                       list(range(1, N_CONFIGS)))
        return vec

    def run(self, max_ticks: int = 10000, *, preemption=None):
        """Tick until the queue and slots drain (or ``max_ticks``).

        preemption: an optional ``dist.fault_tolerance
        .PreemptionHandler`` (or anything with a ``preempted`` flag).
        Once it trips, the engine drains gracefully: admission stops
        (queued-but-unadmitted work is left queued), and in-flight
        slots either finish normally or — when a checkpointer is
        attached — are snapshot immediately so a successor engine
        resumes them mid-stream bit-identically."""
        ticks = 0
        while ((bool(self.queue) and not self._draining)
               or any(s is not None for s in self.slots)) \
                and ticks < max_ticks:
            if preemption is not None and preemption.preempted:
                self.drain()
            if self._draining and self.checkpointer is not None:
                self.save_snapshot()
                break
            self.step()
            ticks += 1
        return self.completed

    # -- snapshot / restore (PR 7) ---------------------------------------
    def _snapshot_arrays(self) -> dict:
        """The array half of a snapshot (Checkpointer leaves must be
        arrays): KV cache, config tensors, per-slot numpy state, and
        the sampler key — everything token generation depends on."""
        arrs = {"cache": jax.tree.map(np.asarray, self.cache),
                "approx_cfg": self.approx_cfg,
                "slot_cfg": self.slot_cfg,
                # int32 on disk: positions/strikes fit comfortably, and
                # restore's jnp round-trip would truncate int64 anyway
                "slot_pos": self.slot_pos.astype(np.int32),
                "slot_pinned": self.slot_pinned,
                "nan_strikes": self._nan_strikes.astype(np.int32),
                "rng": np.asarray(self.rng)}
        if self.paged is not None:
            arrs["block_tables"] = self.block_tables
            arrs["seq_lens"] = self.seq_lens
            arrs["refcounts"] = np.array(self.allocator.refcounts)
        return arrs

    _SNAP_COUNTERS = ("n_decode_steps", "n_prefill_tokens",
                      "mac_energy_pj_per_param",
                      "exact_energy_pj_per_param", "n_tokens_charged",
                      "serve_mac_energy_pj_per_param",
                      "n_serve_tokens_charged",
                      "serve_energy_by_class", "serve_tokens_by_class",
                      "n_tokens_emitted",
                      "n_spec_ticks", "n_spec_aborts", "n_draft_tokens",
                      "n_spec_emitted", "n_verify_steps",
                      "n_rejected", "n_expired", "n_failed", "n_retries",
                      "n_nan_events", "n_quarantined")
    # fault counters never roll back: an in-process restore (self-heal)
    # keeps what this engine lived through; only serving ACCOUNTING
    # (steps/tokens/energy) rewinds with the state it describes
    _MONOTONE_COUNTERS = frozenset(
        {"n_rejected", "n_expired", "n_failed", "n_retries",
         "n_nan_events", "n_quarantined"})

    def save_snapshot(self, step: int | None = None) -> int:
        """Persist the full serving state through the attached
        ``checkpoint.Checkpointer`` (atomic dir-rename, bounded
        retention).  Requests (slots, queue, completed) travel in the
        msgpack metadata; arrays in the npz tree.  Returns the step id
        (monotonic snapshot ordinal by default)."""
        assert self.checkpointer is not None, \
            "Engine(checkpointer=...) required for snapshots"
        self.n_snapshots += 1
        step = self.n_snapshots if step is None else int(step)
        meta = {"slots": [_pack_request(r) for r in self.slots],
                "queue": [_pack_request(r) for r in self.queue],
                "completed": [_pack_request(r) for r in self.completed],
                # dict-valued counters (per-class splits) are copied so
                # the snapshot can never alias live accumulators
                "counters": {k: (dict(v) if isinstance(v, dict) else v)
                             for k in self._SNAP_COUNTERS
                             for v in (getattr(self, k),)}}
        if self.paged is not None:
            # allocator refcounts travel as an array; the prefix index
            # and per-slot ownership are msgpack-able structures
            meta["paged"] = {
                "prefix_index": [
                    [list(map(int, key)), int(blk)]
                    for key, blk in sorted(
                        self.allocator._prefix_index.items())],
                "slot_blocks": [[int(b) for b in bl]
                                for bl in self._slot_blocks],
                "prefill_progress": {
                    str(s): {"tokens": [int(t) for t in pr["tokens"]],
                             "next": int(pr["next"]),
                             "resumed": bool(pr["resumed"])}
                    for s, pr in self._prefill_progress.items()},
                "n_preempted": int(self.n_preempted),
                "n_shared_blocks": int(self.n_shared_blocks)}
        self.checkpointer.save(step, self._snapshot_arrays(), meta)
        self._last_snapshot = step
        return step

    def restore_snapshot(self, step: int | None = None) -> None:
        """Load a snapshot (latest by default) into this engine —
        models/executables are untouched, so the restored engine
        decodes through the exact compiled functions it already has;
        the continuation is bit-identical to the uninterrupted run
        (tests/test_resilience.py).  Also the self-healing path for
        persistent cache corruption (see _quarantine)."""
        assert self.checkpointer is not None, \
            "Engine(checkpointer=...) required for snapshots"
        tree, meta = self.checkpointer.restore(self._snapshot_arrays(),
                                               step)
        cache = tree["cache"]
        if self.mapping is not None:
            cache = jax.device_put(cache, self._cache_sh)
        self.cache = cache
        # np.array copies: the restored leaves are jnp (read-only
        # views under np.asarray) and the slot state must stay mutable
        self.approx_cfg = np.array(tree["approx_cfg"], dtype=np.int32)
        self.slot_cfg = np.array(tree["slot_cfg"], dtype=np.int32)
        self.slot_pos = np.array(tree["slot_pos"], dtype=np.int64)
        self.slot_pinned = np.array(tree["slot_pinned"], dtype=bool)
        self._nan_strikes = np.array(tree["nan_strikes"],
                                     dtype=np.int64)
        self.rng = jnp.asarray(np.asarray(tree["rng"]), jnp.uint32)
        if self.paged is not None:
            self.block_tables = np.array(tree["block_tables"], np.int32)
            self.seq_lens = np.array(tree["seq_lens"], np.int32)
            pg = meta["paged"]
            self.allocator.load_state_dict(
                {"refcounts": np.asarray(tree["refcounts"]),
                 "prefix_index": pg["prefix_index"]})
            self._slot_blocks = [[int(b) for b in bl]
                                 for bl in pg["slot_blocks"]]
            self._prefill_progress = {
                int(s): {"tokens": np.asarray(pr["tokens"], np.int32),
                         "next": int(pr["next"]),
                         "resumed": bool(pr["resumed"])}
                for s, pr in pg["prefill_progress"].items()}
            self.n_preempted = max(self.n_preempted,
                                   int(pg["n_preempted"]))
            self.n_shared_blocks = int(pg["n_shared_blocks"])
        self.slots = [_unpack_request(d) for d in meta["slots"]]
        self.queue.clear()
        self.queue.extend(_unpack_request(d) for d in meta["queue"])
        self.completed = [_unpack_request(d) for d in meta["completed"]]
        for k, v in meta["counters"].items():
            if k in self._MONOTONE_COUNTERS:
                v = max(v, getattr(self, k))
            setattr(self, k, v)
        self._retry_streak = 0
        self._backoff_until = 0.0
        self.n_restores += 1

    def resilience_report(self) -> dict:
        """Lifetime fault/SLO counters plus the live backpressure
        signal — the dashboard row BENCH_resilience.json is built
        from."""
        from collections import Counter
        return {"rejected": self.n_rejected, "expired": self.n_expired,
                "failed": self.n_failed, "retries": self.n_retries,
                "nan_events": self.n_nan_events,
                "quarantined": self.n_quarantined,
                "snapshots": self.n_snapshots,
                "restores": self.n_restores,
                "last_error": self.last_error,
                "statuses": dict(Counter(r.status
                                         for r in self.completed)),
                "backpressure": self.backpressure}

    # -- paper-knob reporting --------------------------------------------
    @property
    def macs_per_token(self) -> float:
        """~MACs executed per generated token (one multiply-add per
        active parameter) — the scale factor between the per-MAC energy
        integral and joules/token (shared with the scheduler)."""
        if self._macs_per_token is None:
            n_params = sum(int(np.prod(p.shape))
                           for p in jax.tree.leaves(self.params))
            self._macs_per_token = 2.0 * n_params / 2
        return self._macs_per_token

    def energy_report(self) -> dict:
        """Modeled MAC energy of the work executed so far, integrated at
        the configs each prefill/decode actually ran vs exact mode
        (DESIGN.md §2).  saving_frac is derived from the SAME integral
        (1 - modeled/exact), so it reflects executed work, not the
        engine's current setting; before any work it falls back to the
        current config's modeled saving.

        Modeling caveat with cfg_groups > 1: the integral weights every
        (layer, group) cell equally, i.e. it assumes each neuron group
        covers an equal share of the layer's MACs.  GEMMs narrower than
        cfg_groups kernel blocks conservatively collapse straddled
        groups to their lowest-MRED config (DESIGN.md §3), so the
        reported saving is an upper bound on such layers.  With
        cfg_experts > 1 the expert axis is weighted by the MoE share of
        MACs (equal share per expert); the dense share is charged at the
        expert-collapsed config it actually executes (_energy_pj_mean)."""
        macs_per_token = self.macs_per_token   # ~N MACs/token
        e_cfg = macs_per_token * self.mac_energy_pj_per_param * 1e-12
        e_exact = macs_per_token * self.exact_energy_pj_per_param * 1e-12
        saving = (1.0 - e_cfg / e_exact if e_exact > 0 else
                  float(np.mean(MAC_SAVING_FRAC[self.approx_cfg])))
        return {"approx_cfg": self.approx_cfg.tolist(),
                "modeled_mac_energy_j": e_cfg,
                "exact_mac_energy_j": e_exact,
                "saving_frac": saving,
                "decode_steps": self.n_decode_steps,
                "prefill_tokens": self.n_prefill_tokens}
