"""Serving engine: batched prefill + decode with continuous batching.

A fixed pool of `max_batch` decode slots runs the jitted ``decode_step``
every tick; a request queue feeds empty slots via per-request prefill
(cache rows are spliced into the pool).  This is the standard orca-style
continuous-batching control loop in its jax-native form: python-side
scheduling around two jitted functions with static shapes.

The engine exposes the paper's knob end-to-end: ``approx_cfg`` selects
the MAC error configuration for *all* GEMMs of the model at request
time, and ``energy_report`` integrates the calibrated per-MAC energy
model over the executed steps (DESIGN.md §2: energy is modeled — the
knob's effect on accuracy is real, measured on the generated tokens).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.power_model import MAC_SAVING_FRAC, energy_per_mac_pj
from repro.nn import transformer as T
from .sampling import sample


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    submitted_at: float = field(default_factory=time.time)
    tokens: list = field(default_factory=list)
    done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None


class Engine:
    def __init__(self, params, cfg: T.ModelConfig, *, max_batch: int = 4,
                 max_len: int = 512, approx_cfg: int = 0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.approx_cfg = approx_cfg
        self.rng = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * max_batch
        self.cache, _ = T.init_cache(cfg, max_batch, max_len)
        self.slot_pos = np.zeros(max_batch, dtype=np.int64)
        self.n_decode_steps = 0
        self.n_prefill_tokens = 0
        self.completed: list[Request] = []

        cfg_ = cfg
        acfg = approx_cfg

        @jax.jit
        def _decode(params, cache, token):
            return T.decode_step(params, cfg_, cache, token,
                                 approx_cfg=acfg)

        self._decode = _decode
        self._prefill = jax.jit(
            lambda params, tokens: T.prefill(params, cfg_, tokens,
                                             max_len=max_len,
                                             approx_cfg=acfg))

    # -- request management --------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _splice_cache(self, slot: int, row_cache):
        """Copy a single-row prefill cache into slot `slot` of the pool.
        Mismatched `pos` semantics are kept per-slot in numpy."""
        def splice(pool, row):
            if pool.ndim == 0 or row.ndim == 0:
                return pool
            return pool.at[slot].set(row[0])
        self.cache = jax.tree.map(splice, self.cache, row_cache)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, row_cache = self._prefill(self.params, tokens)
                self.n_prefill_tokens += tokens.shape[1]
                self._splice_cache(slot, row_cache)
                self.slot_pos[slot] = tokens.shape[1]
                self.rng, k = jax.random.split(self.rng)
                first = sample(logits, k, temperature=req.temperature)
                req.tokens.append(int(first[0]))
                req.first_token_at = time.time()
                self.slots[slot] = req

    # -- main loop ------------------------------------------------------
    def step(self):
        """One engine tick: admit requests, one decode step for the pool."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return False
        token = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i in active:
            token[i, 0] = self.slots[i].tokens[-1]
        # pool-level pos: decode_step uses a scalar cache pos; per-slot
        # positions differ after splicing — the pool position is the max,
        # and per-slot validity is handled by each row's own written range
        # (rows beyond a slot's true length hold zeros written at admit).
        pos = int(self.slot_pos[active].max())
        cache = dict(self.cache)
        cache["pos"] = jnp.asarray(pos, jnp.int32)
        logits, self.cache = self._decode(self.params, cache,
                                          jnp.asarray(token))
        self.n_decode_steps += 1
        self.rng, k = jax.random.split(self.rng)
        nxt = np.asarray(sample(logits, k))
        for i in active:
            req = self.slots[i]
            req.tokens.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if (len(req.tokens) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                req.finished_at = time.time()
                self.completed.append(req)
                self.slots[i] = None
        return True

    def run(self, max_ticks: int = 10000):
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed

    # -- paper-knob reporting --------------------------------------------
    def energy_report(self) -> dict:
        """Modeled MAC energy of the work executed so far at this
        engine's approx_cfg vs exact mode (DESIGN.md §2)."""
        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(self.params))
        total_tokens = self.n_prefill_tokens + self.n_decode_steps
        macs = 2.0 * n_params * max(total_tokens, 1) / 2  # ~N MACs/token
        e_cfg = macs * energy_per_mac_pj(self.approx_cfg) * 1e-12
        e_exact = macs * energy_per_mac_pj(0) * 1e-12
        return {"approx_cfg": self.approx_cfg,
                "modeled_mac_energy_j": e_cfg,
                "exact_mac_energy_j": e_exact,
                "saving_frac": float(MAC_SAVING_FRAC[self.approx_cfg]),
                "decode_steps": self.n_decode_steps,
                "prefill_tokens": self.n_prefill_tokens}
