"""Serving engine: batched prefill + decode with continuous batching.

A fixed pool of `max_batch` decode slots runs the jitted ``decode_step``
every tick; a request queue feeds empty slots via per-request prefill
(cache rows are spliced into the pool).  This is the standard orca-style
continuous-batching control loop in its jax-native form: python-side
scheduling around two jitted functions with static shapes.

The engine exposes the paper's knob end-to-end **as a runtime value**:
the per-layer error-config vector is a traced int32 argument of both
jitted functions, so

  * each request may carry its own ``approx_cfg`` (applied to its
    prefill, and folded into the decode pool config);
  * ``set_approx_cfg`` / ``apply_allocation`` retune live slots between
    ticks — a power-budget scheduler can sweep all 32 configs with ZERO
    recompilations (asserted in tests/test_runtime_config.py);
  * ``energy_report`` integrates the calibrated per-MAC energy model
    over the executed steps at the configs they actually ran
    (DESIGN.md §2: energy is modeled — the knob's effect on accuracy is
    real, measured on the generated tokens).

Pool semantics: decode runs one batched step for all slots, so per
layer the pool runs the LOWEST-ERROR config among the active requests'
vectors (ranked by measured MRED — config index is ordered by energy
saving, in which error is non-monotone) — a slot never executes at a
higher-error config than its request asked for.

PR 2: with ``cfg.mac_backend == "pallas"`` every GEMM runs through the
fused approx-MAC kernel; ``cfg_groups > 1`` widens all of the above
from per-layer vectors to per-layer-per-neuron-group (n_layers,
cfg_groups) matrices (DESIGN.md §3).  Weights are pre-quantized into
QTensors ONCE at init (``quantize_weights``), so no decode step
re-quantizes weights inside the traced graph.

PR 3: ``cfg_experts > 1`` (MoE models) adds an EXPERT axis — configs
become (n_layers, cfg_experts, cfg_groups) tensors, each expert of each
MoE layer at its own error config through the grouped expert kernel
(DESIGN.md §4; MoE expert weights now pre-quantize into stacked QTensor
banks too).  Dense GEMMs in those layers collapse the expert axis to
the lowest-measured-MRED config — the pool-join rule — and
``apply_allocation`` accepts (layer, expert) tuple keys so a controller
can target single experts.

PR 4: ``Engine(scheduler=...)`` closes the power loop ONLINE
(DESIGN.md §7): a ``serve.scheduler.PowerBudgetScheduler`` hooks into
every tick — periodic shadow-decode probes re-run the pool's step at
exact config through the SAME decode executable (zero retraces) to
measure token agreement, and every K ticks the pool is retuned toward
a joules/token budget over the full (layer[, expert][, group]) space.
Time is injected (``Engine(clock=...)``) so request ordering and the
scheduler's tick timing are deterministic under test; ``energy_log``
records every charged (kind, tokens, per-MAC-pJ) increment so budget
accounting is auditable step by step.

PR 5: ``Engine(mapping=..., param_specs=...)`` serves one TP/SP-SHARDED
model (DESIGN.md §8): params (incl. the stacked MoE QTensor banks) are
placed by their logical specs (``dist.sharding.Mapping`` over a
``launch.mesh`` mesh, specs transformed by
``transformer.quantize_lm_specs`` to match the quantized layout), the
KV cache is sharded along ``kv_hd``/``kv_seq``, and every config
tensor is REPLICATED across the mesh — the decode step runs under the
activated mapping (GSPMD via ``lsc``/``lsc_tree`` constraints) with
the config as a traced replicated operand, so ``set_approx_cfg`` /
``apply_allocation`` / the scheduler retune the WHOLE mesh with zero
retraces, and — in the heads-TP regime (``serve_mapping(kv="hd")``
with TP dividing the KV-head count) — the sharded decode is
bit-identical to the single-host path (int8 MACs accumulate in int32,
which is exact under any contraction-dim split, and per-head attention
stays whole on one shard; tests/test_sharded_serving.py).

CONFIG-KEY CONVENTION (used by ``apply_allocation``, the scheduler,
and the controller alike): a config-tensor cell is addressed by
``layer`` (int index into the depth axis), then — only when the engine
has the corresponding axis — ``expert`` (index into ``cfg_experts``)
and ``group`` (index into ``cfg_groups``), in that order.
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx_multiplier import N_CONFIGS
from repro.core.power_model import (ENERGY_PER_MAC_PJ, MAC_SAVING_FRAC,
                                    energy_per_token_pj, error_rank)
from repro.dist.sharding import activate as _activate, lsc_tree
from repro.nn import transformer as T
from .sampling import sample

_ENERGY_PJ = ENERGY_PER_MAC_PJ


def _mred_table() -> np.ndarray:
    """Per-config measured MRED — the error ranking for the pool join
    (shared per-process table, see core.error_metrics.mred_table)."""
    from repro.core.error_metrics import mred_table
    return mred_table()


def pool_join(stack) -> np.ndarray:
    """Join k config tensors (stacked on axis 0) elementwise at the
    LOWEST measured MRED, ties broken toward the lower config index —
    the decode-pool rule (DESIGN.md §5): no participant executes at a
    higher error than it asked for.  A commutative, associative,
    idempotent lattice meet over the ``power_model.error_rank`` total
    order — the ONE definition of that order, shared with the
    expert-axis collapse (``ops.collapse_expert_cfg``) and the
    scheduler's energy state (property-tested in
    tests/test_config_algebra.py)."""
    stack = np.asarray(stack)
    idx = np.argmin(error_rank()[stack], axis=0)
    return np.take_along_axis(stack, idx[None, ...], axis=0)[0]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    approx_cfg: Any = None        # None -> engine default; int or
                                  # (n_layers,) per-layer vector
    submitted_at: float | None = None   # stamped by Engine.submit from
                                        # the injected clock (was
                                        # wall-clock at construction —
                                        # untestable ordering)
    tokens: list = field(default_factory=list)
    done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None


class Engine:
    def __init__(self, params, cfg: T.ModelConfig, *, max_batch: int = 4,
                 max_len: int = 512, approx_cfg=0, seed: int = 0,
                 cfg_groups: int = 1, cfg_experts: int = 1,
                 quantize_weights: bool = True, scheduler=None,
                 clock: Callable[[], float] = time.time,
                 mapping=None, param_specs=None):
        """Continuous-batching engine over one compiled prefill + one
        compiled decode executable.

        Knobs (see the module docstring for the config-key convention):

        max_batch (default 4): decode-pool slots — one batched decode
            step serves up to this many in-flight requests per tick.
        max_len (default 512): KV-cache length in tokens (prompt +
            generated), the static shape of every cache buffer.
        approx_cfg (default 0 = exact): engine-wide error config; an
            int broadcasts over the whole config tensor, or pass a
            per-layer / per-(layer, expert[, group]) array.
        seed (default 0): sampling PRNG seed.
        cfg_groups (default 1): neuron groups per layer — widens the
            config tensor's trailing axis so each layer's GEMM output
            columns split into `cfg_groups` contiguous groups, each at
            its own config (requires ``cfg.mac_backend == "pallas"``).
        cfg_experts (default 1): expert axis (MoE models; must equal
            ``cfg.n_experts``) — every expert of every MoE layer at its
            own config through the grouped expert kernel.
        quantize_weights (default True): pre-quantize every GEMM weight
            into QTensors once at init (serving mode).  False keeps
            float params (each call quantizes in-trace — debugging/A-B
            only).
        scheduler (default None): a ``serve.scheduler
            .PowerBudgetScheduler`` to close the power loop online; the
            engine calls its ``on_step``/``on_tick`` hooks every tick.
        clock (default time.time): injected time source, read for
            request ``submitted_at``/TTFT/finish stamps and the
            scheduler's tick timing — pass a fake for deterministic
            tests.  Units: seconds (float).
        mapping (default None = single-host): a ``dist.sharding
            .Mapping`` (e.g. ``dist.sharding.serve_mapping`` over a
            ``launch.mesh.make_serve_mesh`` mesh).  Params and KV cache
            are placed by logical specs, config tensors are replicated,
            and every jitted call runs under the activated mapping.
        param_specs (default None): the logical-spec tree ``init_lm``
            returned for these params; required to shard the params
            when ``mapping`` is given (without it they replicate, the
            cache still shards).
        """
        # quantize every dense GEMM weight ONCE at engine init and carry
        # QTensors through the jitted step functions — no decode step
        # re-quantizes weights inside the traced graph (PR 2; MoE expert
        # weights join as stacked banks in PR 3)
        self.params = (T.quantize_lm_params(params, cfg)
                       if quantize_weights else params)
        self.cfg = cfg
        # -- sharded serving (PR 5, DESIGN.md §8): place params by their
        # logical specs (transformed to the quantized QTensor layout),
        # shard the KV cache, replicate every config tensor.  All jitted
        # calls then run under the activated mapping (_ctx), so the lsc
        # constraints inside the model bake GSPMD shardings into the
        # (still unique) executables.
        self.mapping = mapping
        if mapping is not None:
            specs = param_specs
            if specs is not None and quantize_weights:
                specs = T.quantize_lm_specs(specs, cfg)
            sh = (mapping.shardings(specs, self.params)
                  if specs is not None
                  else jax.tree.map(lambda _: mapping.replicated(),
                                    self.params))
            self.params = jax.device_put(self.params, sh)
        self.max_batch = max_batch
        self.max_len = max_len
        # cfg_groups > 1 widens the knob to per-layer-per-N-block config
        # matrices (n_layers, cfg_groups): each layer's GEMMs split their
        # output columns into cfg_groups contiguous neuron groups, each
        # at its own error config (requires cfg.mac_backend == "pallas").
        # cfg_experts > 1 (MoE models) adds the expert axis in between:
        # (n_layers, cfg_experts, cfg_groups) — each expert of a MoE
        # layer at its own config via the grouped expert kernel; dense
        # GEMMs collapse the expert axis to the lowest-MRED config.
        self.cfg_groups = cfg_groups
        self.cfg_experts = cfg_experts
        if cfg_groups > 1 or cfg_experts > 1:
            assert cfg.mac_backend == "pallas", \
                "per-block/per-expert configs require mac_backend='pallas'"
        if cfg_experts > 1:
            assert cfg_experts == cfg.n_experts, (cfg_experts,
                                                  cfg.n_experts)
        # share of a MoE layer's MACs executed by the expert GEMMs (the
        # remainder — attention/router — runs at the expert-COLLAPSED
        # config): weights the expert axis in the energy integral.
        # Equal-share-per-expert modeling, like the per-group caveat in
        # energy_report.
        if cfg.n_experts > 0:
            d, h, kv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim)
            attn_macs = d * (h + 2 * kv) * hd + h * hd * d
            moe_macs = 3 * d * cfg.d_ff * max(cfg.top_k, 1)
            self._moe_mac_frac = moe_macs / (moe_macs + attn_macs)
        else:
            self._moe_mac_frac = 0.0
        self.approx_cfg = self._as_layer_vector(
            0 if approx_cfg is None else approx_cfg)
        # injected time source: request ordering, TTFT stamps, and the
        # scheduler's tick timing all read it — deterministic in tests
        self.clock = clock
        self.rng = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * max_batch
        self.slot_cfg = np.broadcast_to(
            self.approx_cfg, (max_batch,) + self.approx_cfg.shape).copy()
        # slots whose request carried its OWN approx_cfg are pinned to
        # it; unpinned slots follow the engine config live, so
        # set_approx_cfg retunes in-flight generation at the next tick
        self.slot_pinned = np.zeros(max_batch, dtype=bool)
        self.cache, self.cache_spec = T.init_cache(cfg, max_batch, max_len)
        if mapping is not None:
            # canonical cache placement: kv_seq/kv_hd shard per the
            # mapping, batch over the data axis when divisible.  Kept
            # around (_cache_sh) so host-side cache surgery (_splice_
            # cache) can re-pin — the decode executable's input sharding
            # signature must never drift, or "zero retraces" breaks.
            self._cache_sh = mapping.shardings(self.cache_spec, self.cache)
            self.cache = jax.device_put(self.cache, self._cache_sh)
        self.slot_pos = np.zeros(max_batch, dtype=np.int64)
        self.n_decode_steps = 0
        self.n_prefill_tokens = 0
        self.mac_energy_pj_per_param = 0.0   # sum over tokens of E(cfg)
        self.exact_energy_pj_per_param = 0.0
        self.n_tokens_charged = 0
        # every energy charge, in order: (kind, tokens, per-MAC pJ at
        # the executed config) — the report totals are exactly the sum
        # of these rows while nothing has been evicted
        # (tests/test_energy_accounting.py).  BOUNDED: the totals live
        # in the accumulators above, the log is an audit window, so a
        # long-running engine must not grow it forever.
        self.energy_log: deque[tuple[str, int, float]] = deque(
            maxlen=65536)
        self.completed: list[Request] = []
        self._macs_per_token: float | None = None

        cfg_ = cfg
        cache_spec_ = self.cache_spec

        # approx_cfg is a TRACED (n_layers,) int32 argument: retuning the
        # engine or mixing request configs never retraces (PR 1).  The
        # lsc_tree pins are identities without an active mapping; under
        # one they constrain the cache in AND out to its canonical
        # sharding, so the decode-feeds-its-own-cache loop is a sharding
        # fixed point from the very first call (one executable, ever).
        @jax.jit
        def _decode(params, cache, token, acfg):
            cache = lsc_tree(cache, cache_spec_)
            logits, new_cache = T.decode_step(params, cfg_, cache, token,
                                              approx_cfg=acfg)
            return logits, lsc_tree(new_cache, cache_spec_)

        self._decode = _decode
        self._prefill = jax.jit(
            lambda params, tokens, acfg: T.prefill(params, cfg_, tokens,
                                                   max_len=max_len,
                                                   approx_cfg=acfg))

        # online power-budget scheduler (serve/scheduler.py): hooks into
        # every tick AFTER the jitted functions exist — its shadow
        # probes reuse self._decode, so the whole loop adds zero
        # compiled artifacts (asserted in tests/test_scheduler.py)
        self.scheduler = scheduler
        if scheduler is not None:
            scheduler.attach(self)

    # -- sharded-serving helpers -----------------------------------------
    def _ctx(self):
        """Execution context for one tick: the mapping's mesh + the
        activated logical-axis mapping (so every ``lsc`` inside the
        traced functions resolves), or a no-op without one."""
        if self.mapping is None:
            return contextlib.nullcontext()
        es = contextlib.ExitStack()
        es.enter_context(self.mapping.mesh)
        es.enter_context(_activate(self.mapping))
        return es

    def _replicate(self, x):
        """Device-put a host value as a mesh-REPLICATED committed array
        (identity placement without a mapping).  Config tensors and
        token batches go through here: a replicated committed operand
        keeps the jitted functions' input-sharding signature constant
        across retunes/requests — the zero-retrace invariant — and is
        what lets one ``set_approx_cfg`` retune every shard at once."""
        x = jnp.asarray(x)
        if self.mapping is None:
            return x
        return jax.device_put(x, self.mapping.replicated())

    # -- config management ----------------------------------------------
    def _as_layer_vector(self, approx_cfg) -> np.ndarray:
        """Normalize int / sequence / None to the engine's config shape:
        (n_layers,) when cfg_groups == cfg_experts == 1, (n_layers,
        cfg_groups) with only neuron groups, (n_layers, cfg_experts,
        cfg_groups) with an expert axis.  Scalars broadcast everywhere;
        a per-layer vector broadcasts across experts and groups; a 2-D
        input with cfg_experts > 1 is per-layer-per-EXPERT (broadcast
        across the groups).  One fixed shape keeps every request/retune
        on the same compiled executables (zero retraces)."""
        if approx_cfg is None:
            return self.approx_cfg.copy()
        if self.cfg_experts > 1:
            shape = (self.cfg.n_layers, self.cfg_experts, self.cfg_groups)
        elif self.cfg_groups > 1:
            shape = (self.cfg.n_layers, self.cfg_groups)
        else:
            shape = (self.cfg.n_layers,)
        vec = np.asarray(approx_cfg, dtype=np.int32)
        while 1 <= vec.ndim < len(shape):
            vec = vec[..., None]
        vec = np.broadcast_to(vec, shape).copy()
        assert ((0 <= vec) & (vec < N_CONFIGS)).all(), vec
        return vec

    def set_approx_cfg(self, approx_cfg):
        """Live retune: from the next tick on, every active slot whose
        request did not pin its own config — plus all future
        admissions — runs at this config.  No recompilation (the config
        is a traced argument)."""
        self.approx_cfg = self._as_layer_vector(approx_cfg)

    def apply_allocation(self, assignment: Mapping[Any, int]):
        """Wire a ``DynamicPowerController.allocate`` result in: keys are
        layer indices, integer-suffixed names ('layer_<i>'), or — with
        cfg_experts > 1 — (layer, expert) tuples targeting one expert of
        one MoE layer; values are configs.  Layers/experts missing from
        the assignment stay at their current config.  Free-form
        controller layer names must be mapped to indices by the caller —
        unparseable or out-of-range keys raise."""
        vec = self.approx_cfg.copy()
        for key, c in assignment.items():
            expert = None
            if isinstance(key, tuple):
                if len(key) != 2 or self.cfg_experts <= 1:
                    raise ValueError(
                        f"key {key!r}: (layer, expert) tuples need "
                        f"len == 2 and an engine with cfg_experts > 1")
                key, expert = key
                expert = int(expert)
                if not 0 <= expert < self.cfg_experts:
                    raise ValueError(f"expert index {expert} out of range "
                                     f"[0, {self.cfg_experts})")
            if isinstance(key, str):
                tail = key.rsplit("_", 1)[-1]
                if not tail.isdigit():
                    raise ValueError(
                        f"layer key {key!r}: expected an integer index or "
                        f"an integer-suffixed name like 'layer_3'")
                i = int(tail)
            else:
                i = int(key)
            if not 0 <= i < self.cfg.n_layers:
                raise ValueError(f"layer index {i} (from key {key!r}) out "
                                 f"of range [0, {self.cfg.n_layers})")
            if expert is None:
                vec[i] = int(c)
            else:
                vec[i, expert] = int(c)
        self.set_approx_cfg(vec)

    def _pool_cfg(self) -> np.ndarray:
        """Decode-pool config: per layer, the lowest-MRED config among
        active slots (ties broken toward the lower config index), so no
        request executes at a higher error than it asked for.  Pinned
        slots contribute their request's config; unpinned slots track
        the engine's current config, so live retunes take effect on
        them immediately."""
        active = [self.slot_cfg[i] if self.slot_pinned[i]
                  else self.approx_cfg
                  for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return self.approx_cfg
        return pool_join(np.stack(active))  # (k, n_layers[, cfg_groups])

    # -- request management --------------------------------------------
    def submit(self, req: Request):
        if req.submitted_at is None:
            req.submitted_at = self.clock()
        self.queue.append(req)

    def _splice_cache(self, slot: int, row_cache):
        """Copy a single-row prefill cache into slot `slot` of the pool.
        Mismatched `pos` semantics are kept per-slot in numpy."""
        def splice(pool, row):
            if pool.ndim == 0 or row.ndim == 0:
                return pool
            return pool.at[slot].set(row[0])
        self.cache = jax.tree.map(splice, self.cache, row_cache)
        if self.mapping is not None:
            # re-pin the canonical sharding: the eager splice's output
            # placement is whatever GSPMD propagated, and a drifting
            # cache sharding would re-specialize the decode executable
            self.cache = jax.device_put(self.cache, self._cache_sh)

    def _energy_pj_mean(self, cfg_vec: np.ndarray) -> float:
        """Mean modeled per-MAC energy of one executed token under
        cfg_vec (power_model.energy_per_token_pj at macs_per_token=1).
        Without an expert axis this is the plain mean over (layer,
        group) cells.  With cfg_experts > 1 only the expert GEMMs run
        at their own configs — every dense GEMM of the layer executes
        at the expert-COLLAPSED (lowest-measured-MRED) config
        (layers.dense / ops.collapse_expert_cfg) — so the expert-axis
        mean is weighted by the MoE share of MACs and the dense share is
        charged at the collapsed config."""
        return energy_per_token_pj(cfg_vec,
                                   moe_mac_frac=self._moe_mac_frac)

    def _count_energy(self, tokens: int, cfg_vec: np.ndarray,
                      kind: str = "decode"):
        pj = self._energy_pj_mean(cfg_vec)
        self.mac_energy_pj_per_param += tokens * pj
        self.exact_energy_pj_per_param += tokens * float(_ENERGY_PJ[0])
        self.n_tokens_charged += tokens
        self.energy_log.append((kind, tokens, pj))

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                req_cfg = self._as_layer_vector(req.approx_cfg)
                self.slot_pinned[slot] = req.approx_cfg is not None
                tokens = self._replicate(
                    jnp.asarray(req.prompt, jnp.int32)[None, :])
                logits, row_cache = self._prefill(self.params, tokens,
                                                  self._replicate(req_cfg))
                self.n_prefill_tokens += tokens.shape[1]
                self._count_energy(tokens.shape[1], req_cfg, "prefill")
                self._splice_cache(slot, row_cache)
                self.slot_pos[slot] = tokens.shape[1]
                self.slot_cfg[slot] = req_cfg
                self.rng, k = jax.random.split(self.rng)
                first = sample(logits, k, temperature=req.temperature)
                req.tokens.append(int(first[0]))
                req.first_token_at = self.clock()
                self.slots[slot] = req

    # -- main loop ------------------------------------------------------
    def step(self):
        """One engine tick: admit requests, one decode step for the pool.
        Runs under the sharding mapping's mesh context when one is
        attached (a no-op single-host otherwise)."""
        with self._ctx():
            return self._step()

    def _step(self):
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return False
        token = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i in active:
            token[i, 0] = self.slots[i].tokens[-1]
        # pool-level pos: decode_step uses a scalar cache pos; per-slot
        # positions differ after splicing — the pool position is the max,
        # and per-slot validity is handled by each row's own written range
        # (rows beyond a slot's true length hold zeros written at admit).
        pos = int(self.slot_pos[active].max())
        pool_cfg = self._pool_cfg()
        cache = dict(self.cache)
        cache["pos"] = self._replicate(jnp.asarray(pos, jnp.int32))
        token = self._replicate(token)
        logits, self.cache = self._decode(self.params, cache, token,
                                          self._replicate(pool_cfg))
        self.n_decode_steps += 1
        # one token comes out of every active slot this tick
        self._count_energy(len(active), pool_cfg)
        if self.scheduler is not None:
            # shadow probe: `cache` still holds the PRE-step state, so
            # the scheduler can re-run this exact step at the exact
            # config through the same executable and score agreement
            self.scheduler.on_step(self, active, cache, token, logits,
                                   pool_cfg)
        self.rng, k = jax.random.split(self.rng)
        # per-slot temperatures (sampling.sample takes one scalar): rows
        # at temperature t sample categorically from logits/t, rows at
        # 0 take the argmax — the decode loop used to sample EVERY
        # slot at temperature 1.0, ignoring Request.temperature (whose
        # default, 0.0, promises greedy decoding; only the first token
        # from _admit honored it)
        temps = np.asarray([r.temperature if r is not None else 0.0
                            for r in self.slots], np.float32)
        greedy = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        if np.any(temps[active] > 0.0):
            safe = np.where(temps > 0.0, temps, 1.0).astype(np.float32)
            drawn = np.asarray(sample(
                logits / jnp.asarray(safe)[:, None], k))
            nxt = np.where(temps > 0.0, drawn, greedy)
        else:
            nxt = greedy
        for i in active:
            req = self.slots[i]
            req.tokens.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if (len(req.tokens) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                req.finished_at = self.clock()
                # repro-lint: disable=bounded-state — completed holds the run()'s return payload, one entry per submitted request; bounding it would silently drop finished results
                self.completed.append(req)
                self.slots[i] = None
        if self.scheduler is not None:
            self.scheduler.on_tick(self)
        return True

    def run(self, max_ticks: int = 10000):
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed

    # -- paper-knob reporting --------------------------------------------
    @property
    def macs_per_token(self) -> float:
        """~MACs executed per generated token (one multiply-add per
        active parameter) — the scale factor between the per-MAC energy
        integral and joules/token (shared with the scheduler)."""
        if self._macs_per_token is None:
            n_params = sum(int(np.prod(p.shape))
                           for p in jax.tree.leaves(self.params))
            self._macs_per_token = 2.0 * n_params / 2
        return self._macs_per_token

    def energy_report(self) -> dict:
        """Modeled MAC energy of the work executed so far, integrated at
        the configs each prefill/decode actually ran vs exact mode
        (DESIGN.md §2).  saving_frac is derived from the SAME integral
        (1 - modeled/exact), so it reflects executed work, not the
        engine's current setting; before any work it falls back to the
        current config's modeled saving.

        Modeling caveat with cfg_groups > 1: the integral weights every
        (layer, group) cell equally, i.e. it assumes each neuron group
        covers an equal share of the layer's MACs.  GEMMs narrower than
        cfg_groups kernel blocks conservatively collapse straddled
        groups to their lowest-MRED config (DESIGN.md §3), so the
        reported saving is an upper bound on such layers.  With
        cfg_experts > 1 the expert axis is weighted by the MoE share of
        MACs (equal share per expert); the dense share is charged at the
        expert-collapsed config it actually executes (_energy_pj_mean)."""
        macs_per_token = self.macs_per_token   # ~N MACs/token
        e_cfg = macs_per_token * self.mac_energy_pj_per_param * 1e-12
        e_exact = macs_per_token * self.exact_energy_pj_per_param * 1e-12
        saving = (1.0 - e_cfg / e_exact if e_exact > 0 else
                  float(np.mean(MAC_SAVING_FRAC[self.approx_cfg])))
        return {"approx_cfg": self.approx_cfg.tolist(),
                "modeled_mac_energy_j": e_cfg,
                "exact_mac_energy_j": e_exact,
                "saving_frac": saving,
                "decode_steps": self.n_decode_steps,
                "prefill_tokens": self.n_prefill_tokens}
