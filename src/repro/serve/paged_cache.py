"""Paged KV-cache bookkeeping: block pool config + host-side allocator.

The serving cache (DESIGN.md §11) is a fixed pool of ``num_blocks``
fixed-size blocks per KV leaf; each active request owns a *block table*
(row of physical block ids) instead of a dense cache row.  Everything
here runs on the host — the device only ever sees block tables and
sequence lengths as int32 *data* operands, never as shapes, so one
compiled decode executable serves any mix of stream counts and prompt
lengths (the repo's zero-retrace invariant).

Two block ids are reserved:

* ``ZERO_BLOCK`` (0) is all-zero and never written.  Unallocated table
  entries point at it, so gathers past a request's last block read
  zeros — exactly what the dense pool holds past ``pos``, which is what
  makes paged decode bit-identical to dense at equal occupancy.
* ``TRASH_BLOCK`` (1) absorbs writes from inactive/padded rows (the
  paged kernels route masked-off scatters here).  Its contents are
  garbage by design and never read.

The allocator is a refcounted free list.  Refcounts > 1 arise from
prefix sharing: requests with a common prompt prefix map the same
physical blocks (copy-on-write; see ``ensure_writable``).  State is
plain numpy + dicts so it round-trips through ``checkpoint.Checkpointer``
snapshots (``state_dict`` / ``load_state_dict``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

ZERO_BLOCK = 0
TRASH_BLOCK = 1
N_RESERVED = 2


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static paged-serving geometry (shapes; safe to close over a jit).

    ``num_blocks`` counts *total* pool blocks including the two reserved
    ones; ``usable_blocks`` is what requests can actually hold.
    ``prefill_chunk`` is the number of prompt tokens advanced per engine
    tick and the boundary prompts are padded to (killing the
    per-prompt-length prefill retrace); it must be a multiple of
    ``block_size`` so a chunk never straddles a partially-owned block.
    """
    num_blocks: int
    block_size: int = 16
    prefill_chunk: int = 32
    share_prefixes: bool = True
    attn_backend: str = "xla"          # "xla" | "pallas"

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        if self.num_blocks <= N_RESERVED:
            raise ValueError(
                f"num_blocks must exceed the {N_RESERVED} reserved blocks")
        if self.prefill_chunk % self.block_size:
            raise ValueError(
                f"prefill_chunk ({self.prefill_chunk}) must be a multiple "
                f"of block_size ({self.block_size})")
        if self.attn_backend not in ("xla", "pallas"):
            raise ValueError(f"unknown attn_backend {self.attn_backend!r}")

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - N_RESERVED

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` cache entries."""
        return -(-max(int(n_tokens), 0) // self.block_size)


class PageAllocator:
    """Refcounted block allocator with a prefix-sharing index.

    Invariants (property-tested in tests/test_paged_cache.py):

    * reserved blocks keep refcount 1 forever and are never handed out;
    * every live block-table reference is counted exactly once, so
      ``refcounts[b]`` == number of table slots mapping block ``b``;
    * ``decref`` below zero is a hard error (no double-free);
    * a block whose refcount drops to 0 leaves the prefix index.

    Allocation is deterministic — lowest free id wins — so allocator
    state is fully described by ``refcounts`` + the prefix index, which
    is what ``state_dict`` serialises.
    """

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        self.refcounts = np.zeros(cfg.num_blocks, dtype=np.int32)
        self.refcounts[:N_RESERVED] = 1        # pinned, never allocated
        # prefix index: token-tuple key -> physical block holding that
        # (full) block of prompt K/V; _block_keys is the reverse map so
        # a dying block can purge its keys in O(its keys).
        self._prefix_index: dict[tuple, int] = {}
        self._block_keys: dict[int, list] = {}

    # ------------------------------------------------------------ alloc
    def free_blocks(self) -> int:
        return int(np.sum(self.refcounts[N_RESERVED:] == 0))

    def can_alloc(self, n: int) -> bool:
        return self.free_blocks() >= n

    def alloc(self) -> int:
        """Return the lowest free block id (refcount 0 -> 1)."""
        free = np.flatnonzero(self.refcounts[N_RESERVED:] == 0)
        if free.size == 0:
            raise MemoryError("paged KV pool exhausted")
        blk = int(free[0]) + N_RESERVED
        self.refcounts[blk] = 1
        return blk

    def alloc_n(self, n: int) -> list[int]:
        return [self.alloc() for _ in range(n)]

    def incref(self, blk: int) -> None:
        assert N_RESERVED <= blk < self.cfg.num_blocks, blk
        assert self.refcounts[blk] > 0, f"incref on free block {blk}"
        self.refcounts[blk] += 1

    def decref(self, blk: int) -> None:
        assert N_RESERVED <= blk < self.cfg.num_blocks, blk
        if self.refcounts[blk] <= 0:
            raise AssertionError(f"double free of block {blk}")
        self.refcounts[blk] -= 1
        if self.refcounts[blk] == 0:
            for key in self._block_keys.pop(blk, ()):
                if self._prefix_index.get(key) == blk:
                    del self._prefix_index[key]

    def release(self, blocks: Sequence[int]) -> None:
        """Decref every non-reserved block in a table slice."""
        for blk in blocks:
            if blk >= N_RESERVED:
                self.decref(int(blk))

    # ----------------------------------------------------------- share
    def fork(self, blocks: Sequence[int]) -> list[int]:
        """Share ``blocks`` into a new table (incref each); returns them."""
        out = [int(b) for b in blocks]
        for blk in out:
            self.incref(blk)
        return out

    def ensure_writable(self, blk: int) -> tuple[int, bool]:
        """Copy-on-write: return a block safe to scatter into.

        A block referenced once is returned as-is.  A shared block
        (refcount > 1) gets a fresh copy target: the caller must copy
        the pool contents ``blk -> new`` before writing.  Returns
        ``(block, copied)``.
        """
        assert self.refcounts[blk] > 0, f"ensure_writable on free {blk}"
        if self.refcounts[blk] == 1:
            return blk, False
        new = self.alloc()
        self.decref(blk)
        return new, True

    def lookup_prefix(self, key: tuple) -> int | None:
        if not self.cfg.share_prefixes:
            return None
        return self._prefix_index.get(key)

    def register_prefix(self, key: tuple, blk: int) -> None:
        """Publish a fully-written prompt block for reuse."""
        if not self.cfg.share_prefixes or key in self._prefix_index:
            return
        assert self.refcounts[blk] > 0, blk
        self._prefix_index[key] = blk
        self._block_keys.setdefault(blk, []).append(key)

    def match_prefix(self, prompt: Sequence[int]) -> list[int]:
        """Longest run of already-cached full prompt blocks.

        Sharing is capped one token short of the prompt so the last
        prompt token is always prefilled locally — its logits seed the
        request's first sampled token.  Matched blocks are NOT
        incref'd; callers fork() the returned list into their table.
        """
        if not self.cfg.share_prefixes:
            return []
        bs = self.cfg.block_size
        toks = [int(t) for t in prompt]
        matched: list[int] = []
        for i in range((len(toks) - 1) // bs):
            blk = self._prefix_index.get(tuple(toks[: (i + 1) * bs]))
            if blk is None:
                break
            matched.append(blk)
        return matched

    # -------------------------------------------------------- snapshot
    def state_dict(self) -> dict:
        return {
            "refcounts": np.array(self.refcounts),
            "prefix_index": [[list(k), int(v)]
                             for k, v in sorted(self._prefix_index.items())],
        }

    def load_state_dict(self, state: dict) -> None:
        rc = np.asarray(state["refcounts"], dtype=np.int32)
        assert rc.shape == self.refcounts.shape, (rc.shape,
                                                  self.refcounts.shape)
        self.refcounts = np.array(rc)
        self._prefix_index = {tuple(int(t) for t in k): int(v)
                              for k, v in state.get("prefix_index", [])}
        self._block_keys = {}
        for key, blk in self._prefix_index.items():
            self._block_keys.setdefault(blk, []).append(key)

    def check_consistency(self, slot_blocks) -> None:
        """Assert refcounts == live references (test/debug hook).

        ``slot_blocks`` is the engine's per-slot owned-block lists (the
        authoritative ownership record — it can run one write block
        ahead of ``blocks_for(seq_len)`` after a rolled-back tick);
        every owned reference must be counted exactly once.
        """
        counted = np.zeros_like(self.refcounts)
        counted[:N_RESERVED] = 1
        for blocks in slot_blocks:
            for blk in blocks:
                counted[int(blk)] += int(blk) >= N_RESERVED
        for blk, keys in self._block_keys.items():
            assert self.refcounts[blk] > 0, f"indexed free block {blk}"
            assert keys, blk
        # the prefix index holds no refcount of its own (entries are
        # purged when their block's last table reference dies), so
        # table references and refcounts must agree exactly.
        assert np.array_equal(counted[N_RESERVED:],
                              self.refcounts[N_RESERVED:]), \
            (counted, self.refcounts)
