"""Optimizers implemented from scratch in JAX (no optax dependency).

Functional API mirroring the (init, update) convention:

    opt = adamw(lr=3e-4, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All optimizer states are pytrees with the same tree structure as the
params, so they shard identically under pjit (rule: optimizer state
inherits the param's PartitionSpec).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


@dataclass
class AdamWState:
    step: Any
    mu: Any
    nu: Any

    def tree_flatten(self):
        return (self.step, self.mu, self.nu), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node_class(AdamWState)


def adamw(lr: float | Callable[[jax.Array], jax.Array] = 1e-3,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0,
          grad_clip_norm: float | None = None) -> Optimizer:
    """AdamW with optional global-norm clipping and schedule-as-callable lr.

    Moments are kept in f32 regardless of param dtype (mixed-precision
    safe); decay is decoupled (Loshchilov-Hutter).
    """

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(grads, state: AdamWState, params):
        if grad_clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip_norm)
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, m, v

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.9,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "vel": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr

        def upd(g, v):
            g = g.astype(jnp.float32)
            v = momentum * v + g
            d = g + momentum * v if nesterov else v
            return -lr_t * d, v

        out = jax.tree.map(upd, grads, state["vel"])
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        vel = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"step": step, "vel": vel}

    return Optimizer(init=init, update=update)
