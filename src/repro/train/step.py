"""Train-step builder: loss + grad (with microbatch accumulation) +
optimizer update, as a single jit-able function over a TrainState pytree.

Microbatching (gradient accumulation via lax.scan) bounds activation
memory: each microbatch's remat'ed backward runs before the next starts,
so boundary activations scale with B/num_microbatches.
Gradients accumulate in f32 with the same sharding as the params (FSDP).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.nn.transformer import ModelConfig, lm_loss
from .optimizer import Optimizer, apply_updates, global_norm


def init_state(params, opt: Optimizer):
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def build_train_step(cfg: ModelConfig, opt: Optimizer,
                     num_microbatches: int = 1,
                     loss_fn: Callable | None = None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = loss_fn or (lambda p, mb: lm_loss(p, cfg, mb))

    def split_mb(batch):
        def r(x):
            b = x.shape[0]
            assert b % num_microbatches == 0, (b, num_microbatches)
            return x.reshape((num_microbatches, b // num_microbatches)
                             + x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(state, batch):
        from repro.dist.sharding import lsc
        params = state["params"]
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = split_mb(batch)
            mbs = jax.tree.map(
                lambda x: lsc(x, None, "batch", *([None] * (x.ndim - 2))),
                mbs)

            def mb_body(acc, mb):
                mb = jax.tree.map(
                    lambda x: lsc(x, "batch", *([None] * (x.ndim - 1))), mb)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                mb_body, (zero_g, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
        updates, new_opt = opt.update(grads, state["opt"], params)
        new_params = apply_updates(params, updates)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": global_norm(grads),
                   "step": state["step"] + 1}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step
