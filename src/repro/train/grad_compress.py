"""Int8 gradient compression with error feedback, for the DP all-reduce.

At 1000+ nodes the gradient all-reduce dominates the step at small
per-chip batch.  Compressing the DP all-reduce payload to int8 (4x fewer
bytes than f32) with per-tensor scales and an error-feedback residual
(Seide et al. / 1-bit SGD lineage) keeps convergence while cutting the
collective term.

Implemented with ``jax.shard_map`` so the quantize -> psum -> dequantize
pipeline is explicit in the collective schedule (the int8 psum is the
wire payload).  Validated in tests/test_multidevice.py against the exact
f32 all-reduce: compressed mean + residual == exact mean within the int8
quantization bound, and the residual carries the difference forward.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

try:                                       # jax >= 0.6: top-level shard_map
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma; pick by
# the resolved function's signature, not by import location
import inspect as _inspect
_SHMAP_PARAMS = _inspect.signature(_shard_map).parameters
_SHMAP_KW = ({"check_vma": False} if "check_vma" in _SHMAP_PARAMS
             else {"check_rep": False} if "check_rep" in _SHMAP_PARAMS
             else {})


def _q8(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compressed_psum_mean(grads, residual, mesh, axis: str = "data"):
    """Mean-reduce `grads` over `axis` with int8 payload + error feedback.

    grads/residual: pytrees of f32 arrays sharded arbitrarily over the
    mesh (entering shard_map with replicated spec on `axis`).  Returns
    (mean_grads, new_residual).
    """
    from jax.sharding import PartitionSpec as P

    naxis = mesh.shape[axis]

    def one(g, r):
        def body(gl, rl):
            x = gl + rl                              # error feedback
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
            q = _q8(x, scale)
            # wire payload: int8 values + f32 scale (psum over ints in
            # int32 to avoid overflow at <=128 participants x 127)
            summed = jax.lax.psum(q.astype(jnp.int32), axis)
            scale_sum = jax.lax.psum(scale, axis)    # scales ~equal; use mean
            mean = summed.astype(jnp.float32) * (scale_sum / naxis) / naxis
            new_r = x - q.astype(jnp.float32) * scale
            return mean, new_r

        spec = P(*([None] * g.ndim))
        return _shard_map(body, mesh=mesh,
                          in_specs=(spec, spec), out_specs=(spec, spec),
                          **_SHMAP_KW)(g, r)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    means = jax.tree.unflatten(treedef, [o[0] for o in out])
    resids = jax.tree.unflatten(treedef, [o[1] for o in out])
    return means, resids


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
