"""Serving launcher: continuous-batching engine with the power knob.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      [--requests 8] [--max-batch 4] [--max-new 16] [--approx-cfg 0] \
      [--budget-frac 0.85] [--mesh 2x4] [--kv hd|seq]

Loads a checkpoint when --ckpt is given, otherwise serves random init
(useful for shape/throughput validation).  --smoke selects the reduced
config so the loop runs on CPU.  --budget-frac attaches an online
``PowerBudgetScheduler`` targeting that fraction of the exact-mode
joules/token (DESIGN.md §7) instead of a fixed --approx-cfg.

--mesh DPxTP serves the model SHARDED (DESIGN.md §8): params placed by
their logical specs on a ("data", "model") mesh, KV cache sharded along
heads (--kv hd, bit-identical decode) or sequence (--kv seq, enables
``kv_onehot_write``), config tensors replicated so every retune — CLI,
controller, or scheduler — reaches the whole mesh with zero retraces.
Off-TPU, force host devices first, e.g.:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --smoke --mesh 2x4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.nn import transformer as T
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--approx-cfg", type=int, default=0)
    ap.add_argument("--budget-frac", type=float, default=None,
                    help="attach a PowerBudgetScheduler targeting this "
                         "fraction of exact-mode joules/token")
    ap.add_argument("--mesh", default=None, metavar="DPxTP",
                    help="serve sharded on a (data, model) mesh, e.g. "
                         "2x4 (needs dp*tp visible devices)")
    ap.add_argument("--kv", choices=("hd", "seq"), default="hd",
                    help="sharded KV-cache layout: TP over heads (hd; "
                         "bit-identical when tp divides the KV-head "
                         "count, see DESIGN.md §8) or sequence-parallel "
                         "(seq)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    mapping = None
    if args.mesh:
        from repro.dist.sharding import serve_mapping
        from repro.launch.mesh import make_serve_mesh
        dp, tp = (int(x) for x in args.mesh.lower().split("x"))
        if args.kv == "seq":
            cfg = dataclasses.replace(cfg, kv_onehot_write=True)
        mapping = serve_mapping(make_serve_mesh(dp=dp, tp=tp), kv=args.kv)
        print(f"mesh ({dp}, {tp}) over {dp * tp} devices, kv={args.kv}")

    params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint.checkpointer import Checkpointer
        ck = Checkpointer(args.ckpt)
        state, _ = ck.restore({"params": params})
        params = state["params"]
        print(f"restored checkpoint step {ck.latest_step()}")

    sched = None
    if args.budget_frac is not None:
        from repro.serve.scheduler import PowerBudgetScheduler
        sched = PowerBudgetScheduler(0.0)   # budget set below from the
        #                                     model's exact-mode pJ/token
    eng = Engine(params, cfg, max_batch=args.max_batch,
                 max_len=args.max_len, approx_cfg=args.approx_cfg,
                 scheduler=sched, mapping=mapping, param_specs=specs)
    if sched is not None:
        from repro.core.power_model import energy_per_token_pj
        exact_pj = energy_per_token_pj(
            np.zeros_like(eng.approx_cfg), eng.macs_per_token,
            eng._moe_mac_frac)
        sched.set_budget(args.budget_frac * exact_pj)
        print(f"power-budget scheduler: {args.budget_frac:.2f} x exact = "
              f"{sched.budget_pj_per_token/1e6:.3f} uJ/token")
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(4, 24))),
            max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in done)
    ttfts = [r.first_token_at - r.submitted_at for r in done]
    print(f"{len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s); "
          f"TTFT p50 {np.median(ttfts)*1e3:.0f} ms")
    rep = eng.energy_report()
    print(f"approx_cfg={rep['approx_cfg']} modeled MAC energy "
          f"{rep['modeled_mac_energy_j']*1e3:.2f} mJ "
          f"(exact {rep['exact_mac_energy_j']*1e3:.2f} mJ, "
          f"saving {rep['saving_frac']*100:.2f}%)")
    if sched is not None:
        s = sched.report()
        measured = s["measured_pj_per_token"] or s["modeled_pj_per_token"]
        print(f"scheduler: {s['retunes']} retunes, {s['probes']} probes "
              f"(agree {100*(s['agreement'] or 0):.1f}%, "
              f"{s['backoffs']} backoffs), energy/token "
              f"{measured/1e6:.3f} uJ vs budget "
              f"{s['budget_pj_per_token']/1e6:.3f} uJ")


if __name__ == "__main__":
    main()
