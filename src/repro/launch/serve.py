"""Serving launcher: continuous-batching engine with the power knob.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      [--requests 8] [--max-batch 4] [--max-new 16] [--approx-cfg 0] \
      [--budget-frac 0.85] [--mesh 2x4] [--kv hd|seq]

Loads a checkpoint when --ckpt is given, otherwise serves random init
(useful for shape/throughput validation).  --smoke selects the reduced
config so the loop runs on CPU.  --budget-frac attaches an online
``PowerBudgetScheduler`` targeting that fraction of the exact-mode
joules/token (DESIGN.md §7) instead of a fixed --approx-cfg.

--mesh DPxTP serves the model SHARDED (DESIGN.md §8): params placed by
their logical specs on a ("data", "model") mesh, KV cache sharded along
heads (--kv hd, bit-identical decode) or sequence (--kv seq, enables
``kv_onehot_write``), config tensors replicated so every retune — CLI,
controller, or scheduler — reaches the whole mesh with zero retraces.
Off-TPU, force host devices first, e.g.:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --smoke --mesh 2x4

Resilience (DESIGN.md §10): --traffic RATE drives the engine from a
replayable Poisson generator for --ticks engine ticks (--spike
START:END:MULT adds a burst window), --ttft-slo/--e2e-slo stamp
per-request deadlines, --queue-capacity bounds admission,
--power-cap-frac caps the modeled pool power (fraction of max_batch
exact-config tokens/tick), --brownout LADDER degrades along the config
ladder under pressure, and --chaos SEED replays a seeded fault plan:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --traffic 0.5 --spike 10:40:4.0 --ticks 80 --queue-capacity 8 \
      --power-cap-frac 0.6 --brownout 0,16,31 --chaos 7

Paged serving (DESIGN.md §11): --paged swaps the dense (max_batch,
max_len) KV pool for a block pool with per-request block tables,
chunked prefill, prefix sharing, and preempt-by-recompute — the
concurrency scaler; geometry via --num-blocks/--block-size/
--prefill-chunk (single-host only):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --paged --max-batch 64 --num-blocks 258 --block-size 16 \
      --prefill-chunk 32 --requests 64

Speculative decoding (DESIGN.md §12): --draft-cfg CFG turns on
approx-draft self-speculation — eligible greedy decode ticks draft
--draft-k tokens at the aggressive low-power CFG and verify them in
ONE service-config pass, emitting the verifier's own tokens (stream
identical to plain greedy by construction).  Composes with --paged and
--budget-frac (the scheduler then drives draft depth as a second
control axis):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --draft-cfg 8 --draft-k 3 [--paged]

Per-class power budgets (DESIGN.md §13): --classes turns the --traffic
stream into a weighted class mix, and any class that declares a
BUDGET_SHARE splits the --budget-frac energy budget across classes —
the scheduler tracks per-class attribution and re-splits the shares
from measured usage every retune:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --traffic 0.6 --ticks 60 --budget-frac 0.85 \
      --classes chat:2:0.5,bulk:1:0.5
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.nn import transformer as T
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--approx-cfg", type=int, default=0)
    ap.add_argument("--budget-frac", type=float, default=None,
                    help="attach a PowerBudgetScheduler targeting this "
                         "fraction of exact-mode joules/token")
    ap.add_argument("--mesh", default=None, metavar="DPxTP",
                    help="serve sharded on a (data, model) mesh, e.g. "
                         "2x4 (needs dp*tp visible devices)")
    ap.add_argument("--kv", choices=("hd", "seq"), default="hd",
                    help="sharded KV-cache layout: TP over heads (hd; "
                         "bit-identical when tp divides the KV-head "
                         "count, see DESIGN.md §8) or sequence-parallel "
                         "(seq)")
    ap.add_argument("--queue-capacity", type=int, default=256,
                    help="bounded admission queue; overflow is an "
                         "explicit rejection (DESIGN.md §10)")
    ap.add_argument("--ttft-slo", type=float, default=None,
                    help="per-request time-to-first-token SLO (s)")
    ap.add_argument("--e2e-slo", type=float, default=None,
                    help="per-request end-to-end SLO (s)")
    ap.add_argument("--power-cap-frac", type=float, default=None,
                    help="admission power cap as a fraction of "
                         "max_batch exact-config tokens/tick")
    ap.add_argument("--brownout", default=None, metavar="LADDER",
                    help="comma-separated config ladder for graceful "
                         "degradation under pressure, e.g. 0,16,31")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject a seeded, replayable fault plan "
                         "(NaN logits, step failure, stall)")
    ap.add_argument("--traffic", type=float, default=None, metavar="RATE",
                    help="drive from a replayable Poisson arrival "
                         "stream at RATE requests/tick instead of the "
                         "fixed --requests batch")
    ap.add_argument("--spike", default=None, metavar="START:END:MULT",
                    help="traffic burst window (ticks), e.g. 10:40:4.0")
    ap.add_argument("--ticks", type=int, default=60,
                    help="engine ticks to drive under --traffic")
    ap.add_argument("--classes", default=None, metavar="SPEC",
                    help="mixed-class traffic under --traffic: comma "
                         "list of NAME:WEIGHT[:BUDGET_SHARE], e.g. "
                         "chat:2:0.5,bulk:1:0.5 — budget shares split "
                         "the --budget-frac budget across classes and "
                         "the scheduler re-splits them from measured "
                         "usage (DESIGN.md §13)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: block pool + per-request "
                         "block tables, chunked prefill, prefix "
                         "sharing, preempt-by-recompute (DESIGN.md §11)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged pool size incl. the 2 reserved blocks "
                         "(default: the dense pool's block count)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens advanced per engine tick "
                         "(multiple of --block-size)")
    ap.add_argument("--draft-cfg", type=int, default=None, metavar="CFG",
                    help="speculative decoding: draft at this error "
                         "config, verify at the service config "
                         "(DESIGN.md §12)")
    ap.add_argument("--draft-k", type=int, default=3,
                    help="draft depth per speculative tick (the "
                         "scheduler may lower it live)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    mapping = None
    if args.mesh:
        from repro.dist.sharding import serve_mapping
        from repro.launch.mesh import make_serve_mesh
        dp, tp = (int(x) for x in args.mesh.lower().split("x"))
        if args.kv == "seq":
            cfg = dataclasses.replace(cfg, kv_onehot_write=True)
        mapping = serve_mapping(make_serve_mesh(dp=dp, tp=tp), kv=args.kv)
        print(f"mesh ({dp}, {tp}) over {dp * tp} devices, kv={args.kv}")

    params, specs = T.init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint.checkpointer import Checkpointer
        ck = Checkpointer(args.ckpt)
        state, _ = ck.restore({"params": params})
        params = state["params"]
        print(f"restored checkpoint step {ck.latest_step()}")

    sched = None
    if args.budget_frac is not None:
        from repro.serve.scheduler import PowerBudgetScheduler
        sched = PowerBudgetScheduler(0.0)   # budget set below from the
        #                                     model's exact-mode pJ/token
    brownout = None
    if args.brownout is not None:
        from repro.serve.brownout import BrownoutController
        ladder = tuple(int(x) for x in args.brownout.split(","))
        brownout = BrownoutController(ladder=ladder)
    injector = None
    if args.chaos is not None:
        from repro.serve.faults import FaultEvent, FaultInjector
        r = np.random.default_rng(args.chaos)
        injector = FaultInjector(
            [FaultEvent(tick=int(r.integers(2, 12)), kind="nan_logits"),
             FaultEvent(tick=int(r.integers(4, 16)), kind="step_fail"),
             FaultEvent(tick=int(r.integers(6, 20)), kind="stall",
                        stall_s=0.05)], seed=args.chaos)
        print(f"chaos plan (seed {args.chaos}): "
              f"{[(e.tick, e.kind) for e in injector.plan]}")
    paged = None
    if args.paged:
        from repro.serve.paged_cache import N_RESERVED, PagedCacheConfig
        assert mapping is None, "--paged is single-host (DESIGN.md §11)"
        num_blocks = args.num_blocks
        if num_blocks is None:
            # default: the same token capacity the dense pool would hold
            num_blocks = (args.max_batch * args.max_len
                          // args.block_size + N_RESERVED)
        paged = PagedCacheConfig(num_blocks=num_blocks,
                                 block_size=args.block_size,
                                 prefill_chunk=args.prefill_chunk)
        print(f"paged KV: {num_blocks} blocks x {args.block_size} tokens "
              f"({paged.usable_blocks * args.block_size} usable), "
              f"prefill chunk {args.prefill_chunk}")
    spec = None
    if args.draft_cfg is not None:
        from repro.serve.speculative import SpecConfig
        assert mapping is None, "--draft-cfg is single-host (DESIGN.md §12)"
        spec = SpecConfig(draft_cfg=args.draft_cfg, k=args.draft_k,
                          max_k=max(args.draft_k, 4))
        print(f"speculative decoding: draft cfg {args.draft_cfg}, "
              f"k={args.draft_k} (verify at the service config)")
    eng = Engine(params, cfg, max_batch=args.max_batch,
                 max_len=args.max_len, approx_cfg=args.approx_cfg,
                 scheduler=sched, mapping=mapping, param_specs=specs,
                 queue_capacity=args.queue_capacity, brownout=brownout,
                 fault_injector=injector, paged=paged, spec=spec)
    from repro.core.power_model import energy_per_token_pj
    exact_pj = energy_per_token_pj(
        np.zeros_like(eng.approx_cfg), eng.macs_per_token,
        eng._moe_mac_frac)
    if sched is not None:
        sched.set_budget(args.budget_frac * exact_pj)
        print(f"power-budget scheduler: {args.budget_frac:.2f} x exact = "
              f"{sched.budget_pj_per_token/1e6:.3f} uJ/token")
    if args.power_cap_frac is not None:
        eng.power_cap_pj_per_tick = (args.power_cap_frac
                                     * args.max_batch * exact_pj)
        print(f"admission power cap: {args.power_cap_frac:.2f} x "
              f"{args.max_batch} exact tokens/tick")
    rng = np.random.default_rng(0)
    t0 = time.time()
    offered = None
    if args.traffic is not None:
        from repro.serve.traffic import (TrafficClass, TrafficGenerator,
                                         class_budget_shares, slo_report)
        spikes = ()
        if args.spike:
            a, b, m = args.spike.split(":")
            spikes = ((int(a), int(b), float(m)),)
        if args.classes:
            classes = []
            for item in args.classes.split(","):
                parts = item.split(":")
                classes.append(TrafficClass(
                    parts[0], ttft_slo_s=args.ttft_slo,
                    e2e_slo_s=args.e2e_slo, prompt_len=8,
                    max_new_tokens=args.max_new,
                    weight=float(parts[1]) if len(parts) > 1 else 1.0,
                    budget_share=(float(parts[2]) if len(parts) > 2
                                  else None)))
            classes = tuple(classes)
            shares = class_budget_shares(classes)
            if shares:
                assert sched is not None, \
                    "--classes budget shares need --budget-frac"
                sched.set_class_budgets(shares)
                print(f"per-class budgets: {shares} "
                      f"(re-split from usage each retune)")
        else:
            classes = (TrafficClass("cli", ttft_slo_s=args.ttft_slo,
                                    e2e_slo_s=args.e2e_slo, prompt_len=8,
                                    max_new_tokens=args.max_new),)
        gen = TrafficGenerator(
            classes, rate_per_tick=args.traffic, seed=0,
            vocab_size=cfg.vocab_size, spikes=spikes)
        offered = []
        for t in range(args.ticks):
            for req in gen.arrivals(t):
                offered.append(req)
                eng.submit(req)
            eng.step()
        done = eng.run()           # drain the tail
    else:
        for rid in range(args.requests):
            eng.submit(Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab_size,
                                             size=int(rng.integers(4, 24))),
                max_new_tokens=args.max_new,
                ttft_slo_s=args.ttft_slo, e2e_slo_s=args.e2e_slo))
        done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in done)
    ttfts = [r.first_token_at - r.submitted_at for r in done
             if r.first_token_at is not None]
    ttft_note = (f"TTFT p50 {np.median(ttfts)*1e3:.0f} ms"
                 if ttfts else "no first tokens")
    print(f"{len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s); {ttft_note}")
    rep = eng.energy_report()
    print(f"approx_cfg={rep['approx_cfg']} modeled MAC energy "
          f"{rep['modeled_mac_energy_j']*1e3:.2f} mJ "
          f"(exact {rep['exact_mac_energy_j']*1e3:.2f} mJ, "
          f"saving {rep['saving_frac']*100:.2f}%)")
    if sched is not None:
        s = sched.report()
        measured = s["measured_pj_per_token"] or s["modeled_pj_per_token"]
        print(f"scheduler: {s['retunes']} retunes, {s['probes']} probes "
              f"(agree {100*(s['agreement'] or 0):.1f}%, "
              f"{s['backoffs']} backoffs), energy/token "
              f"{measured/1e6:.3f} uJ vs budget "
              f"{s['budget_pj_per_token']/1e6:.3f} uJ")
        if sched.class_shares:
            for name in sorted(sched.class_shares):
                dn = eng.serve_tokens_by_class.get(name, 0)
                de = eng.serve_energy_by_class.get(name, 0.0)
                pj = de / dn * eng.macs_per_token if dn else 0.0
                print(f"  class {name}: {dn} tokens, "
                      f"{pj/1e6:.3f} uJ/token, final share "
                      f"{sched.class_shares[name]:.3f}")
    rr = eng.resilience_report()
    if any((rr["rejected"], rr["expired"], rr["failed"], rr["retries"],
            rr["nan_events"], injector, brownout)):
        print(f"resilience: rejected {rr['rejected']}, expired "
              f"{rr['expired']}, failed {rr['failed']}, retries "
              f"{rr['retries']}, nan events {rr['nan_events']}, "
              f"quarantined {rr['quarantined']}")
    if spec is not None:
        tv = (eng.n_spec_emitted / eng.n_verify_steps
              if eng.n_verify_steps else 0.0)
        print(f"speculative: {eng.n_spec_ticks} ticks, "
              f"{eng.n_spec_emitted}/{eng.n_draft_tokens} "
              f"emitted/drafted, {tv:.2f} tokens/verify-step, "
              f"{eng.n_spec_aborts} aborts")
    if args.paged:
        bp = eng.backpressure
        print(f"paged: {eng.n_preempted} preemptions, "
              f"{eng.n_shared_blocks} shared prefix blocks, "
              f"{bp['kv_free_blocks']}/{paged.usable_blocks} blocks free")
    if brownout is not None:
        b = brownout.report()
        print(f"brownout: {b['escalations']} escalations, "
              f"{b['recoveries']} recoveries, final level "
              f"{b['level']} (ladder {b['ladder']})")
    if injector is not None:
        print(f"chaos fired: {injector.report()['counts']}")
    if offered is not None:
        tot = slo_report(offered)["total"]
        print(f"traffic: {tot['offered']} offered, availability "
              f"{tot['availability']*100:.1f}%, SLO attainment "
              f"{tot['slo_attainment']*100:.1f}%")


if __name__ == "__main__":
    main()
