"""Input ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Modality frontends are STUBS per the assignment: whisper gets post-conv
frame embeddings (B, S_enc, d); internvl2 gets patch embeddings
(B, 1024, d).  Decoder length for whisper train/prefill cells is
seq_len // 8 (audio tokens compress ~8x vs text).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeCell
from repro.nn.transformer import ModelConfig, init_cache

SDS = jax.ShapeDtypeStruct
WHISPER_ENC_LEN_FOR_DECODE = 1536   # fixed encoder stub for decode cells


def token_inputs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Inputs for train/prefill steps (no shardings attached)."""
    b, s = cell.global_batch, cell.seq_len
    bf16 = jnp.bfloat16
    i32 = jnp.int32
    if cfg.encoder_decoder:
        dec = max(s // 8, 64)
        d = {"enc_embeds": SDS((b, s, cfg.d_model), bf16),
             "tokens": SDS((b, dec), i32)}
        if cell.step == "train":
            d["labels"] = SDS((b, dec), i32)
        return d
    if cfg.vision_prefix_len:
        txt = s - cfg.vision_prefix_len
        assert txt > 0
        d = {"tokens": SDS((b, txt), i32),
             "vision_embeds": SDS((b, cfg.vision_prefix_len, cfg.d_model),
                                  bf16)}
        if cell.step == "train":
            d["labels"] = SDS((b, txt), i32)
        return d
    d = {"tokens": SDS((b, s), i32)}
    if cell.step == "train":
        d["labels"] = SDS((b, s), i32)
    return d


def decode_inputs(cfg: ModelConfig, cell: ShapeCell):
    """(cache_shapes, cache_specs, token_shape) for decode cells."""
    b, s = cell.global_batch, cell.seq_len
    enc_len = WHISPER_ENC_LEN_FOR_DECODE if cfg.encoder_decoder else 0
    captured = {}

    def build():
        cache, spec = init_cache(cfg, b, s, enc_len)
        captured["spec"] = spec
        return cache

    cache_shapes = jax.eval_shape(build)
    return cache_shapes, captured["spec"], SDS((b, 1), jnp.int32)
