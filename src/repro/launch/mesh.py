"""Production mesh construction.

Target: TPU v5e pods — 16x16 = 256 chips per pod; the multi-pod config
adds a leading "pod" axis (2 pods = 512 chips) used as an outer
data-parallel dimension (gradient all-reduce crosses DCN hierarchically).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state — the dry-run must
set XLA_FLAGS before anything initializes the backend.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_serve_mesh(*, dp: int = 1, tp: int | None = None):
    """("data", "model") mesh for the sharded serving engine
    (DESIGN.md §8): `dp` replica groups x `tp` tensor-parallel shards.
    `tp` defaults to every remaining visible device, so
    ``make_serve_mesh()`` is "TP over the whole host/pod"."""
    n = len(jax.devices())
    if tp is None:
        if n % dp:
            raise ValueError(f"dp={dp} does not divide the {n} visible "
                             f"devices; pass tp explicitly to serve on "
                             f"a subset")
        tp = max(n // dp, 1)
    if dp * tp > n:
        raise ValueError(f"mesh ({dp}, {tp}) needs {dp * tp} devices, "
                         f"have {n}")
    return make_mesh((dp, tp), ("data", "model"))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (e.g. (4,2) on 8 forced host devices).

    Handles the jax API drift around explicit axis types: on versions
    that have ``jax.sharding.AxisType`` every axis is created Auto; older
    versions (<= 0.4.x) only know Auto meshes, so the kwarg is omitted.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis
PEAK_BF16_FLOPS = 197e12        # per chip
PEAK_INT8_OPS = 394e12          # per chip (the approx-MAC int8 path)
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~3 links usable / chip)
