import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the
# device count at first backend initialization.

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell
on the production meshes and record memory / cost / collective stats.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Per cell this emits experiments/dryrun/<mesh>/<arch>__<shape>.json with:
  bytes-per-device (arguments/outputs/temps), per-device HLO FLOPs and
  bytes accessed, and the collective schedule (op counts + operand bytes
  by collective kind) parsed from the partitioned HLO — the §Roofline
  inputs.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import SHAPES, all_cells, cells_for, get_config
from repro.dist.sharding import Mapping, activate, train_state_specs
from repro.launch import mesh as mesh_lib
from repro.launch.shapes import decode_inputs, token_inputs
from repro.nn import transformer as T
from repro.train import step as step_lib
from repro.train.optimizer import adamw

# per-arch microbatch counts for train_4k (activation-memory fit)
MICROBATCHES = {
    "gemma2-27b": 8, "qwen2.5-3b": 4, "h2o-danube-3-4b": 4, "gemma-7b": 4,
    "olmoe-1b-7b": 8, "dbrx-132b": 16, "internvl2-76b": 16,
    "whisper-large-v3": 4, "xlstm-350m": 2, "recurrentgemma-2b": 4,
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-operand bytes per collective kind (per-device HLO)."""
    out: dict[str, dict] = {}
    for type_str, op in _COLL_RE.findall(hlo_text):
        d = out.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += _shape_bytes(type_str)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def runtime_config(arch: str, cell_name: str, dp_total: int):
    """Apply production runtime settings to the published config."""
    cfg = get_config(arch)
    over = dict(scan_layers=True, remat=True, q_chunk=1024, loss_chunks=8)
    cell = SHAPES[cell_name]
    if cfg.n_experts:
        over["moe_groups"] = dp_total
        over["moe_ep"] = True            # §Perf iteration 3 (9.4x less coll)
        if cell.step == "prefill":
            over["moe_seq_chunks"] = 8   # bound the dispatch buffer
    if cell.step != "train":
        over["remat"] = False
    return dataclasses.replace(cfg, **over)


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if s.dtype == jnp.float32 else s.dtype), tree)


def probe_config(cfg, k_groups: int, with_rest: bool = False):
    """Depth-reduced variant for HloCostAnalysis probes: XLA counts while
    bodies once, so the production scan under-reports flops/bytes/
    collectives by ~n_groups; two shallow probes (1 and 2 groups, with
    attention chunks python-unrolled) let us extrapolate linearly:
      total = P + G*delta (+ rest), delta = probe2 - probe1."""
    npat = len(cfg.pattern)
    n_layers = k_groups * npat + (cfg.remainder_layers() if with_rest else 0)
    over = dict(n_layers=n_layers, unroll_chunks=True, loss_chunks=1,
                scan_layers=False)
    if cfg.encoder_decoder:
        over["n_enc_layers"] = k_groups
    return dataclasses.replace(cfg, **over)


def lower_cell(arch: str, cell_name: str, mesh, *, serve_dtype=jnp.bfloat16,
               fsdp: bool = True, save_hlo: str | None = None,
               cfg_override=None, stats_only: bool = False,
               nmb_override: int | None = None):
    cell = SHAPES[cell_name]
    multi = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi else ("data",)
    dp_total = int(np.prod([mesh.shape[a] for a in batch_axes]))
    cfg = cfg_override or runtime_config(arch, cell_name, dp_total)
    if cell.global_batch % dp_total != 0 or cell.global_batch < dp_total:
        batch_axes = ()          # long_500k: batch=1 -> replicate batch
    kv_seq_axis = None
    kv_hd_axis = None
    tp_size = int(mesh.shape["model"])
    if cell.step in ("decode", "prefill"):
        if cell.step == "decode" and cell.global_batch < dp_total:
            # long-context SP decode: KV sequence sharded over the DP axes
            kv_seq_axis = ("pod", "data") if multi else ("data",)
            cfg = dataclasses.replace(cfg, kv_onehot_write=True)
        elif cfg.n_kv_heads % tp_size != 0:
            # kv heads can't take the model axis -> shard the cache seq
            # dim on it; single-token writes use the shard-local one-hot
            # blend (plain DUS at a traced index makes GSPMD all-gather
            # the cache every step) — §Perf iteration 1.  [A head-dim
            # sharding variant was tried first and refuted: q stays
            # head-sharded, so the partitioner re-gathers K/V anyway.]
            kv_seq_axis = ("model",)
            if cell.step == "decode":
                cfg = dataclasses.replace(cfg, kv_onehot_write=True)
    mapping = Mapping(mesh, fsdp=fsdp and cell.step == "train",
                      batch_axes=batch_axes or (), kv_seq_axis=kv_seq_axis,
                      kv_hd_axis=kv_hd_axis)

    key = jax.random.key(0)
    captured = {}

    def initf():
        p, s = T.init_lm(key, cfg)
        captured["specs"] = s
        return p

    param_shapes = jax.eval_shape(initf)
    param_specs = captured["specs"]

    if cell.step != "train":
        # weight-gathered serving: when the TP-sharded bf16 weights alone
        # exceed half the HBM, also shard them over "data" (per-layer
        # all-gather at use — §Perf iteration 2)
        pbytes = sum(int(np.prod(s_.shape)) * 2
                     for s_ in jax.tree.leaves(param_shapes))
        if pbytes / int(mesh.shape["model"]) > 8 * 2 ** 30:
            mapping.fsdp = True

    t0 = time.time()
    if cell.step == "train":
        nmb = MICROBATCHES.get(arch, 1) if cell_name == "train_4k" else 1
        if nmb_override is not None:
            nmb = nmb_override
        opt = adamw(lr=1e-4, weight_decay=0.01, grad_clip_norm=1.0)
        state_shapes = jax.eval_shape(
            lambda p: step_lib.init_state(p, opt), param_shapes)
        state_specs = train_state_specs(param_specs)
        state_sh = mapping.shardings(state_specs, state_shapes)
        batch_shapes = token_inputs(cfg, cell)
        batch_sh = mapping.batch_sharding(batch_shapes)
        train_step = step_lib.build_train_step(cfg, opt, num_microbatches=nmb)
        metrics_sh = jax.tree.map(lambda _: mapping.replicated(),
                                  {"loss": 0, "grad_norm": 0, "step": 0})
        fn = jax.jit(train_step,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh),
                     donate_argnums=(0,))
        with mesh, activate(mapping):
            lowered = fn.lower(state_shapes, batch_shapes)
    elif cell.step == "prefill":
        p_shapes = _cast_tree(param_shapes, serve_dtype)
        p_sh = mapping.shardings(param_specs, p_shapes)
        batch_shapes = token_inputs(cfg, cell)
        batch_sh = mapping.batch_sharding(batch_shapes)

        def prefill_fn(params, batch):
            return T.prefill(params, cfg, batch["tokens"],
                             vision_embeds=batch.get("vision_embeds"),
                             enc_embeds=batch.get("enc_embeds"))

        fn = jax.jit(prefill_fn, in_shardings=(p_sh, batch_sh))
        with mesh, activate(mapping):
            lowered = fn.lower(p_shapes, batch_shapes)
    else:  # decode
        p_shapes = _cast_tree(param_shapes, serve_dtype)
        p_sh = mapping.shardings(param_specs, p_shapes)
        cache_shapes, cache_specs, tok = decode_inputs(cfg, cell)
        cache_sh = mapping.shardings(cache_specs, cache_shapes)
        tok_sh = jax.tree.map(
            lambda x: mapping.batch_sharding(x), tok)

        def decode_fn(params, cache, token):
            return T.decode_step(params, cfg, cache, token)

        logits_sh = mapping.replicated()
        fn = jax.jit(decode_fn, in_shardings=(p_sh, cache_sh, tok_sh),
                     out_shardings=(logits_sh, cache_sh),
                     donate_argnums=(1,))
        with mesh, activate(mapping):
            lowered = fn.lower(p_shapes, cache_shapes, tok)

    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax <= 0.4.x wraps it in a list
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    if stats_only:
        return {"flops": cost.get("flops", 0.0),
                "bytes": cost.get("bytes accessed", 0.0),
                "collectives": colls}
    if save_hlo:
        import gzip
        with gzip.open(save_hlo, "wt") as f:
            f.write(hlo)
    n_params = sum(int(np.prod(s.shape))
                   for s in jax.tree.leaves(param_shapes))
    # --- scan-corrected cost via depth-reduced probes -------------------
    n_groups = cfg.n_groups()
    rem = cfg.remainder_layers()
    corrected = None
    try:
        p1 = lower_cell(arch, cell_name, mesh, serve_dtype=serve_dtype,
                        fsdp=fsdp, cfg_override=probe_config(cfg, 1),
                        stats_only=True, nmb_override=1)
        p2 = lower_cell(arch, cell_name, mesh, serve_dtype=serve_dtype,
                        fsdp=fsdp, cfg_override=probe_config(cfg, 2),
                        stats_only=True, nmb_override=1)
        rest_fl = rest_by = 0.0
        rest_coll = {}
        if rem:
            p1r = lower_cell(arch, cell_name, mesh, serve_dtype=serve_dtype,
                             fsdp=fsdp,
                             cfg_override=probe_config(cfg, 1, with_rest=True),
                             stats_only=True, nmb_override=1)
            rest_fl = p1r["flops"] - p1["flops"]
            rest_by = p1r["bytes"] - p1["bytes"]
            rest_coll = {k: {kk: p1r["collectives"].get(k, {}).get(kk, 0)
                             - p1["collectives"].get(k, {}).get(kk, 0)
                             for kk in ("count", "bytes")}
                         for k in set(p1r["collectives"]) | set(p1["collectives"])
                         if k != "total_bytes"}

        def comb(a1, a2, rest=0.0):
            return a1 + (n_groups - 1) * (a2 - a1) + rest

        coll_c = {}
        kinds = (set(p1["collectives"]) | set(p2["collectives"])
                 | set(rest_coll)) - {"total_bytes"}
        for k in kinds:
            c1 = p1["collectives"].get(k, {"count": 0, "bytes": 0})
            c2 = p2["collectives"].get(k, {"count": 0, "bytes": 0})
            r = rest_coll.get(k, {"count": 0, "bytes": 0})
            coll_c[k] = {
                "count": int(comb(c1["count"], c2["count"], r["count"])),
                "bytes": int(comb(c1["bytes"], c2["bytes"], r["bytes"]))}
        coll_c["total_bytes"] = sum(v["bytes"] for v in coll_c.values())
        corrected = {
            "flops_per_device": comb(p1["flops"], p2["flops"], rest_fl),
            "bytes_per_device": comb(p1["bytes"], p2["bytes"], rest_by),
            "collectives": coll_c,
            "probe": {"p1_flops": p1["flops"], "p2_flops": p2["flops"],
                      "n_groups": n_groups, "rest_layers": rem},
        }
    except Exception as e:   # probes are best-effort; record the failure
        corrected = {"error": f"{type(e).__name__}: {e}"}

    result = {
        "arch": arch, "shape": cell_name,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a])
                                           for a in mesh.axis_names])),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "n_params": n_params,
        "step": cell.step,
        "lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "cost": {"flops_per_device": cost.get("flops", 0.0),
                 "bytes_per_device": cost.get("bytes accessed", 0.0)},
        "collectives": colls,
        "corrected": corrected,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    for multi in meshes:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi)
        tag = "pod2x16x16" if multi else "pod16x16"
        outdir = os.path.join(args.out, tag)
        os.makedirs(outdir, exist_ok=True)
        for arch, shape in cells:
            if shape not in cells_for(arch):
                continue
            path = os.path.join(outdir, f"{arch}__{shape}.json")
            hlo_path = (os.path.join(outdir, f"{arch}__{shape}.hlo.gz")
                        if args.save_hlo else None)
            print(f"[dryrun] {tag} {arch} x {shape} ...", flush=True)
            try:
                res = lower_cell(arch, shape, mesh, fsdp=not args.no_fsdp,
                                 save_hlo=hlo_path)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                mm = res["memory"]["peak_estimate_bytes"] / 2**30
                cf = res.get("corrected") or {}
                print(f"  OK lower={res['lower_s']}s compile="
                      f"{res['compile_s']}s mem/dev={mm:.2f}GiB "
                      f"flops/dev={cf.get('flops_per_device', 0):.3g} "
                      f"coll={cf.get('collectives', {}).get('total_bytes', 0):.3g}B",
                      flush=True)
            except Exception as e:
                print(f"  FAIL {type(e).__name__}: {e}", flush=True)
                with open(path + ".err", "w") as f:
                    traceback.print_exc(file=f)


if __name__ == "__main__":
    main()
