"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
      [--smoke] [--steps 100] [--batch 8] [--seq 256] [--approx-cfg 0] \
      [--multi-pod] [--microbatches 1] [--ckpt-dir experiments/ckpt]

On real TPU/TRN fleets this binary runs per host under the cluster
scheduler; jax.distributed initialization is guarded so the same entry
point works single-process (CPU smoke) and multi-host.  --smoke uses the
reduced same-family config so the full loop (data -> sharded step ->
checkpoint -> resume) runs on one CPU device.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config
from repro.data.synthetic_lm import SyntheticLM, SyntheticLMConfig
from repro.dist.fault_tolerance import resilient_train_loop
from repro.dist.sharding import Mapping, activate, train_state_specs
from repro.nn import transformer as T
from repro.train.optimizer import adamw
from repro.train.schedule import warmup_cosine
from repro.train.step import build_train_step, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--approx-cfg", type=int, default=0,
                    help="MAC error config for all GEMMs (paper's knob)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="experiments/ckpt_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}; arch: {cfg.name}; smoke={args.smoke}")

    key = jax.random.PRNGKey(0)
    params, specs = T.init_lm(key, cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n/1e6:.1f}M")

    sched = warmup_cosine(args.lr, min(20, args.steps // 5 + 1), args.steps)
    opt = adamw(lr=sched, weight_decay=0.01, grad_clip_norm=1.0)
    acfg = args.approx_cfg
    loss = lambda p, mb: T.lm_loss(p, cfg, mb, approx_cfg=acfg)
    step_fn = build_train_step(cfg, opt, num_microbatches=args.microbatches,
                               loss_fn=loss)
    state = init_state(params, opt)

    if n_dev > 1:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mapping = Mapping(mesh, fsdp=True,
                          batch_axes=(("pod", "data") if args.multi_pod
                                      else ("data",)))
        state_sh = mapping.shardings(train_state_specs(specs),
                                     jax.eval_shape(lambda: state))
        batch_example = {
            "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
        with mesh, activate(mapping):
            step_fn = jax.jit(step_fn, in_shardings=(
                state_sh, mapping.batch_sharding(batch_example)),
                donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0))
    ck = Checkpointer(args.ckpt_dir, keep_last_k=3)
    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 10 == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f}")

    state, monitor, last = resilient_train_loop(
        train_step=step_fn, state=state,
        data_iter=lambda s: jax.tree.map(jnp.asarray, data.batch(s)),
        checkpointer=ck, total_steps=args.steps,
        checkpoint_every=args.ckpt_every, on_metrics=on_metrics)
    print(f"done at step {last}; loss {np.mean(losses[:5]):.3f} -> "
          f"{np.mean(losses[-5:]):.3f}; "
          f"{len(monitor.flagged)} stragglers flagged; "
          f"latest checkpoint step {ck.latest_step()}")


if __name__ == "__main__":
    main()
