"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf verified].

26 blocks, d_model 2560, 10 heads (MQA kv=1, head_dim 256), d_ff 7680
GeGLU, vocab 256000, pattern: 2x RG-LRU recurrent blocks : 1 local
attention (window 2048), lru width 2560.  26 = 8 groups of 3 + 2
remainder recurrent blocks.
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    pattern=("recurrent", "recurrent", "local"), window=2048,
    mlp="geglu", act="gelu", lru_width=2560,
    embed_scale=True, tie_embeddings=True,
)
