"""xLSTM-350M [arXiv:2405.04517; unverified tier].

24 blocks, d_model 1024, 4 heads, vocab 50304, d_ff=0 (xLSTM blocks
carry their own projections).  Alternating mLSTM (matrix memory,
parallel-form training) and sLSTM (scalar memory, scan) blocks.
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    pattern=("mlstm", "slstm"), mlp="none",
    mlstm_proj_factor=2.0, tie_embeddings=True,
)
