"""The paper's own architecture: 62-30-10 MLP for MNIST (Section III).

Not a ModelConfig — built on repro.nn.mlp_paper with signed-magnitude
8-bit quantization and the 32-config approximate MAC.  This module holds
the canonical hyperparameters used by examples/ and benchmarks/.
"""
N_INPUT = 62
N_HIDDEN = 30
N_OUTPUT = 10
N_PHYSICAL_NEURONS = 10
FSM_STATES = 5
TRAIN_STEPS = 1500
LR = 3e-3
