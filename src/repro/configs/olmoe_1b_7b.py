"""OLMoE-1B-7B [arXiv:2409.02060; hf verified].

16L, d_model 2048, 16 heads (kv=16, head_dim 128), vocab 50304,
MoE: 64 experts, top-8, d_ff 1024 per expert (SwiGLU), no renorm of
top-k probs (OLMoE normalizes post-top-k=False in the release config).
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    pattern=("global",), mlp="swiglu", act="silu",
    n_experts=64, top_k=8, capacity_factor=1.25, renormalize=False,
    moe_groups=16, rope_theta=10000.0,
)
