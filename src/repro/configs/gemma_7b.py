"""Gemma 7B [arXiv:2403.08295; hf verified].

28L, d_model 3072, 16 heads (kv=16, head_dim 256), d_ff 24576 GeGLU,
vocab 256000, embeddings scaled by sqrt(d), tied embeddings.
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    pattern=("global",), mlp="geglu", act="gelu",
    rope_theta=10000.0, embed_scale=True, tie_embeddings=True,
    kv_quant=True,
)
