"""Architecture registry: ``get_config(arch_id)`` plus shape-cell helpers.

Each assigned architecture lives in its own module defining ``CONFIG``
(exact public-literature configuration) — the registry imports them all.
Shape cells (train_4k / prefill_32k / decode_32k / long_500k) are defined
here with the per-arch skip rules below (LONG_CONTEXT_ARCHS).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.nn.transformer import ModelConfig

ARCH_IDS = [
    "gemma2-27b", "qwen2.5-3b", "h2o-danube-3-4b", "gemma-7b",
    "olmoe-1b-7b", "dbrx-132b", "internvl2-76b", "whisper-large-v3",
    "xlstm-350m", "recurrentgemma-2b",
]

_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "qwen2.5-3b": "qwen2_5_3b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "gemma-7b": "gemma_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "dbrx-132b": "dbrx_132b",
    "internvl2-76b": "internvl2_76b",
    "whisper-large-v3": "whisper_large_v3",
    "xlstm-350m": "xlstm_350m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id == "paper-mlp":
        raise ValueError("paper-mlp uses repro.nn.mlp_paper, not ModelConfig")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str           # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic archs (quadratic attention
# at 500k positions would neither fit nor finish)
LONG_CONTEXT_ARCHS = {"xlstm-350m", "recurrentgemma-2b", "h2o-danube-3-4b",
                      "gemma2-27b"}


def cells_for(arch_id: str) -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_id in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return cells


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in cells_for(a)]
