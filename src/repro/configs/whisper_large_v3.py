"""Whisper large-v3 [arXiv:2212.04356; unverified tier].

Encoder-decoder: 32+32L, d_model 1280, 20 heads (MHA kv=20, head_dim 64),
d_ff 5120 GELU, vocab 51866, LayerNorm + learned positions.  The conv
audio frontend is a STUB: input_specs provides precomputed frame
embeddings (B, S_enc, d) — the post-conv sequence.  Decoder length for
train/prefill cells is seq_len // 8 (documented in DESIGN.md).
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    pattern=("global",), mlp="gelu", act="gelu", norm="ln",
    encoder_decoder=True, n_enc_layers=32, max_positions=65536,
    kv_quant=True,
)
