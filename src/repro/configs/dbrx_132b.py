"""DBRX-132B [hf:databricks/dbrx-base; unverified tier].

40L, d_model 6144, 48 heads (GQA kv=8, head_dim 128), vocab 100352,
MoE: 16 experts, top-4, d_ff 10752 per expert (GLU), rope theta 5e5.
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    pattern=("global",), mlp="swiglu", act="silu",
    n_experts=16, top_k=4, capacity_factor=1.25, renormalize=True,
    moe_groups=16, rope_theta=500_000.0, kv_quant=True,
)
