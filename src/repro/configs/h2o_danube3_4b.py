"""H2O-Danube3-4B [arXiv:2401.16818 family; unverified tier].

24L (per assignment), d_model 3840, 32 heads (GQA kv=8, head_dim 120),
d_ff 10240 SwiGLU, vocab 32000, llama+mistral mix with sliding-window
attention (window 4096).  head_dim 120 is not 128-aligned — the Pallas
kernel pads the head dim to 128 (see kernels/flash_attention).
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000,
    pattern=("local",), window=4096, mlp="swiglu", act="silu",
    rope_theta=10000.0,
)
