"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B verified family].

36L, d_model 2048, 16 heads (GQA kv=2, head_dim 128), d_ff 11008 SwiGLU,
vocab 151936, QKV bias, tied embeddings, rope theta 1e6.
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab_size=151936,
    pattern=("global",), mlp="swiglu", act="silu",
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=True,
)
