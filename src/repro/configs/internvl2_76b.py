"""InternVL2-Llama3-76B [arXiv:2404.16821; unverified tier].

Language backbone (Llama-3-70B): 80L, d_model 8192, 64 heads (GQA kv=8,
head_dim 128), d_ff 28672 SwiGLU, vocab 128256.  The InternViT-6B vision
frontend is a STUB per the assignment: input_specs provides 1024
precomputed patch embeddings per image, prepended to the text tokens.
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    pattern=("global",), mlp="swiglu", act="silu",
    rope_theta=500_000.0, vision_prefix_len=1024, kv_quant=True,
)
