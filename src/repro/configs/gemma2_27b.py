"""Gemma-2 27B [arXiv:2408.00118; hf verified].

46L, d_model 4608, 32 heads (GQA kv=16, head_dim 128), d_ff 36864 GeGLU,
vocab 256000.  Alternating local(4096-window)+global attention, attn
logit softcap 50, final softcap 30, RMSNorm pre+post, query scale
(d_model/n_heads)^-0.5, embeddings scaled by sqrt(d).
"""
from repro.nn.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    mlp="geglu", act="gelu", rope_theta=10000.0,
    query_scale=(4608 / 32) ** -0.5,
    post_norm=True, embed_scale=True, tie_embeddings=True,
    moe_groups=1, kv_quant=True,
)
