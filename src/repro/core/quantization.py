"""Signed-magnitude 8-bit quantization (paper Section III-A).

The paper represents inputs, weights and biases as 1 sign bit + 7-bit
magnitude.  Numerically that is symmetric int8 in [-127, 127] (note: -128
is unrepresentable in signed magnitude — we clip to +/-127, which also
keeps the quantizer symmetric).

Two layers of API:

  * array-level:  quantize / dequantize with per-tensor or per-channel
    scales (symmetric, scale = max|x| / 127).
  * ``QTensor``:  a small pytree-compatible container used by the model
    layers and the Pallas kernel wrapper.

``fake_quant`` provides the straight-through estimator used for
quantization-aware fine-tuning.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

QMAX = 127


def expand_left(v, ndim: int):
    """Prepend size-1 axes until ``v`` has rank ``ndim`` — the explicit
    form of numpy's implicit left-padding broadcast.  The tier-1 suite
    runs under ``jax_numpy_rank_promotion='raise'`` (tests/conftest.py),
    so every mixed-rank elementwise op must spell its broadcast out; the
    reshape is metadata-only and the arithmetic (and therefore
    bit-identity) is unchanged.  Scalars and equal-rank inputs pass
    through untouched."""
    v = jnp.asarray(v)
    if v.ndim == 0 or v.ndim >= ndim:
        return v
    return jax.lax.expand_dims(v, tuple(range(ndim - v.ndim)))


@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    """int8 values + float scale; scale broadcasts along `axis`.

    STACKED containers (scan-stacked layer weights, MoE expert banks):
    the scale may carry leading batch axes — values (E, ..., C) with
    scale (E, C) — and the aux `axis` then refers to the UNSTACKED
    per-item layout (the layout consumers see after lax.scan slicing or
    ``take``).  `scale.ndim - 1` leading dims of `values` are treated as
    stacked axes everywhere below."""
    values: Any          # int8 array
    scale: Any           # f32 scalar or per-channel vector
    axis: int | None = None   # channel axis of `scale` (None = per-tensor)

    def _lead_base_axis(self):
        """(n_lead, base_ndim, channel axis within the base layout)."""
        n_lead = jnp.ndim(self.scale) - 1
        base_ndim = self.values.ndim - n_lead
        return n_lead, base_ndim, self.axis % base_ndim

    def dequantize(self):
        scale = self.scale
        if self.axis is not None:
            n_lead, base_ndim, axis = self._lead_base_axis()
            assert scale.shape[-1] == self.values.shape[n_lead + axis] \
                and scale.shape[:n_lead] == self.values.shape[:n_lead], \
                (scale.shape, self.values.shape, self.axis)
            shape = list(scale.shape[:n_lead]) + [1] * base_ndim
            shape[n_lead + axis] = -1
            scale = jnp.reshape(scale, shape)
        return self.values.astype(jnp.float32) * scale

    @property
    def magnitudes(self):
        return jnp.abs(self.values.astype(jnp.int32))

    def reshape(self, *shape):
        """Reshape `values`; valid only while the scale stays broadcastable
        (per-tensor scale, or a reshape that keeps the channel axis as
        the last dim — and, for stacked containers such as an (E, in,
        out) expert bank with (E, out) scales, the leading stacked axes
        too.  E.g. (d, h, hd) -> (d, h*hd) with an axis=-1 scale of size
        h*hd is NOT expressible pre-reshape, so pre-quantized layer
        weights are stored in their 2D GEMM layout instead)."""
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        vals = self.values.reshape(shape)
        if self.axis is None:
            return QTensor(vals, self.scale, None)
        n_lead, _, axis = self._lead_base_axis()
        assert n_lead + axis == self.values.ndim - 1 and \
            vals.shape[-1] == self.values.shape[-1] and \
            vals.shape[:n_lead] == self.values.shape[:n_lead], \
            "reshape must preserve the scale (channel/stacked) axes"
        return QTensor(vals, self.scale, vals.ndim - n_lead - 1)

    def take(self, idx):
        """Index one item out of a stacked container along the leading
        stacked axis (e.g. expert e's (in, out) weights + (out,) scale
        from an (E, in, out) bank).  `idx` may be a Python int or a
        traced int32 scalar; the aux `axis` already refers to the
        unstacked layout, so it carries over unchanged."""
        vals = self.values[idx]
        scale = self.scale
        if self.axis is not None and jnp.ndim(scale) > 1:
            scale = scale[idx]
        return QTensor(vals, scale, self.axis)

    def tree_flatten(self):
        return (self.values, self.scale), (self.axis,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


def compute_scale(x, axis: int | None = None, eps: float = 1e-12):
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes)
    return jnp.maximum(amax, eps) / QMAX


def quantize(x, axis: int | None = None) -> QTensor:
    scale = compute_scale(x, axis)
    if axis is None:
        q = jnp.round(x / scale)
    else:
        shape = [1] * x.ndim
        shape[axis] = -1
        q = jnp.round(x / jnp.reshape(scale, shape))
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32), axis)


def quantize_np(x: np.ndarray, axis: int | None = None):
    """numpy twin used by the oracle / hw simulator (no jax involved)."""
    if axis is None:
        amax = np.abs(x).max()
        scale = max(amax, 1e-12) / QMAX
        q = np.clip(np.round(x / scale), -QMAX, QMAX).astype(np.int8)
        return q, np.float32(scale)
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
    amax = np.abs(x).max(axis=reduce_axes)
    scale = np.maximum(amax, 1e-12) / QMAX
    shape = [1] * x.ndim
    shape[axis] = -1
    q = np.clip(np.round(x / scale.reshape(shape)), -QMAX, QMAX).astype(np.int8)
    return q, scale.astype(np.float32)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x, axis: int | None = None):
    """Quantize+dequantize with a straight-through gradient (QAT)."""
    return quantize(x, axis).dequantize()


def _fq_fwd(x, axis):
    return fake_quant(x, axis), None


def _fq_bwd(axis, _, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def truncate_operand_lsb(q_values, depth, gate, round_to_nearest=True):
    """TPU-native adaptation of the error-config knob (DESIGN.md §2).

    Truncates `depth` low magnitude bits of int8 values whose magnitude is
    >= `gate` (per-operand gating; pair-gating is not expressible as an
    elementwise pre-matmul transform).  Executable before an exact MXU
    matmul.  round_to_nearest halves the expected truncation error.

    `depth`/`gate`/`round_to_nearest` may be Python ints/bools (static —
    the selects below constant-fold under jit) OR traced int32 scalars,
    so the error config can change per call without recompilation.  ONE
    body serves both, so they are bit-identical by construction for
    every config, including depth == 0 (strict identity, even for the
    signed-magnitude-unrepresentable int8 value -128).
    """
    if not any(isinstance(p, jax.Array)
               for p in (depth, gate, round_to_nearest)) and depth <= 0:
        return q_values
    # depth>0 / gate>0 / rtn branches expressed as selects: depth==0
    # reduces to the identity (guarded explicitly — the QMAX clamp must
    # not touch an untruncated magnitude of 128), and gate==0 gates
    # nothing (every magnitude is >= 0).
    depth = jnp.asarray(depth, jnp.int32)
    gate = jnp.asarray(gate, jnp.int32)
    rtn = jnp.asarray(round_to_nearest, jnp.int32)
    v = q_values.astype(jnp.int32)
    mag = jnp.abs(v)
    sign = jnp.sign(v)
    low_mask = jnp.left_shift(1, depth) - 1
    half = jnp.where(depth > 0,
                     jnp.left_shift(1, jnp.maximum(depth - 1, 0)), 0)
    tmag = jnp.where(rtn != 0,
                     jnp.minimum((mag + half) & ~low_mask, QMAX),
                     mag & ~low_mask)
    tmag = jnp.where(depth > 0, tmag, mag)
    new_mag = jnp.where(mag >= gate, tmag, mag)
    return (sign * new_mag).astype(q_values.dtype)
