"""Core contribution: error-configurable approximate MAC + power control."""
from . import (approx_matmul, approx_multiplier, controller, error_metrics,
               power_model, quantization)
