"""Error-configurable approximate multiplier — functional model.

The paper's MAC units embed an approximate multiplier with a 5-bit
error-control input: 32 configurations, config 0 = exact.  The paper
publishes the error envelope (Table I: ER 9.96-61.83%, MRED 0.055-3.684%,
NMED 0.0028-0.364%) but NOT the netlist, so we implement a functional
*family* calibrated so its measured envelope brackets Table I:

  approximate product = mode-dependent truncation of the t low product
  bits, applied only when BOTH operand magnitudes are >= gate
  ("operand-gated" approximation: an OR over each operand's MSBs enables
  the approximate path; small operands take the exact path, which is how
  a hardware multiplier keeps ER bounded while truncating deeply).

  modes:
    0  TRUNC   floor-truncate the t LSBs of the product
    1  ROUND   round-to-nearest at bit t
    2  COMP    truncate + static +2^(t-1) compensation when both operands
               have live low bits (static error-compensation logic)
    3  LOA     lower-part OR (Mahdiani-style): low t result bits = OR of
               the operands' low bits

``CONFIG_TABLE`` holds 31 frozen (mode, t, gate) triples, selected by a
randomized search (see benchmarks/table1_multiplier_metrics.py for the
measured-vs-paper comparison) and ordered by increasing modeled energy
saving, so config index is monotone in power saving and config 31 is the
paper's "lowest accuracy mode".

Operands are the paper's signed-magnitude 8-bit format: 1 sign + 7-bit
magnitude (0..127).  The product magnitude is 14 bits; the sign is the
XOR of operand signs and is never approximated (the paper's MAC handles
sign outside the unsigned multiplier array).

Everything here is exact integer math, vectorized over numpy or
jax.numpy (`xp` dispatch), so the same code serves as the bit-exact
oracle for the Pallas kernel and as the reference for quantized layers.
"""
from __future__ import annotations

import numpy as np

MAG_BITS = 7
MAG_MAX = (1 << MAG_BITS) - 1          # 127
PROD_BITS = 2 * MAG_BITS               # 14
PROD_MAX = MAG_MAX * MAG_MAX           # 16129
N_CONFIGS = 32

MODE_TRUNC, MODE_ROUND, MODE_COMP, MODE_LOA = 0, 1, 2, 3
MODE_NAMES = {MODE_TRUNC: "TRUNC", MODE_ROUND: "ROUND",
              MODE_COMP: "COMP", MODE_LOA: "LOA"}

# (mode, truncation depth t, operand gate) for configs 1..31, ordered by
# increasing energy saving.  Selected to match the paper's Table I
# envelope; measured metrics in the trailing comments (exhaustive over
# the 128x128 magnitude space).
CONFIG_TABLE: tuple[tuple[int, int, int], ...] = (
    (1,  1, 48),  # ER=  9.77%  MRED=0.0015%  NMED=0.0006%
    (2,  2, 56),  # ER=  9.89%  MRED=0.0016%  NMED=0.0007%
    (0,  1, 48),  # ER=  9.77%  MRED=0.0015%  NMED=0.0006%
    (1,  1,  0),  # ER= 25.00%  MRED=0.0581%  NMED=0.0016%
    (0,  1,  0),  # ER= 25.00%  MRED=0.0581%  NMED=0.0016%
    (0,  2,  0),  # ER= 50.00%  MRED=0.2155%  NMED=0.0062%
    (2,  3,  0),  # ER= 57.81%  MRED=0.2702%  NMED=0.0081%
    (3,  9, 48),  # ER= 38.92%  MRED=1.0305%  NMED=0.4075%
    (0,  9, 48),  # ER= 38.90%  MRED=1.5445%  NMED=0.6187%
    (2, 10, 48),  # ER= 38.96%  MRED=1.5459%  NMED=0.6202%
    (3,  7, 32),  # ER= 55.44%  MRED=0.5901%  NMED=0.1837%
    (1, 10, 48),  # ER= 39.00%  MRED=1.5489%  NMED=0.6198%
    (0,  8, 40),  # ER= 46.83%  MRED=1.0827%  NMED=0.3720%
    (0,  7, 32),  # ER= 54.86%  MRED=0.7501%  NMED=0.2159%
    (0,  6, 24),  # ER= 62.45%  MRED=0.5338%  NMED=0.1235%
    (2,  9, 40),  # ER= 47.00%  MRED=1.0880%  NMED=0.3749%
    (3,  9, 40),  # ER= 47.08%  MRED=1.4725%  NMED=0.4964%
    (1,  9, 40),  # ER= 47.09%  MRED=1.0914%  NMED=0.3753%
    (2,  8, 32),  # ER= 55.44%  MRED=0.7746%  NMED=0.2230%
    (0,  9, 40),  # ER= 47.09%  MRED=2.1744%  NMED=0.7489%
    (3,  8, 32),  # ER= 55.86%  MRED=0.8471%  NMED=0.2340%
    (1,  8, 32),  # ER= 55.66%  MRED=0.7766%  NMED=0.2234%
    (2, 10, 40),  # ER= 47.16%  MRED=2.1951%  NMED=0.7523%
    (0,  8, 32),  # ER= 55.66%  MRED=1.5343%  NMED=0.4421%
    (1, 10, 40),  # ER= 47.20%  MRED=2.1637%  NMED=0.7481%
    (2,  9, 32),  # ER= 55.90%  MRED=1.5479%  NMED=0.4461%
    (3,  9, 32),  # ER= 56.03%  MRED=2.1421%  NMED=0.5965%
    (1,  9, 32),  # ER= 56.01%  MRED=1.5546%  NMED=0.4467%
    (0,  9, 32),  # ER= 56.01%  MRED=3.0879%  NMED=0.8910%
    (2, 10, 32),  # ER= 56.10%  MRED=3.0808%  NMED=0.8918%
    (1, 10, 32),  # ER= 56.16%  MRED=3.1240%  NMED=0.8938%
)
assert len(CONFIG_TABLE) == N_CONFIGS - 1


def config_params(config: int) -> tuple[int, int, int]:
    """(mode, depth, gate) for an approximate config in [1, 31]."""
    if not 1 <= config <= 31:
        raise ValueError(f"approximate config must be in [1,31], got {config}")
    return CONFIG_TABLE[config - 1]


def operand_params(config: int) -> tuple[int, int, int, int]:
    """(depth_a, depth_b, gate, rtn) of the operand-truncation adaptation.

    The product truncation depth t splits across the two operands (floor
    on activations, ceil on weights).  ROUND/COMP/LOA modes map to
    round-to-nearest operand truncation — LOA's lower-part OR keeps the
    expected product near exact, which floor truncation would model as
    twice the error (the cfg-8 operand-vs-LUT divergence); only plain
    TRUNC floors.  This is the single definition of the mapping used by
    the XLA path, the Pallas kernel, and OPERAND_PARAM_TABLE below.
    Config 0 -> all zeros (exact).
    """
    if config == 0:
        return 0, 0, 0, 0
    mode, t, gate = config_params(config)
    return t // 2, t - t // 2, gate, int(mode != MODE_TRUNC)


# (32, 4) int32 rows of (depth_a, depth_b, gate, rtn), indexed by config.
# Gathering a row with a *traced* int32 config is what makes the error
# configuration a runtime value: one compiled executable serves all 32
# configs (the paper's dynamic power knob, PR 1).
OPERAND_PARAM_TABLE = np.asarray(
    [operand_params(c) for c in range(N_CONFIGS)], dtype=np.int32)


def _as_xp(a):
    """Pick numpy vs jax.numpy based on input type (oracle runs in numpy)."""
    if isinstance(a, np.ndarray) or np.isscalar(a):
        return np
    import jax.numpy as jnp  # deferred so numpy-only users avoid jax init
    return jnp


def approx_multiply_magnitude(a, b, config: int):
    """Approximate product of two magnitudes (0..127) under `config`.

    a, b: integer arrays (any integer dtype, values in [0, 127]).
    Returns int32 array of approximate products.  Exact for config==0.
    Pure elementwise integer math; works for numpy and jax inputs.
    """
    xp = _as_xp(a)
    a = xp.asarray(a).astype(xp.int32)
    b = xp.asarray(b).astype(xp.int32)
    exact = a * b
    if config == 0:
        return exact
    mode, t, gate = config_params(config)
    low_mask = (1 << t) - 1
    hi = exact & ~low_mask
    if mode == MODE_TRUNC:
        app = hi
    elif mode == MODE_ROUND:
        # max exact product 16129 + 2^(t-1) stays within the int32 range
        # and, for t<=10, within the 14+1-bit hardware product register.
        app = (exact + (1 << (t - 1))) & ~low_mask
    elif mode == MODE_COMP:
        live = ((a & low_mask) != 0) & ((b & low_mask) != 0)
        app = hi + xp.where(live, 1 << (t - 1), 0)
    elif mode == MODE_LOA:
        app = hi | ((a | b) & low_mask)
    else:  # pragma: no cover
        raise AssertionError("unreachable")
    if gate > 0:
        gated = (a >= gate) & (b >= gate)
        app = xp.where(gated, app, exact)
    return app


def approx_multiply_signed(a_sm, b_sm, config: int):
    """Approximate multiply on signed values in [-127, 127].

    Sign = XOR of operand signs (exact); magnitude via the approximate
    multiplier — matching the paper's signed-magnitude MAC datapath.
    """
    xp = _as_xp(a_sm)
    a_sm = xp.asarray(a_sm).astype(xp.int32)
    b_sm = xp.asarray(b_sm).astype(xp.int32)
    sign = xp.sign(a_sm) * xp.sign(b_sm)
    mag = approx_multiply_magnitude(xp.abs(a_sm), xp.abs(b_sm), config)
    return sign * mag


def exhaustive_products(config: int) -> np.ndarray:
    """(128,128) table of approximate products over all magnitude pairs."""
    a = np.arange(128, dtype=np.int32)[:, None]
    b = np.arange(128, dtype=np.int32)[None, :]
    return np.asarray(approx_multiply_magnitude(np.broadcast_to(a, (128, 128)),
                                                np.broadcast_to(b, (128, 128)),
                                                config))


EXACT_TABLE = (np.arange(128, dtype=np.int64)[:, None]
               * np.arange(128, dtype=np.int64)[None, :])
