"""Power/energy model of the error-configurable MAC, calibrated to the paper.

The paper's measured endpoints (45 nm, 100 MHz, 1.1 V):

  network power, exact mode (cfg 0) : 5.55 mW
  network power, cfg 31             : 4.81 mW   (-13.33 %)
  per-MAC max saving                : 44.36 %
  per-neuron max saving             : 24.78 %
  10 physical neurons

From these the power split is implied (and hard-wired below):
  MAC saving 44.36 % == neuron saving 24.78 %  =>  MAC / neuron = 0.5587
  neuron saving 24.78 % == network saving 13.33 %  =>  neurons / network = 0.5379
  =>  network 5.55 mW = neurons 2.9855 mW (10 x 298.55 uW)
                       + other (controller, muxes, registers, memory IF) 2.5645 mW
      neuron 298.55 uW = MAC 166.79 uW + activation/bias/saturation 131.76 uW

Per-config MAC energy: switching energy of the multiplier array scales
with the *active partial-product columns*; the operand gate disables the
approximate path for small operands, so the expected saving scales with
the gate probability under the uniform exhaustive input model (the same
model the paper's Table I uses):

  saving_frac(cfg) = P(both |operands| >= gate) * (t_eff / PROD_BITS) - mode_overhead

normalized so cfg 31 hits exactly the paper's 44.36 % MAC saving.  The
CONFIG_TABLE in approx_multiplier.py is *ordered* by this quantity, so
power saving is monotone in config index (verified by a unit test).

For the TPU-scale architectures we reuse the same per-MAC energy curve as
a *relative* knob: `energy_per_mac_pj(cfg)` is reported per arch x shape
in the benchmark harness (a TPU cannot realize per-MAC power, see
DESIGN.md §2 — these numbers model the paper's ASIC executing the same
GEMMs, i.e. the technique's headroom, not TPU wall power).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .approx_multiplier import CONFIG_TABLE, N_CONFIGS, PROD_BITS

# --- paper-calibrated constants (mW unless noted) -------------------------
NETWORK_POWER_EXACT_MW = 5.55
NETWORK_POWER_MIN_MW = 4.81
MAX_NETWORK_SAVING = 0.1333
MAX_NEURON_SAVING = 0.2478
MAX_MAC_SAVING = 0.4436
N_PHYSICAL_NEURONS = 10

NEURON_SHARE_OF_NETWORK = MAX_NETWORK_SAVING / MAX_NEURON_SAVING   # 0.5379
MAC_SHARE_OF_NEURON = MAX_NEURON_SAVING / MAX_MAC_SAVING           # 0.5587

NEURONS_POWER_MW = NETWORK_POWER_EXACT_MW * NEURON_SHARE_OF_NETWORK
NEURON_POWER_MW = NEURONS_POWER_MW / N_PHYSICAL_NEURONS
MAC_POWER_EXACT_MW = NEURON_POWER_MW * MAC_SHARE_OF_NEURON
NEURON_OTHER_MW = NEURON_POWER_MW - MAC_POWER_EXACT_MW
NETWORK_OTHER_MW = NETWORK_POWER_EXACT_MW - NEURONS_POWER_MW

# energy of one exact 8x8 signed-magnitude MAC, derived from the
# calibration: each physical neuron's MAC retires 1 op/cycle at 100 MHz,
# so E = P_mac / f = 166.8 uW / 100 MHz = 1.668 pJ (45 nm, 1.1 V) — the
# unit for the TPU-arch energy *reports* (relative knob, see docstring).
PAPER_CLOCK_HZ = 100e6
MAC_ENERGY_EXACT_PJ = MAC_POWER_EXACT_MW * 1e-3 / PAPER_CLOCK_HZ * 1e12

_MODE_OVERHEAD = {0: 0.000, 1: 0.010, 2: 0.020, 3: 0.015}


def _raw_saving(mode: int, t: int, gate: int) -> float:
    p_gate = ((128 - gate) / 128.0) ** 2 if gate > 0 else 1.0
    cols = min(t, 13) / PROD_BITS
    return p_gate * cols - _MODE_OVERHEAD[mode]


# normalize so config 31 (last table entry == max raw saving) hits 44.36%
_RAW = np.array([0.0] + [_raw_saving(m, t, g) for (m, t, g) in CONFIG_TABLE])
_SCALE = MAX_MAC_SAVING / _RAW.max()
MAC_SAVING_FRAC = _RAW * _SCALE          # per-config fraction of MAC power saved


def mac_saving(config: int) -> float:
    """Fraction of MAC power saved at `config` (0 for exact mode)."""
    return float(MAC_SAVING_FRAC[config])


def mac_power_mw(config: int) -> float:
    return MAC_POWER_EXACT_MW * (1.0 - mac_saving(config))


def neuron_power_mw(config: int) -> float:
    return NEURON_OTHER_MW + mac_power_mw(config)


def network_power_mw(config: int) -> float:
    """Total network power with all 10 neurons at `config` (paper Fig 6)."""
    return NETWORK_OTHER_MW + N_PHYSICAL_NEURONS * neuron_power_mw(config)


def network_improvement_pct(config: int) -> float:
    """Paper Fig 5: % improvement vs exact mode."""
    return 100.0 * (1.0 - network_power_mw(config) / NETWORK_POWER_EXACT_MW)


def energy_per_mac_pj(config: int) -> float:
    return MAC_ENERGY_EXACT_PJ * (1.0 - mac_saving(config))


# per-config modeled MAC energy as a (32,) table — the vectorized twin of
# energy_per_mac_pj, shared by the engine integral and the scheduler
ENERGY_PER_MAC_PJ = MAC_ENERGY_EXACT_PJ * (1.0 - MAC_SAVING_FRAC)

_ERROR_RANK: list[np.ndarray] = []


def error_rank() -> np.ndarray:
    """Total error order over the 32 configs: position when sorting by
    (measured MRED, config index) — THE tie-break-free ranking behind
    every conservative config join (engine pool join, expert collapse,
    scheduler energy state); keeping one definition keeps them from
    diverging.  Lazy import: error_metrics measures the multiplier
    tables on first use, and only the join/collapse paths need it."""
    if not _ERROR_RANK:
        from .error_metrics import mred_table
        mred = np.asarray(mred_table())
        order = np.lexsort((np.arange(mred.size), mred))
        rank = np.empty_like(order)
        rank[order] = np.arange(order.size)
        _ERROR_RANK.append(rank)
    return _ERROR_RANK[0]


def energy_per_token_pj(config, macs_per_token: float = 1.0,
                        moe_mac_frac: float = 0.0) -> float:
    """Modeled MAC energy (pJ) of ONE generated token under `config`.

    `config` is anything the engine accepts: a scalar, an (n_layers,)
    vector, an (n_layers, groups) matrix, or an (n_layers, experts,
    groups) tensor.  Cells are weighted equally (each covers an equal
    share of the token's MACs), matching the engine's energy integral.

    With an expert axis (ndim == 3) only the MoE expert GEMMs — a
    `moe_mac_frac` share of the layer's MACs — run at their own
    per-expert configs; every dense GEMM executes at the expert-
    COLLAPSED (lowest-measured-MRED per (layer, group)) config
    (ops.collapse_expert_cfg), so the dense share is charged at the
    configs actually executed.  This is the joules/token view both the
    offline controller and the online `PowerBudgetScheduler` consume.
    """
    cfg = np.asarray(config, dtype=np.int64)
    per_mac = float(np.mean(ENERGY_PER_MAC_PJ[cfg]))
    if cfg.ndim >= 3 and moe_mac_frac < 1.0:
        idx = np.argmin(error_rank()[cfg], axis=-2)
        collapsed = np.take_along_axis(
            cfg, np.expand_dims(idx, -2), axis=-2)[..., 0, :]
        per_mac = (moe_mac_frac * per_mac
                   + (1.0 - moe_mac_frac)
                   * float(np.mean(ENERGY_PER_MAC_PJ[collapsed])))
    return macs_per_token * per_mac


@dataclass(frozen=True)
class PowerReport:
    config: int
    mac_mw: float
    neuron_mw: float
    network_mw: float
    improvement_pct: float


def full_report() -> list[PowerReport]:
    return [PowerReport(c, mac_power_mw(c), neuron_power_mw(c),
                        network_power_mw(c), network_improvement_pct(c))
            for c in range(N_CONFIGS)]


def model_energy_mj(n_macs: float, config: int) -> float:
    """Modeled energy (millijoules) for `n_macs` MACs at `config` —
    used by the LM-arch energy reports (6*N*D-scale MAC counts)."""
    return n_macs * energy_per_mac_pj(config) * 1e-9
