"""Approximate matrix multiplication built on the error-configurable multiplier.

Three semantics, from bit-faithful to TPU-fast (see DESIGN.md §2):

  1. ``approx_matmul_lut``  — product-level approximation via the
     exhaustive 128x128 LUT of the hardware multiplier.  Bit-exact w.r.t.
     the ASIC model; materializes the (..., M, K, N) product tensor, so
     it is for oracle/small-model use (the paper's 62-30-10 MLP).
  2. ``approx_matmul_operand`` — the TPU-native adaptation: operand-LSB
     truncation (+gate) *before* an exact integer matmul.  MXU-friendly
     (mask -> dot), jit/pjit-shardable, and exactly the semantics the
     Pallas kernel in ``kernels/approx_mac`` implements.
  3. ``quantized_matmul`` — config 0 path (exact int8 x int8 -> int32),
     shared by both.

All integer matmuls accumulate in int32 (the hardware accumulates 62
14-bit products into 21 bits; int32 strictly contains that range — a
property test asserts no overflow against the 21-bit model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .approx_multiplier import (CONFIG_TABLE, N_CONFIGS,
                                OPERAND_PARAM_TABLE, exhaustive_products,
                                operand_params)
from .quantization import QTensor, expand_left, truncate_operand_lsb

# ---------------------------------------------------------------------------
# device-resident constant tables
# ---------------------------------------------------------------------------
# jnp.asarray(<module numpy constant>) inside a traced function re-embeds
# the table as a fresh HLO constant on every trace (and re-uploads it per
# compile).  These lazy singletons upload each table to the default
# device ONCE per process; every gather then references the same buffer.

_OPERAND_TABLE_DEV: list = []
_LUT_CACHE: dict[int, np.ndarray] = {}
_LUT_STACK: list[np.ndarray] = []      # lazily built (32, 128, 128) stack
_LUT_STACK_DEV: list = []


def device_constant(cache: list, build):
    """Lazy once-per-process device constant (cache is a module-level
    list).  ensure_compile_time_eval guards the first call happening
    inside a jit trace: the cached value must be a concrete device
    array, never a tracer."""
    if not cache:
        with jax.ensure_compile_time_eval():
            cache.append(jnp.asarray(build()))
    return cache[0]


def operand_param_table():
    """(32, 4) int32 OPERAND_PARAM_TABLE as a device constant."""
    return device_constant(_OPERAND_TABLE_DEV, lambda: OPERAND_PARAM_TABLE)


# ---------------------------------------------------------------------------
# LUT path (bit-faithful oracle)
# ---------------------------------------------------------------------------


def _lut(config: int) -> np.ndarray:
    if config not in _LUT_CACHE:
        _LUT_CACHE[config] = exhaustive_products(config).astype(np.int32)
    return _LUT_CACHE[config]


def _lut_stack() -> np.ndarray:
    """All 32 multiplier tables stacked — 2 MiB, gathered by a traced
    config index so the bit-exact oracle is runtime-switchable too."""
    if not _LUT_STACK:
        _LUT_STACK.append(np.stack([_lut(c) for c in range(N_CONFIGS)]))
    return _LUT_STACK[0]


def _lut_stack_dev():
    return device_constant(_LUT_STACK_DEV, _lut_stack)


def approx_matmul_lut(a_q, b_q, config):
    """Bit-exact approximate matmul on int8 values.

    a_q: (..., M, K) int8, b_q: (K, N) int8 -> (..., M, N) int32.
    Each scalar product is looked up in the hardware multiplier table;
    signs handled by XOR (sign product), matching the paper MAC.
    `config` may be a traced int32 scalar (table row gathered at
    runtime) or a Python int (single table baked into the trace).
    """
    if isinstance(config, jax.Array):
        lut = _lut_stack_dev()[jnp.asarray(config, jnp.int32)]
    else:
        lut = jnp.asarray(_lut(config))
    a = a_q.astype(jnp.int32)
    b = b_q.astype(jnp.int32)
    a_mag, a_sign = jnp.abs(a), jnp.sign(a)
    b_mag, b_sign = jnp.abs(b), jnp.sign(b)
    # (..., M, K, 1) x (K, N) -> (..., M, K, N)
    prod_mag = lut[a_mag[..., :, :, None], b_mag[None, :, :]]
    sign = a_sign[..., :, :, None] * b_sign[None, :, :]
    return jnp.sum(prod_mag * sign, axis=-2)


def approx_matmul_lut_np(a_q: np.ndarray, b_q: np.ndarray, config: int) -> np.ndarray:
    """numpy twin (used by the cycle-level hardware simulator)."""
    lut = _lut(config)
    a = a_q.astype(np.int64)
    b = b_q.astype(np.int64)
    prod = lut[np.abs(a)[..., :, :, None], np.abs(b)[None, :, :]].astype(np.int64)
    sign = np.sign(a)[..., :, :, None] * np.sign(b)[None, :, :]
    return (prod * sign).sum(axis=-2)


# ---------------------------------------------------------------------------
# Operand-truncation path (TPU-native)
# ---------------------------------------------------------------------------

def gather_operand_params(config):
    """(depth_a, depth_b, gate, rtn) int32 scalars for a TRACED config.

    One gather from the frozen (32, 4) OPERAND_PARAM_TABLE — the runtime
    replacement for the Python branch on a static config, so switching
    configs between calls retraces nothing.
    """
    row = operand_param_table()[jnp.asarray(config, jnp.int32)]
    return row[..., 0], row[..., 1], row[..., 2], row[..., 3]


def resolve_operand_params(config):
    """(depth_a, depth_b, gate, rtn) for a Python-int OR traced config —
    the single static/traced dispatch shared by every operand-truncation
    call site (dense matmul, MoE expert einsums, the Pallas wrapper)."""
    if isinstance(config, jax.Array):
        return gather_operand_params(config)
    return operand_params(int(config))


def approx_matmul_operand(a_q, b_q, config,
                          preferred_element_type=jnp.int32):
    """Operand-LSB-truncated exact matmul — the MXU-executable adaptation.

    a_q: (..., M, K) int8, b_q: (K, N) int8 -> int32.  The (mode, depth,
    gate) of `config` maps to per-operand truncation: ROUND/COMP modes use
    round-to-nearest, TRUNC/LOA floor.  depth is split across the two
    operands (ceil on weights, floor on activations) so the product-level
    error magnitude tracks the product-truncation model.

    `config` is a Python int (static specialization, the original path)
    or a traced int32 scalar: the per-config parameters are then gathered
    from OPERAND_PARAM_TABLE inside the trace, so one compiled executable
    serves all 32 configs.  Both paths are bit-identical per config.
    """
    if isinstance(config, jax.Array) or config != 0:
        depth_a, depth_b, gate, rtn = resolve_operand_params(config)
        a_q = truncate_operand_lsb(a_q, depth_a, gate, rtn)
        b_q = truncate_operand_lsb(b_q, depth_b, gate, rtn)
    return jax.lax.dot_general(
        a_q, b_q,
        dimension_numbers=(((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred_element_type)


def quantized_matmul(a_q, b_q, preferred_element_type=jnp.int32):
    """Exact int8 matmul with int32 accumulation (config 0)."""
    return approx_matmul_operand(a_q, b_q, 0, preferred_element_type)


# ---------------------------------------------------------------------------
# per-column-block (mixed-config) references
# ---------------------------------------------------------------------------
# The hardware's knob is per MAC unit, i.e. per *neuron* — one GEMM may
# run different output columns at different error configs.  These are the
# N-column-blocked reference semantics the Pallas kernel implements with
# its per-tile scalar-prefetch config vector: output columns
# [i*block_n, (i+1)*block_n) are computed entirely under cfg_vec[i]
# (both operands truncated with that block's parameters).


def _split_col_blocks(n: int, block_n: int) -> list[tuple[int, int]]:
    assert block_n > 0
    return [(s, min(s + block_n, n)) for s in range(0, n, block_n)]


def approx_matmul_operand_blocked(a_q, b_q, cfg_vec, block_n: int,
                                  preferred_element_type=jnp.int32):
    """Mixed-config operand-truncation matmul (reference implementation).

    cfg_vec: (ceil(N/block_n),) config indices — Python ints or a traced
    int32 vector.  Block i's columns run under cfg_vec[i].  The Pallas
    kernel computes this in ONE pallas_call; here each block is a
    separate `approx_matmul_operand` call, concatenated — the oracle the
    kernel is tested against.
    """
    n = b_q.shape[-1]
    blocks = _split_col_blocks(n, block_n)
    assert len(blocks) == (len(cfg_vec) if not isinstance(cfg_vec, jax.Array)
                           else cfg_vec.shape[0]), (n, block_n)
    outs = [approx_matmul_operand(a_q, b_q[..., s:e], cfg_vec[i],
                                  preferred_element_type)
            for i, (s, e) in enumerate(blocks)]
    return jnp.concatenate(outs, axis=-1)


def approx_matmul_lut_blocked(a_q, b_q, cfg_vec, block_n: int):
    """Mixed-config bit-exact LUT matmul: the ASIC-model oracle for a
    per-neuron-block configured GEMM (cfg_vec as in the operand twin)."""
    n = b_q.shape[-1]
    blocks = _split_col_blocks(n, block_n)
    outs = [approx_matmul_lut(a_q, b_q[..., s:e], cfg_vec[i])
            for i, (s, e) in enumerate(blocks)]
    return jnp.concatenate(outs, axis=-1)


# ---------------------------------------------------------------------------
# Float-facing layer op
# ---------------------------------------------------------------------------

def approx_dense(x, w_qt: QTensor, config: int, *, method: str = "operand"):
    """y = approx(x) @ w for float activations and a pre-quantized weight.

    Activations are dynamically quantized per-tensor; the integer result
    is rescaled back to f32.  `method` in {"operand", "lut"}.

    Rescale convention (shared by every approx path in the repo): the
    COMBINED dequant scale ``x_scale * w_scale`` is rounded ONCE and the
    accumulator is multiplied by it in a single f32 multiply.  A
    two-multiply chain ``(acc * x_scale) * w_scale`` is not
    association-stable under XLA (the simplifier regroups the cheap
    scalar/broadcast product), so it cannot be reproduced bit-for-bit
    across differently-compiled paths; the single-multiply form can.
    """
    from .quantization import quantize
    x_qt = quantize(x)
    if method == "lut":
        acc = approx_matmul_lut(x_qt.values, w_qt.values, config)
    else:
        acc = approx_matmul_operand(x_qt.values, w_qt.values, config)
    return acc.astype(jnp.float32) * expand_left(
        x_qt.scale * w_qt.scale, acc.ndim)


N_APPROX_CONFIGS = N_CONFIGS
__all__ = [
    "approx_matmul_lut", "approx_matmul_lut_np", "approx_matmul_operand",
    "approx_matmul_operand_blocked", "approx_matmul_lut_blocked",
    "quantized_matmul", "approx_dense", "operand_param_table",
    "CONFIG_TABLE", "N_APPROX_CONFIGS",
]
