"""Approximate matrix multiplication built on the error-configurable multiplier.

Three semantics, from bit-faithful to TPU-fast (see DESIGN.md §2):

  1. ``approx_matmul_lut``  — product-level approximation via the
     exhaustive 128x128 LUT of the hardware multiplier.  Bit-exact w.r.t.
     the ASIC model; materializes the (..., M, K, N) product tensor, so
     it is for oracle/small-model use (the paper's 62-30-10 MLP).
  2. ``approx_matmul_operand`` — the TPU-native adaptation: operand-LSB
     truncation (+gate) *before* an exact integer matmul.  MXU-friendly
     (mask -> dot), jit/pjit-shardable, and exactly the semantics the
     Pallas kernel in ``kernels/approx_mac`` implements.
  3. ``quantized_matmul`` — config 0 path (exact int8 x int8 -> int32),
     shared by both.

All integer matmuls accumulate in int32 (the hardware accumulates 62
14-bit products into 21 bits; int32 strictly contains that range — a
property test asserts no overflow against the 21-bit model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .approx_multiplier import (CONFIG_TABLE, N_CONFIGS, config_params,
                                exhaustive_products)
from .quantization import QTensor, truncate_operand_lsb

# ---------------------------------------------------------------------------
# LUT path (bit-faithful oracle)
# ---------------------------------------------------------------------------

_LUT_CACHE: dict[int, np.ndarray] = {}


def _lut(config: int) -> np.ndarray:
    if config not in _LUT_CACHE:
        _LUT_CACHE[config] = exhaustive_products(config).astype(np.int32)
    return _LUT_CACHE[config]


def approx_matmul_lut(a_q, b_q, config: int):
    """Bit-exact approximate matmul on int8 values.

    a_q: (..., M, K) int8, b_q: (K, N) int8 -> (..., M, N) int32.
    Each scalar product is looked up in the hardware multiplier table;
    signs handled by XOR (sign product), matching the paper MAC.
    """
    lut = jnp.asarray(_lut(config))
    a = a_q.astype(jnp.int32)
    b = b_q.astype(jnp.int32)
    a_mag, a_sign = jnp.abs(a), jnp.sign(a)
    b_mag, b_sign = jnp.abs(b), jnp.sign(b)
    # (..., M, K, 1) x (K, N) -> (..., M, K, N)
    prod_mag = lut[a_mag[..., :, :, None], b_mag[None, :, :]]
    sign = a_sign[..., :, :, None] * b_sign[None, :, :]
    return jnp.sum(prod_mag * sign, axis=-2)


def approx_matmul_lut_np(a_q: np.ndarray, b_q: np.ndarray, config: int) -> np.ndarray:
    """numpy twin (used by the cycle-level hardware simulator)."""
    lut = _lut(config)
    a = a_q.astype(np.int64)
    b = b_q.astype(np.int64)
    prod = lut[np.abs(a)[..., :, :, None], np.abs(b)[None, :, :]].astype(np.int64)
    sign = np.sign(a)[..., :, :, None] * np.sign(b)[None, :, :]
    return (prod * sign).sum(axis=-2)


# ---------------------------------------------------------------------------
# Operand-truncation path (TPU-native)
# ---------------------------------------------------------------------------

def approx_matmul_operand(a_q, b_q, config: int,
                          preferred_element_type=jnp.int32):
    """Operand-LSB-truncated exact matmul — the MXU-executable adaptation.

    a_q: (..., M, K) int8, b_q: (K, N) int8 -> int32.  The (mode, depth,
    gate) of `config` maps to per-operand truncation: ROUND/COMP modes use
    round-to-nearest, TRUNC/LOA floor.  depth is split across the two
    operands (ceil on weights, floor on activations) so the product-level
    error magnitude tracks the product-truncation model.
    """
    if config != 0:
        mode, t, gate = config_params(config)
        rtn = mode in (1, 2)
        t_a = t // 2
        t_b = t - t_a
        a_q = truncate_operand_lsb(a_q, t_a, gate, rtn)
        b_q = truncate_operand_lsb(b_q, t_b, gate, rtn)
    return jax.lax.dot_general(
        a_q, b_q,
        dimension_numbers=(((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred_element_type)


def quantized_matmul(a_q, b_q, preferred_element_type=jnp.int32):
    """Exact int8 matmul with int32 accumulation (config 0)."""
    return approx_matmul_operand(a_q, b_q, 0, preferred_element_type)


# ---------------------------------------------------------------------------
# Float-facing layer op
# ---------------------------------------------------------------------------

def approx_dense(x, w_qt: QTensor, config: int, *, method: str = "operand"):
    """y = approx(x) @ w for float activations and a pre-quantized weight.

    Activations are dynamically quantized per-tensor; the integer result
    is rescaled back to f32.  `method` in {"operand", "lut"}.
    """
    from .quantization import quantize
    x_qt = quantize(x)
    if method == "lut":
        acc = approx_matmul_lut(x_qt.values, w_qt.values, config)
    else:
        acc = approx_matmul_operand(x_qt.values, w_qt.values, config)
    w_scale = w_qt.scale if w_qt.axis is None else w_qt.scale[None, :]
    return acc.astype(jnp.float32) * x_qt.scale * w_scale


N_APPROX_CONFIGS = N_CONFIGS
__all__ = [
    "approx_matmul_lut", "approx_matmul_lut_np", "approx_matmul_operand",
    "quantized_matmul", "approx_dense", "CONFIG_TABLE", "N_APPROX_CONFIGS",
]
