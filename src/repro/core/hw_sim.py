"""Cycle-level simulator of the paper's datapath and FSM (Sections III-C/D).

The hardware integrates only 10 physical neurons, time-multiplexed:

  State 0:  hidden neurons  0..9   (weights/bias select = 0) -> registers
  State 1:  hidden neurons 10..19  (select = 1)              -> registers
  State 2:  hidden neurons 20..29  (select = 2)              -> registers
  State 3:  output neurons  0..9   (select = 3), max-circuit -> label;
            loop to State 0 while images remain
  State 4:  done signal

Each physical neuron's MAC consumes one (input, weight) pair per clock:
62 cycles/neuron in states 0-2 (inputs stream from memory), 30 in state 3
(hidden-register file), all 10 neurons in parallel.  This simulator
executes that schedule with bit-exact integer arithmetic (the same
multiplier LUT as the vectorized model), counts cycles and MAC
operations, and integrates the calibrated power model into energy.

A unit test asserts prediction-equivalence with the vectorized
``QuantizedMLP.apply`` — i.e. the multi-cycle resource-shared datapath
computes exactly the fully-parallel network, which is the paper's claim
in Section III-C ("ensures efficient use of hardware resources while
maintaining the accuracy and functionality").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.approx_multiplier import exhaustive_products
from repro.core.power_model import energy_per_mac_pj, network_power_mw
from repro.nn.mlp_paper import QMAX, QuantizedMLP

N_PHYS = 10
CLOCK_HZ = 100e6  # paper's measurement frequency


@dataclass
class SimResult:
    predictions: np.ndarray
    cycles: int
    mac_ops: int
    energy_uj: float          # modeled energy at CLOCK_HZ
    avg_power_mw: float
    fsm_trace: list = field(default_factory=list)


def simulate(qmlp: QuantizedMLP, images: np.ndarray, config: int = 0,
             trace_fsm: bool = False) -> SimResult:
    """Run the 5-state FSM over a batch of images, one image at a time."""
    lut = exhaustive_products(config).astype(np.int64)

    def mac_stream(x_vec: np.ndarray, w_mat: np.ndarray, b_vec: np.ndarray):
        """One FSM compute state: 10 physical neurons, sequential MACs."""
        n_in = x_vec.shape[0]
        acc = b_vec.astype(np.int64).copy()
        for k in range(n_in):                       # one clock per input
            xk = int(x_vec[k])
            prod = lut[abs(xk), np.abs(w_mat[k]).astype(np.int64)]
            sgn = np.sign(xk) * np.sign(w_mat[k].astype(np.int64))
            acc += sgn * prod
        return acc, n_in

    w1 = qmlp.w1.astype(np.int64)
    w2 = qmlp.w2.astype(np.int64)
    preds = np.zeros(len(images), dtype=np.int64)
    cycles = 0
    mac_ops = 0
    trace = []

    for i, img in enumerate(images):
        x_q = qmlp.quantize_input(img[None, :])[0].astype(np.int64)
        hidden = np.zeros(30, dtype=np.int64)
        # States 0..2: hidden layer, 10 neurons per state
        for state in range(3):
            sl = slice(state * N_PHYS, (state + 1) * N_PHYS)
            acc, n_cyc = mac_stream(x_q, w1[:, sl], qmlp.b1[sl])
            acc = np.maximum(acc, 0)                          # ReLU
            hidden[sl] = np.clip(acc >> qmlp.shift1, 0, QMAX)  # saturate
            cycles += n_cyc
            mac_ops += n_cyc * N_PHYS
            if trace_fsm:
                trace.append((i, state))
        # State 3: output layer + max circuit
        acc, n_cyc = mac_stream(hidden, w2, qmlp.b2)
        cycles += n_cyc + 1                                   # +1 max circuit
        mac_ops += n_cyc * N_PHYS
        preds[i] = int(np.argmax(acc))
        if trace_fsm:
            trace.append((i, 3))
    # State 4: done
    cycles += 1
    if trace_fsm:
        trace.append((len(images), 4))

    # energy: dynamic MAC energy (config-dependent) + the rest of the
    # network modeled at its calibrated constant power share.
    t_s = cycles / CLOCK_HZ
    mac_energy_uj = mac_ops * energy_per_mac_pj(config) * 1e-6
    # static + non-MAC switching: network power minus the MAC share, times t
    from repro.core.power_model import N_PHYSICAL_NEURONS, mac_power_mw
    rest_mw = network_power_mw(config) - N_PHYSICAL_NEURONS * mac_power_mw(config)
    rest_energy_uj = rest_mw * 1e-3 * t_s * 1e6
    energy_uj = mac_energy_uj + rest_energy_uj
    avg_power_mw = energy_uj * 1e-6 / t_s * 1e3 if t_s > 0 else 0.0
    return SimResult(predictions=preds, cycles=cycles, mac_ops=mac_ops,
                     energy_uj=energy_uj, avg_power_mw=avg_power_mw,
                     fsm_trace=trace)
