"""Dynamic power control — runtime selection of error configurations.

The paper's controller selects one of 32 MAC configurations at runtime to
trade accuracy for power.  We provide that knob plus its generalization
to deep networks:

  * ``select_uniform_config`` — the paper's policy: one global config,
    the most power-saving one whose measured accuracy drop stays within
    budget (evaluated on calibration data).
  * ``DynamicPowerController`` — per-layer allocation for multi-layer
    models: measures per-layer sensitivity (loss increase when only that
    layer is approximated), then greedily assigns deeper approximation to
    the least sensitive layers until the additive estimated degradation
    meets the budget.  This is the "dynamic power control" feature made
    first-class for the 10 assigned architectures: any layer built on
    ``approx_dense``/``approx_matmul_operand`` accepts a per-layer config.

Sensitivities are additive-first-order estimates; the controller
re-validates the final assignment end-to-end and backs off (lowers the
most aggressive layer) until the true degradation fits the budget.

The greedy allocation itself is the pure ``greedy_allocate`` below
(with ``Candidate``/``step_down_config``), shared with the ONLINE
``serve.scheduler.PowerBudgetScheduler`` — identical static feedback
through either path yields the identical assignment (DESIGN.md §7).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Hashable, Sequence

import numpy as np

from .power_model import MAC_SAVING_FRAC, N_CONFIGS


def select_uniform_config(eval_fn: Callable[[int], float],
                          budget: float,
                          configs: Sequence[int] = tuple(range(N_CONFIGS))
                          ) -> tuple[int, dict[int, float]]:
    """Paper policy: max-saving config whose accuracy drop <= budget.

    eval_fn(config) -> accuracy in [0,1].  Returns (config, {cfg: acc}).
    Configs are ordered by saving already (CONFIG_TABLE invariant)."""
    acc = {c: float(eval_fn(c)) for c in configs}
    base = acc[0]
    best = 0
    for c in configs:
        if base - acc[c] <= budget and MAC_SAVING_FRAC[c] >= MAC_SAVING_FRAC[best]:
            best = c
    return best, acc


@dataclass
class LayerSensitivity:
    layer: str
    config: int
    loss_delta: float
    saving: float


@dataclass(frozen=True)
class Candidate:
    """One (key, config) upgrade option for the shared greedy core.

    `key` is whatever the caller allocates over: a layer name (offline
    controller) or a (layer[, expert][, group]) index tuple (online
    scheduler).  `loss_delta` is the estimated quality degradation of
    running `key` at `config`; `saving` its MAC power saving fraction.
    """
    key: Hashable
    config: int
    loss_delta: float
    saving: float


def step_down_config(config: int, probe_configs: Sequence[int]) -> int:
    """Next config in `probe_configs` with strictly lower saving than
    `config` (0 = exact when none is lower) — ONE notch of backoff, the
    shared rule for the offline validation loop and the scheduler's
    online hysteresis (a one-config overshoot costs one notch of
    saving, never the whole allocation)."""
    lower = [c for c in probe_configs
             if MAC_SAVING_FRAC[c] < MAC_SAVING_FRAC[config]]
    return max(lower, key=lambda c: MAC_SAVING_FRAC[c], default=0)


def greedy_allocate(keys: Sequence[Hashable],
                    candidates: Sequence[Candidate],
                    loss_budget: float,
                    *, stop: Callable[[dict, "Candidate | None"],
                                      bool] | None = None
                    ) -> tuple[dict, float]:
    """Shared pure greedy allocation core (offline controller AND online
    scheduler): starting from all-exact, apply candidate upgrades in
    descending saving/degradation-ratio order while the cumulative
    estimated degradation stays within `loss_budget`, optionally
    stopping early once `stop(assignment, accepted)` holds (the
    scheduler's energy-budget-met predicate; the offline path passes
    none and runs the budget dry).  `stop` is called once up front with
    `accepted=None` and then only after each accepted upgrade with the
    accepted `Candidate` — the predicate can only change when the
    assignment does, and the accepted candidate lets the caller update
    incremental state in O(1).  Re-upgrading a key charges only the
    degradation *increase* over its current config.  Returns
    (assignment, spent).

    Deterministic: `sorted` is stable, so equal ratios resolve in
    candidate order — feeding identical sensitivities through the
    offline and online paths yields the identical assignment
    (tests/test_scheduler.py)."""
    assignment: dict = {k: 0 for k in keys}
    delta: dict = {}
    for c in candidates:
        delta.setdefault((c.key, c.config), c.loss_delta)
    spent = 0.0
    order = sorted(candidates,
                   key=lambda s: s.saving / max(s.loss_delta, 1e-9),
                   reverse=True)
    # stop() is a pure function of the assignment, which only changes
    # on an accepted upgrade — evaluating it once up front and once per
    # acceptance (instead of per candidate) is semantically identical
    # and keeps the scheduler's energy predicate off the O(candidates)
    # path
    if stop is not None and stop(assignment, None):
        return assignment, spent
    for cand in order:
        cur = assignment[cand.key]
        if MAC_SAVING_FRAC[cand.config] <= MAC_SAVING_FRAC[cur]:
            continue
        cur_delta = 0.0 if cur == 0 else delta.get((cand.key, cur), 0.0)
        extra = max(cand.loss_delta, 0.0) - max(cur_delta, 0.0)
        if spent + extra <= loss_budget:
            assignment[cand.key] = cand.config
            spent += extra
            if stop is not None and stop(assignment, cand):
                break
    return assignment, spent


class DynamicPowerController:
    """Greedy per-layer error-config allocator.

    loss_fn(assignment: dict[layer, config]) -> scalar loss (lower=better)
    layers: names of approximable layers.
    probe_configs: subset of configs to measure per layer (keeps the
    calibration pass cheap; savings for other configs are interpolated
    from MAC_SAVING_FRAC ordering).
    """

    def __init__(self, layers: Sequence[str],
                 loss_fn: Callable[[dict], float],
                 probe_configs: Sequence[int] = (8, 16, 24, 31)):
        self.layers = list(layers)
        self.loss_fn = loss_fn
        self.probe_configs = [c for c in probe_configs if 1 <= c < N_CONFIGS]
        self.base_loss: float | None = None
        self.sensitivity: list[LayerSensitivity] = []

    def calibrate(self) -> None:
        exact = {l: 0 for l in self.layers}
        self.base_loss = float(self.loss_fn(exact))
        self.sensitivity = []
        for layer in self.layers:
            for cfg in self.probe_configs:
                assignment = dict(exact)
                assignment[layer] = cfg
                delta = float(self.loss_fn(assignment)) - self.base_loss
                self.sensitivity.append(LayerSensitivity(
                    layer=layer, config=cfg, loss_delta=delta,
                    saving=float(MAC_SAVING_FRAC[cfg])))

    def allocate(self, loss_budget: float, validate: bool = True
                 ) -> dict[str, int]:
        """Assign configs maximizing total saving s.t. sum(loss_delta) <=
        budget (greedy by saving/delta ratio — the shared
        ``greedy_allocate`` core the online scheduler also runs), then
        optionally validate end-to-end and back off the costliest
        layers."""
        if self.base_loss is None:
            self.calibrate()
        cands = [Candidate(s.layer, s.config, s.loss_delta, s.saving)
                 for s in self.sensitivity]
        assignment, _ = greedy_allocate(self.layers, cands, loss_budget)
        if validate:
            while (float(self.loss_fn(assignment)) - self.base_loss
                   > loss_budget):
                worst = max((l for l in self.layers if assignment[l] > 0),
                            key=lambda l: self._delta(l, assignment[l]),
                            default=None)
                if worst is None:
                    break
                # step the worst layer DOWN to the next-lower probe config
                # instead of resetting it to exact: a one-config overshoot
                # should cost one notch of saving, not all of it (the
                # reset variant discarded recoverable savings — PR 1).
                assignment[worst] = self._step_down(assignment[worst])
        return assignment

    def _step_down(self, config: int) -> int:
        """Next probe config with strictly lower saving than `config`
        (0 = exact when none is lower)."""
        return step_down_config(config, self.probe_configs)

    def _delta(self, layer: str, config: int) -> float:
        if config == 0:
            return 0.0
        for s in self.sensitivity:
            if s.layer == layer and s.config == config:
                return s.loss_delta
        return 0.0

    def total_saving(self, assignment: dict[str, int]) -> float:
        """Mean per-layer MAC saving fraction of an assignment."""
        if not assignment:
            return 0.0
        return float(np.mean([MAC_SAVING_FRAC[c] for c in assignment.values()]))
