"""Standard approximate-arithmetic error metrics: ER, MRED, NMED.

Computed exhaustively over the 128x128 magnitude input space, matching the
methodology of the paper's Table I (metrics of the multiplier itself, not
of the network).  Definitions follow Strollo et al. (TCAS-I 2020) /
Yin et al. (TSUSC 2021) as cited by the paper:

  ED    = |approx - exact|
  ER    = P(ED != 0)                       (error rate)
  RED   = ED / exact              (exact != 0; pairs with exact==0 skipped)
  MRED  = mean(RED)
  NMED  = mean(ED) / max(exact)            (normalized mean error distance)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .approx_multiplier import EXACT_TABLE, N_CONFIGS, exhaustive_products


@dataclass(frozen=True)
class ErrorStats:
    config: int
    er: float      # in [0,1]
    mred: float    # in [0,1]
    nmed: float    # in [0,1]

    def as_percent(self) -> tuple[float, float, float]:
        return self.er * 100.0, self.mred * 100.0, self.nmed * 100.0


def multiplier_error_stats(config: int) -> ErrorStats:
    approx = exhaustive_products(config).astype(np.int64)
    exact = EXACT_TABLE
    ed = np.abs(approx - exact)
    er = float(np.mean(ed != 0))
    nonzero = exact != 0
    mred = float(np.mean(ed[nonzero] / exact[nonzero]))
    nmed = float(np.mean(ed) / exact.max())
    return ErrorStats(config=config, er=er, mred=mred, nmed=nmed)


def all_config_stats() -> list[ErrorStats]:
    return [multiplier_error_stats(c) for c in range(N_CONFIGS)]


_MRED_TABLE: list[np.ndarray] = []


def mred_table() -> np.ndarray:
    """(32,) measured MRED per config, computed once per process — the
    shared error ranking for conservative config joins (the engine's
    decode-pool join and the kernel's neuron-group collapse; config
    index is ordered by energy saving, in which error is non-monotone).
    """
    if not _MRED_TABLE:
        _MRED_TABLE.append(np.asarray(
            [multiplier_error_stats(c).mred for c in range(N_CONFIGS)],
            np.float32))
    return _MRED_TABLE[0]


def summary_table() -> dict[str, float]:
    """min/max/avg over the 31 approximate configs (paper excludes config 0)."""
    stats = [multiplier_error_stats(c) for c in range(1, N_CONFIGS)]
    ers = np.array([s.er for s in stats])
    mreds = np.array([s.mred for s in stats])
    nmeds = np.array([s.nmed for s in stats])
    return {
        "er_min": float(ers.min()), "er_max": float(ers.max()),
        "er_avg": float(ers.mean()),
        "mred_min": float(mreds.min()), "mred_max": float(mreds.max()),
        "mred_avg": float(mreds.mean()),
        "nmed_min": float(nmeds.min()), "nmed_max": float(nmeds.max()),
        "nmed_avg": float(nmeds.mean()),
    }


PAPER_TABLE_I = {
    "er_min": 0.099609, "er_max": 0.618255, "er_avg": 0.43556,
    "mred_min": 0.000548, "mred_max": 0.036840, "mred_avg": 0.02125,
    "nmed_min": 0.000028, "nmed_max": 0.003643, "nmed_avg": 0.00224,
}
