"""Deterministic synthetic LM token pipeline.

Offline container => no downloadable corpora.  The stream is a seeded
Markov-ish token process with enough structure that cross-entropy drops
measurably during the example training runs (repeated n-gram templates +
a power-law unigram background), while staying fully deterministic and
shard-aware: worker `w` of `W` sees batch rows `w::W` — the same global
batch regardless of topology, which makes elastic-restart tests exact.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticLMConfig:
    vocab_size: int = 1024
    seq_len: int = 256
    global_batch: int = 8
    n_templates: int = 64
    template_len: int = 16
    seed: int = 0


class SyntheticLM:
    """Iterator of {tokens, labels} numpy batches (global or per-shard)."""

    def __init__(self, cfg: SyntheticLMConfig, shard: int = 0,
                 num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self.templates = root.integers(
            2, v, size=(cfg.n_templates, cfg.template_len))
        # power-law unigram distribution over the vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rows = []
        local = cfg.global_batch // self.num_shards
        for i in range(local):
            row_id = self.shard + self.num_shards * i
            rng = np.random.default_rng(
                (cfg.seed, step, row_id))   # content depends only on these
            seq = []
            while len(seq) < cfg.seq_len + 1:
                if rng.random() < 0.7:
                    t = self.templates[rng.integers(cfg.n_templates)]
                    seq.extend(t.tolist())
                else:
                    seq.extend(rng.choice(len(self.unigram), size=8,
                                          p=self.unigram).tolist())
            rows.append(seq[:cfg.seq_len + 1])
        arr = np.asarray(rows, dtype=np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
