"""Shard-aware prefetching pipeline around any batch source.

``Prefetcher`` runs the (numpy-producing) data source in a daemon thread
with a bounded queue so host-side batch synthesis/IO overlaps the device
step — the standard input-pipeline shape for accelerator training.  The
device_put hook places each batch onto the mesh sharding when given
(host-to-device transfer also overlaps).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax


class Prefetcher:
    def __init__(self, source: Callable[[int], Any], *, depth: int = 2,
                 start_step: int = 0, place: Callable[[Any], Any] | None = None):
        """source(step) -> batch pytree (numpy); place: e.g.
        lambda b: jax.device_put(b, sharding_tree)."""
        self.source = source
        self.place = place or (lambda b: b)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.source(step)
            except Exception as e:            # surface errors to the consumer
                self._q.put(e)
                return
            # block while the queue is full (bounded prefetch)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self, timeout: float = 60.0):
        item = self._q.get(timeout=timeout)
        if isinstance(item, Exception):
            raise item
        step, batch = item
        return step, self.place(batch)

    def __iter__(self) -> Iterator:
        while True:
            yield self.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def sharded_placer(sharding_tree):
    """Batch placer moving host batches onto mesh shardings."""
    def place(batch):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, sharding_tree)
    return place
