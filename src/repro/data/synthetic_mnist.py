"""MNIST data layer: real IDX files when available, procedural fallback.

The container is offline, so we cannot download MNIST.  `load_mnist`
checks the conventional locations for the IDX files; if absent it
generates a deterministic procedural handwritten-digit dataset (vector
strokes per digit class + random affine jitter + blur + noise) whose
statistics are MNIST-like (28x28 grayscale in [0,1], 10 classes).  The
paper's validation target — accuracy deltas across the 32 MAC configs —
is dataset-instance independent, and the loader makes
the reproduction exact when real MNIST is present.

Feature reduction (paper: 784 -> 62 inputs "for a more hardware-efficient
design"; the algorithm is not specified): we use 4x4 average pooling of
the 24x24 center crop (-> 36) plus 26 fixed random-projection features,
i.e. 62 deterministic linear features — reproducible in hardware as fixed
wiring, matching the paper's constraint that reduction happens before
the network.
"""
from __future__ import annotations

import gzip
import os
import struct
from dataclasses import dataclass

import numpy as np

N_FEATURES = 62
_MNIST_DIRS = ("/root/data/mnist", "/root/mnist", "data/mnist",
               os.path.expanduser("~/.cache/mnist"))


# ---------------------------------------------------------------------------
# real MNIST (IDX format)
# ---------------------------------------------------------------------------

def _read_idx(path: str) -> np.ndarray:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rb") as f:
        magic, = struct.unpack(">I", f.read(4))
        ndim = magic & 0xFF
        dims = struct.unpack(">" + "I" * ndim, f.read(4 * ndim))
        return np.frombuffer(f.read(), dtype=np.uint8).reshape(dims)


def _try_load_real() -> tuple | None:
    names = {
        "train_x": ("train-images-idx3-ubyte", "train-images.idx3-ubyte"),
        "train_y": ("train-labels-idx1-ubyte", "train-labels.idx1-ubyte"),
        "test_x": ("t10k-images-idx3-ubyte", "t10k-images.idx3-ubyte"),
        "test_y": ("t10k-labels-idx1-ubyte", "t10k-labels.idx1-ubyte"),
    }
    for d in _MNIST_DIRS:
        if not os.path.isdir(d):
            continue
        found = {}
        for key, cands in names.items():
            for c in cands:
                for suffix in ("", ".gz"):
                    p = os.path.join(d, c + suffix)
                    if os.path.exists(p):
                        found[key] = p
                        break
                if key in found:
                    break
        if len(found) == 4:
            tx = _read_idx(found["train_x"]).astype(np.float32) / 255.0
            ty = _read_idx(found["train_y"]).astype(np.int32)
            vx = _read_idx(found["test_x"]).astype(np.float32) / 255.0
            vy = _read_idx(found["test_y"]).astype(np.int32)
            return tx, ty, vx, vy
    return None


# ---------------------------------------------------------------------------
# procedural fallback
# ---------------------------------------------------------------------------

# stroke skeletons per digit on a 20x20 canvas: list of polylines
_DIGIT_STROKES: dict[int, list] = {
    0: [[(6, 4), (13, 4), (16, 8), (16, 13), (13, 17), (6, 17), (3, 13), (3, 8), (6, 4)]],
    1: [[(9, 3), (11, 3), (11, 17)], [(7, 17), (15, 17)]],
    2: [[(4, 6), (7, 3), (13, 3), (16, 6), (15, 10), (4, 17), (16, 17)]],
    3: [[(4, 4), (14, 4), (10, 9), (15, 12), (14, 16), (4, 17)]],
    4: [[(12, 3), (4, 12), (16, 12)], [(12, 3), (12, 17)]],
    5: [[(15, 3), (5, 3), (5, 9), (13, 9), (16, 13), (12, 17), (4, 16)]],
    6: [[(13, 3), (6, 7), (4, 12), (7, 17), (13, 16), (15, 12), (10, 10), (5, 12)]],
    7: [[(4, 3), (16, 3), (9, 17)]],
    8: [[(10, 3), (5, 6), (10, 10), (15, 6), (10, 3)],
        [(10, 10), (4, 14), (10, 17), (16, 14), (10, 10)]],
    9: [[(15, 8), (10, 10), (5, 7), (9, 3), (14, 4), (15, 8), (13, 17), (7, 17)]],
}


def _render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    canvas = np.zeros((28, 28), dtype=np.float32)
    # random affine: scale, rotation, shear, translation
    ang = rng.normal(0.0, 0.15)
    scale = rng.normal(1.0, 0.08, size=2).clip(0.8, 1.2)
    shear = rng.normal(0.0, 0.1)
    tx, ty = rng.normal(4.0, 1.2), rng.normal(4.0, 1.2)
    ca, sa = np.cos(ang), np.sin(ang)
    m = np.array([[ca * scale[0], -sa + shear], [sa, ca * scale[1]]])
    thick = rng.uniform(0.7, 1.3)
    for stroke in _DIGIT_STROKES[digit]:
        pts = np.array(stroke, dtype=np.float32)
        pts = pts @ m.T + np.array([tx, ty])
        for (x0, y0), (x1, y1) in zip(pts[:-1], pts[1:]):
            n = max(int(np.hypot(x1 - x0, y1 - y0) * 3), 2)
            xs = np.linspace(x0, x1, n) + rng.normal(0, 0.12, n)
            ys = np.linspace(y0, y1, n) + rng.normal(0, 0.12, n)
            for x, y in zip(xs, ys):
                xi, yi = int(round(x)), int(round(y))
                for dx in (-1, 0, 1):
                    for dy in (-1, 0, 1):
                        px, py = xi + dx, yi + dy
                        if 0 <= px < 28 and 0 <= py < 28:
                            d = np.hypot(x - px, y - py)
                            canvas[py, px] = max(canvas[py, px],
                                                 float(np.exp(-(d / thick) ** 2)))
    noise = rng.normal(0, 0.02, canvas.shape).astype(np.float32)
    return np.clip(canvas + noise, 0.0, 1.0)


def _generate_procedural(n_train: int, n_test: int, seed: int):
    rng = np.random.default_rng(seed)
    def gen(n, r):
        ys = r.integers(0, 10, size=n).astype(np.int32)
        xs = np.stack([_render_digit(int(y), r) for y in ys])
        return xs, ys
    tx, ty = gen(n_train, rng)
    vx, vy = gen(n_test, np.random.default_rng(seed + 1))
    return tx, ty, vx, vy


# ---------------------------------------------------------------------------
# feature reduction: 784 -> 62
# ---------------------------------------------------------------------------

def _projection_matrix(seed: int = 1234) -> np.ndarray:
    """26 fixed random-projection rows over the 784 pixels (unit norm)."""
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(26, 784)).astype(np.float32)
    return m / np.linalg.norm(m, axis=1, keepdims=True)


_PROJ = _projection_matrix()


def reduce_features(images: np.ndarray) -> np.ndarray:
    """(N, 28, 28) or (N, 784) -> (N, 62) in [0, ~1]."""
    imgs = images.reshape(len(images), 28, 28)
    crop = imgs[:, 2:26, 2:26]                                    # 24x24
    pooled = crop.reshape(len(imgs), 6, 4, 6, 4).mean(axis=(2, 4))  # 6x6=36
    proj = images.reshape(len(images), 784) @ _PROJ.T * 0.1        # 26
    feats = np.concatenate([pooled.reshape(len(imgs), 36), proj], axis=1)
    return feats.astype(np.float32)


@dataclass
class MNISTData:
    train_x: np.ndarray   # (N, 62)
    train_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    source: str           # "real" | "procedural"


def load_mnist(n_train: int = 8000, n_test: int = 2000,
               seed: int = 0) -> MNISTData:
    real = _try_load_real()
    if real is not None:
        tx, ty, vx, vy = real
        src = "real"
    else:
        tx, ty, vx, vy = _generate_procedural(n_train, n_test, seed)
        src = "procedural"
    tx, ty = tx[:n_train], ty[:n_train]
    vx, vy = vx[:n_test], vy[:n_test]
    return MNISTData(train_x=reduce_features(tx), train_y=ty,
                     test_x=reduce_features(vx), test_y=vy, source=src)
